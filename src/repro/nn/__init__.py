"""Minimal functional neural-net substrate: param specs, logical-axis
sharding, and the layer zoo shared by the DLRM core and the LM family.

Everything is a pure function over pytrees of arrays; a "module" is a pair of
(param_specs(cfg) -> pytree[ParamSpec], apply(params, ...) -> arrays).
"""
from repro.nn.params import (  # noqa: F401
    ParamSpec,
    abstract_params,
    init_params,
    specs_to_pspecs,
    specs_to_shardings,
    stack_specs,
)
from repro.nn.sharding import (  # noqa: F401
    LogicalRules,
    logical_to_pspec,
    shard_activation,
)
