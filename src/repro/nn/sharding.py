"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Params and activations carry *logical* axis names ("vocab", "heads", "ff",
"batch", ...). A rules table maps each logical name to a mesh axis (or a tuple
of mesh axes, or None = replicated). Conflict resolution: within one
PartitionSpec a physical mesh axis may be used at most once; later logical
axes that would reuse an already-consumed mesh axis degrade to replicated.

This is the single knob the perf hillclimb turns: change the rules, re-lower.
"""
from __future__ import annotations

from collections.abc import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = None | str | tuple[str, ...]
LogicalRules = dict[str, MeshAxes]

# ---------------------------------------------------------------------------
# Default rule tables. "pod" only exists on the multi-pod mesh; rules are
# filtered against the live mesh axis names at resolution time so one table
# serves both meshes.
# ---------------------------------------------------------------------------

#: Training rules: data-parallel batch, tensor-parallel heads/ff/vocab/expert.
#: This is the paper-faithful mapping: `data` axis = trainers, `model` axis =
#: sparse parameter-server shards (DESIGN.md section 2).
TRAIN_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "expert": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "conv_dim": "model",
    "layer": None,
    # DLRM logical axes
    "hash": "model",        # row-wise embedding-table sharding
    "table": None,          # table-wise handled by the placement planner
    "feature": None,
    "dense_ff": "model",
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_vocab": "model",
    "act_heads": "model",
    "act_ff": "model",
    # MoE dispatch tiles: (group, expert, capacity) on ((pod, data), model, -)
    "act_tokens": ("pod", "data"),
    "act_expert": "model",
    "moe_groups": ("pod", "data"),
    "moe_cap": None,
}

#: FSDP (ZeRO-3) + sequence-parallel variant — the DEFAULT train mapping for
#: the dry-run (every assigned arch is >= 0.8B: replicated fp32 grads alone
#: blow 16 GB/chip; see EXPERIMENTS.md section Perf for the measured delta
#: vs. plain TRAIN_RULES). Weights/opt-state/grads shard over `data` on the
#: non-TP dim; the residual stream between blocks shards its seq dim over
#: `model` (Megatron-style sequence parallelism), bounding saved activations.
FSDP_RULES: LogicalRules = dict(
    TRAIN_RULES,
    embed=("data",),
    head_dim=None,
    ssm_state=None,
    _gather_weights=True,
)

#: Beyond-paper train mapping (§Perf): pure data parallelism over ALL mesh
#: axes + ZeRO-3 weight sharding. No tensor parallelism => no per-layer
#: activation all-reduces at all; the only collectives are bf16 weight
#: all-gathers (fwd + rematted bwd) and gradient reduce-scatters. Wins when
#: per-chip batch stays >= 1 and the full vocab CE region fits (it does at
#: 4096 tokens/chip for every assigned arch). MoE dispatch becomes fully
#: local (every chip holds gathered experts).
ZERO_DP_RULES: LogicalRules = dict(
    TRAIN_RULES,
    batch=("pod", "data", "model"),
    act_batch=("pod", "data", "model"),
    act_tokens=("pod", "data", "model"),
    moe_groups=("pod", "data", "model"),
    heads=None, kv_heads=None, ff=None, vocab=None,
    ssm_inner=None, ssm_heads=None, conv_dim=None,
    act_vocab=None, act_heads=None, act_ff=None, act_expert=None,
    expert=("model",),                   # experts still sharded at rest
    embed=("data", "model"),             # ZeRO-3: 256-way sharded at rest...
    _gather_weights=True,                # ...gathered bf16 at compute
    _gather_axes=("embed", "expert"),    # experts fully gathered too: the
                                         # dispatch becomes chip-local
)

#: Serving rules: pure TP over `model`, batch over `data`; KV cache seq dim
#: sharded over `model` when kv_heads are too few / not divisible (flash-
#: decoding style; XLA inserts the softmax collectives).
SERVE_RULES: LogicalRules = dict(
    TRAIN_RULES,
    batch=("pod", "data"),
    act_batch=("pod", "data"),
    embed=None,
    cache_seq=None,
    cache_kv="model",
    # serving-only: a non-divisible heads dim (qwen's 40) migrates its mesh
    # axis to head_dim so bf16 weights still shard 16-ways; the price is
    # score-matrix partial-sums, negligible at decode (q_len=1). Training
    # does NOT use this (score all-reduces at 4k seq measured 7x worse).
    _fallback={"heads": "head_dim", "kv_heads": "head_dim"},
)

#: Serving rules for long-context decode (batch=1 cannot fill `data`):
#: shard the cache sequence dim over `model` (flash-decoding — XLA inserts
#: the softmax-reduction collectives); batch/token dims replicated.
LONG_SERVE_RULES: LogicalRules = dict(
    SERVE_RULES,
    cache_seq="model",
    cache_kv=None,
    batch=None,
    act_batch=None,
    act_tokens=None,
    moe_groups=None,
)


def _resolve(axes: Sequence[str | None], rules: LogicalRules,
             mesh_axis_names: Sequence[str]) -> P:
    """Map logical axis names to a PartitionSpec, dropping conflicts."""
    used: set = set()
    out = []
    for name in axes:
        if name is None:
            out.append(None)
            continue
        target = rules.get(name, None)
        if target is None:
            out.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        picked = tuple(t for t in target
                       if t in mesh_axis_names and t not in used)
        for t in picked:
            used.add(t)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    # trim trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_to_pspec(axes: Sequence[str | None],
                     rules: LogicalRules,
                     mesh: Mesh | None = None) -> P:
    names = mesh.axis_names if mesh is not None else _live_mesh_axis_names()
    return _resolve(axes, rules, names)


def resolve_sized(axes: Sequence[str | None], rules: LogicalRules,
                  mesh: Mesh, shape: Sequence[int]) -> P:
    """Like _resolve, but drops mesh axes that do not evenly divide the
    dimension (pjit argument shardings require divisibility — e.g. qwen's
    40 kv heads or mamba's 50280 vocab cannot shard 16-ways).

    A dropped mesh axis may MIGRATE to a sibling dim via rules["_fallback"]
    (e.g. heads -> head_dim): qwen's wq (d, 40, 128) becomes
    P("data", None, "model") instead of leaving the whole attention stack —
    weights, grads, optimizer moments — replicated over the TP axis
    (measured 20+ GB/chip of replication waste, EXPERIMENTS.md Perf)."""
    base = _resolve(axes, rules, mesh.axis_names)
    out = []
    dropped = []                       # (mesh_axis, source_logical_name)
    for i, dim in enumerate(shape):
        entry = base[i] if i < len(base) else None
        if entry is None:
            out.append(None)
            continue
        cand = entry if isinstance(entry, tuple) else (entry,)
        kept, prod = [], 1
        for a in cand:
            size = mesh.shape[a]
            if dim % (prod * size) == 0:
                kept.append(a)
                prod *= size
            elif i < len(axes):
                dropped.append((a, axes[i]))
        out.append(tuple(kept) if len(kept) > 1
                   else (kept[0] if kept else None))
    fallbacks = rules.get("_fallback") or {}
    if dropped and fallbacks:
        used = {a for e in out if e
                for a in (e if isinstance(e, tuple) else (e,))}
        for mesh_ax, src in dropped:
            tgt = fallbacks.get(src)
            if tgt is None or mesh_ax in used:
                continue
            for j, lname in enumerate(axes):
                if (lname == tgt and j < len(shape) and out[j] is None
                        and shape[j] % mesh.shape[mesh_ax] == 0):
                    out[j] = mesh_ax
                    used.add(mesh_ax)
                    break
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _live_mesh() -> Mesh | None:
    env_mesh = jax._src.mesh.thread_resources.env.physical_mesh
    if env_mesh.empty:
        return None
    return env_mesh


def _live_mesh_axis_names() -> tuple[str, ...]:
    m = _live_mesh()
    return tuple(m.axis_names) if m is not None else ()


def shard_activation(x, axes: Sequence[str | None],
                     rules: LogicalRules,
                     mesh: Mesh | None = None):
    """with_sharding_constraint by logical axis names; no-op outside a mesh
    or with an empty rules table (an empty table means "unmanaged", not
    "replicate everything"). Size-aware: mesh axes that don't divide a dim
    are dropped rather than erroring."""
    if not rules:
        return x
    mesh = mesh if mesh is not None else _live_mesh()
    if mesh is None:
        return x
    spec = resolve_sized(axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, axes: Sequence[str | None],
                   rules: LogicalRules) -> NamedSharding:
    return NamedSharding(mesh, _resolve(axes, rules, mesh.axis_names))


#: weight logical axes that FSDP shards at rest and gathers at compute time
#: (rules["_gather_axes"] overrides; ZERO_DP adds "expert")
GATHERED_AXES = ("embed",)


def gather_weight(w, axes: Sequence[str | None], rules: LogicalRules):
    """Manual FSDP: re-constrain a (compute-dtype) weight to its gathered,
    TP-only sharding at the point of use.

    Storage sharding (from the ParamSpec) keeps `embed` on the `data` axis;
    this constraint drops it, so the partitioner emits one bf16 all-gather
    of the weight per use (forward, and again in the rematted backward) and
    a reduce-scatter of the weight gradient — ZeRO-3 traffic, instead of
    guessing (it otherwise replicates ACTIVATIONS and all-reduces
    activation-sized partials — measured 16x worse, EXPERIMENTS.md Perf).
    Enabled by rules["_gather_weights"]; a no-op otherwise.
    """
    if not rules or not rules.get("_gather_weights"):
        return w
    gathered = rules.get("_gather_axes", GATHERED_AXES)
    g_axes = tuple(None if a in gathered else a for a in axes)
    return shard_activation(w, g_axes, rules)
