"""Token-choice top-k Mixture-of-Experts with GShard-style GROUPED dispatch.

Tokens are split into `moe_groups` groups (one per data shard at scale);
capacity, position-in-expert, gather tables and combine all stay group-local,
so the only cross-shard traffic is the (group, expert, capacity, d) reshard
between the data-sharded group dim and the model-sharded expert dim — the
MoE all-to-all. A global-token formulation instead makes XLA all-gather
every token to every chip (measured 16x worse, EXPERIMENTS.md Perf).

The placement analogy to the paper (DESIGN.md section 4): experts are embedding
tables, the router is a multi-hot lookup, expert-parallel sharding over
`model` is table-wise placement, and per-group capacity is the paper's
truncation-size bound on lookups.

Expert padding: expert counts that don't divide the TP axis (granite-3b's
40 over 16 shards) are padded with never-routed dummy experts
(cfg.expert_pad) — weights shard evenly; the router only scores real
experts. GShard does the same.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.params import ParamSpec
from repro.nn.sharding import gather_weight, shard_activation


def moe_specs(cfg) -> dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts + cfg.expert_pad
    out_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "router": ParamSpec((d, cfg.n_experts), ("embed", None),
                            init="fan_in"),
        "wi": ParamSpec((e, d, f), ("expert", "embed", "ff"),
                        init="fan_in", fan_axis=1),
        "wg": ParamSpec((e, d, f), ("expert", "embed", "ff"),
                        init="fan_in", fan_axis=1),
        "wo": ParamSpec((e, f, d), ("expert", "ff", "embed"),
                        init="fan_in", fan_axis=1, scale=out_scale),
    }


def _capacity(tokens_per_group: int, n_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(math.ceil(top_k * tokens_per_group / n_experts
                      * capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 (sublane friendly)


def moe(p, x: jax.Array, cfg, dtype=jnp.bfloat16,
        capacity_factor: float = None,
        rules=None) -> tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_pad = e + cfg.expert_pad
    cf = capacity_factor or cfg.capacity_factor
    t = b * s
    g = max(1, cfg.moe_groups)
    assert t % g == 0, (t, g)
    tg = t // g
    cap = _capacity(tg, e, k, cf)

    xg = x.reshape(g, tg, d).astype(dtype)
    xg = shard_activation(xg, ("moe_groups", None, None), rules or {})
    logits = (xg @ p["router"].astype(dtype)).astype(jnp.float32)  # (g,tg,e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (g, tg, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): e * sum(fraction * prob_mean)
    me = probs.mean(axis=(0, 1))                             # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce)

    # group-local position of each (token, slot) within its expert
    flat_e = gate_idx.reshape(g, tg * k)                     # (g, n)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)      # (g, n, e)
    pos = jnp.cumsum(onehot, axis=1) - onehot                # exclusive
    pos = jnp.take_along_axis(pos, flat_e[..., None],
                              axis=2)[..., 0]                # (g, n)
    keep = pos < cap

    # scatter (token, slot) -> (expert, cap) gather table, per group
    token_ids = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k))
    slot_e = jnp.where(keep, flat_e, e_pad)       # overflow/pad row: dropped
    slot_p = jnp.where(keep, pos, 0)

    def build_table(se, sp, ti):
        tab = jnp.full((e_pad + 1, cap), tg, jnp.int32)      # tg = sentinel
        return tab.at[se, sp].set(ti)[:e_pad]

    gather = jax.vmap(build_table)(slot_e, slot_p, token_ids)  # (g,e_pad,cap)

    # group-local gather (sentinel row -> zeros), then the constraint to
    # (data x model) tiles performs the all-to-all
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), dtype)], axis=1)
    xe = jax.vmap(lambda xp, gt: xp[gt])(xpad, gather)       # (g,e_pad,cap,d)
    xe = shard_activation(xe, ("moe_groups", "act_expert", None, None),
                          rules or {})

    wi = gather_weight(p["wi"].astype(dtype), ("expert", "embed", "ff"),
                       rules)
    wg = gather_weight(p["wg"].astype(dtype), ("expert", "embed", "ff"),
                       rules)
    wo = gather_weight(p["wo"].astype(dtype), ("expert", "ff", "embed"),
                       rules)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, wg)) * \
        jnp.einsum("gecd,edf->gecf", xe, wi)
    h = shard_activation(h, ("moe_groups", "act_expert", None, "act_ff"),
                         rules or {})
    ye = jnp.einsum("gecf,efd->gecd", h, wo)                 # (g,e_pad,cap,d)
    ye = shard_activation(ye, ("moe_groups", "act_expert", None, None),
                          rules or {})

    # combine back, group-local
    ye_flat = ye.reshape(g, e_pad * cap, d)
    slot_flat = jnp.where(keep, flat_e * cap + pos, 0)       # (g, n)
    contrib = jax.vmap(lambda yf, sf: yf[sf])(ye_flat, slot_flat)
    contrib = contrib * (gate_vals.reshape(g, tg * k, 1)
                         * keep[..., None]).astype(dtype)
    y = jax.vmap(lambda ti, c: jnp.zeros((tg, d), jnp.float32)
                 .at[ti].add(c.astype(jnp.float32)))(token_ids, contrib)
    y = shard_activation(y, ("moe_groups", None, None), rules or {})
    return y.reshape(b, s, d).astype(dtype), aux
