"""Mamba-2 (SSD — state-space duality) block, chunked-parallel for train /
prefill and O(1)-state recurrent for decode.

Follows the minimal SSD formulation of arXiv:2405.21060: within a chunk the
output is a masked (semiseparable) matmul — MXU-friendly — and across chunks
a short scan propagates the (heads, headdim, state) tensor.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.params import ParamSpec
from repro.nn.sharding import gather_weight


def mamba_dims(cfg) -> dict[str, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return {
        "d_inner": d_inner,
        "n_heads": n_heads,
        "headdim": cfg.ssm_headdim,
        "d_state": cfg.ssm_state,
        "n_groups": cfg.ssm_ngroups,
        "d_conv": cfg.ssm_conv,
        # in_proj produces: z (d_inner), x (d_inner), B (g*n), C (g*n), dt (h)
        "d_in_proj": 2 * d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
        + n_heads,
        "conv_dim": d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state,
    }


def mamba_specs(cfg) -> dict[str, Any]:
    d = cfg.d_model
    m = mamba_dims(cfg)
    out_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "in_proj": ParamSpec((d, m["d_in_proj"]), ("embed", "ssm_inner"),
                             init="fan_in"),
        "conv_w": ParamSpec((m["d_conv"], m["conv_dim"]),
                            (None, "conv_dim"), init="fan_in", fan_axis=0),
        "conv_b": ParamSpec((m["conv_dim"],), ("conv_dim",), init="zeros"),
        "dt_bias": ParamSpec((m["n_heads"],), ("ssm_heads",),
                             init="constant", scale=math.log(math.e - 1)),
        "A_log": ParamSpec((m["n_heads"],), ("ssm_heads",),
                           init="constant", scale=0.0),
        "D": ParamSpec((m["n_heads"],), ("ssm_heads",), init="ones"),
        "norm_scale": ParamSpec((m["d_inner"],), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((m["d_inner"], d), ("ssm_inner", "embed"),
                              init="fan_in", scale=out_scale),
    }


def _segsum(logdec: jax.Array) -> jax.Array:
    """Stable segment-sum: logdec (..., l) -> (..., l, l) lower-tri cumsums,
    L[i, j] = sum(logdec[j+1 .. i]) for j <= i, -inf above the diagonal."""
    ln = logdec.shape[-1]
    cs = jnp.cumsum(logdec, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((ln, ln), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan.  x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative);
    B, C: (b, s, g, n) with h % g == 0. Returns (y, final_state)."""
    b, s, h, p = x.shape
    g, n = B.shape[-2], B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    # fold dt into x and build per-step log decay (decay math stays fp32)
    xdt = x * dt.astype(x.dtype)[..., None]          # (b, s, h, p)
    logdec = dt * A                                  # (b, s, h), <= 0

    # chunk views
    xc = xdt.reshape(b, nc, chunk, h, p)
    dc = logdec.reshape(b, nc, chunk, h).transpose(0, 1, 3, 2)  # (b,c,h,l)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                 # (b, c, l, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # 1. intra-chunk (diagonal blocks): Y = (C B^T ∘ L) X
    L = jnp.exp(_segsum(dc))                         # (b, c, h, l, l)
    scores = jnp.einsum("bclhn,bcmhn->bchlm", Ch, Bh)
    y_diag = jnp.einsum("bchlm,bchlm,bcmhp->bclhp",
                        scores, L.astype(scores.dtype), xc)

    # 2. chunk final states: S_c = sum_m decay_to_end[m] * B_m x_m^T
    dcum = jnp.cumsum(dc, axis=-1)                   # (b, c, h, l)
    dec_to_end = jnp.exp(dcum[..., -1:] - dcum)      # (b, c, h, l)
    states = jnp.einsum("bchl,bclhn,bclhp->bchpn",
                        dec_to_end.astype(x.dtype), Bh, xc)  # per-chunk

    # 3. inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(dcum[..., -1])             # (b, c, h)

    def step(carry, inp):
        st_prev = carry                              # (b, h, p, n)
        st_c, dec_c = inp                            # (b,h,p,n), (b,h)
        st = st_prev * dec_c[..., None, None].astype(st_prev.dtype) + st_c
        return st, st_prev

    init = jnp.zeros((b, h, p, n), x.dtype)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, c, h, p, n)

    # 4. inter-chunk output: Y_off = C_l · (decay_from_start[l] * S_{c-1})
    dec_from_start = jnp.exp(dcum)                   # (b, c, h, l)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp",
                       Ch, prev_states, dec_from_start.astype(x.dtype))

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One recurrent step. state: (b,h,p,n); x_t: (b,h,p); dt_t: (b,h);
    B_t, C_t: (b,g,n). Returns (y_t, new_state)."""
    h = x_t.shape[1]
    g = B_t.shape[1]
    rep = h // g
    Bh = jnp.repeat(B_t, rep, axis=1)                # (b, h, n)
    Ch = jnp.repeat(C_t, rep, axis=1)
    dec = jnp.exp(dt_t * A)                          # (b, h)
    upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], Bh)
    new_state = state * dec[..., None, None].astype(state.dtype) + \
        upd.astype(state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch.astype(state.dtype))
    return y, new_state


def _causal_conv_train(xBC, w, bias):
    """xBC: (b, s, c); w: (k, c) depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(k):
        out = out + pad[:, i:i + xBC.shape[1]] * w[i]
    return out + bias


def _split_in_proj(zxbcdt, m):
    di, g, n, h = m["d_inner"], m["n_groups"], m["d_state"], m["n_heads"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + m["conv_dim"]]
    dt = zxbcdt[..., di + m["conv_dim"]:]
    return z, xBC, dt


def mamba_block(p, x, cfg, *, mode: str = "train",
                cache: dict[str, jax.Array] | None = None,
                dtype=jnp.bfloat16,
                rules=None) -> tuple[jax.Array, dict | None]:
    """Mamba-2 mixer. cache (decode): {"conv": (b, k-1, conv_dim),
    "ssm": (b, h, p, n)}."""
    m = mamba_dims(cfg)
    b, s, _ = x.shape
    in_proj = gather_weight(p["in_proj"].astype(dtype),
                            ("embed", "ssm_inner"), rules)
    zxbcdt = x.astype(dtype) @ in_proj
    z, xBC, dt = _split_in_proj(zxbcdt, m)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (h,), negative
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (b, s, h)

    conv_w = p["conv_w"].astype(dtype)
    conv_b = p["conv_b"].astype(dtype)

    if mode == "decode":
        assert cache is not None and s == 1
        # causal conv via cache of the last k-1 inputs
        hist = jnp.concatenate([cache["conv"],
                                xBC.astype(cache["conv"].dtype)], axis=1)
        xBC_c = (hist * conv_w[None]).sum(axis=1, keepdims=True) + conv_b
        new_conv = hist[:, 1:]
        xBC_c = jax.nn.silu(xBC_c)
        xs = xBC_c[..., :m["d_inner"]].reshape(b, 1, m["n_heads"],
                                               m["headdim"])
        Bmat = xBC_c[..., m["d_inner"]:m["d_inner"] + m["n_groups"]
                     * m["d_state"]].reshape(b, 1, m["n_groups"], m["d_state"])
        Cmat = xBC_c[..., m["d_inner"] + m["n_groups"] * m["d_state"]:] \
            .reshape(b, 1, m["n_groups"], m["d_state"])
        y_t, new_ssm = ssd_decode_step(
            cache["ssm"], xs[:, 0], dt[:, 0], A, Bmat[:, 0], Cmat[:, 0])
        y = y_t[:, None]                                   # (b, 1, h, p)
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    else:
        xBC_c = jax.nn.silu(_causal_conv_train(xBC, conv_w, conv_b))
        xs = xBC_c[..., :m["d_inner"]].reshape(b, s, m["n_heads"],
                                               m["headdim"])
        Bmat = xBC_c[..., m["d_inner"]:m["d_inner"] + m["n_groups"]
                     * m["d_state"]].reshape(b, s, m["n_groups"], m["d_state"])
        Cmat = xBC_c[..., m["d_inner"] + m["n_groups"] * m["d_state"]:] \
            .reshape(b, s, m["n_groups"], m["d_state"])
        y, final_state = ssd_chunked(xs, dt, A, Bmat, Cmat,
                                     chunk=min(cfg.ssm_chunk, s))
        new_cache = None
        if mode == "prefill":
            assert cache is not None
            new_conv = jnp.concatenate(
                [jnp.zeros_like(xBC[:, :max(0, m["d_conv"] - 1 - s)]),
                 xBC[:, -(m["d_conv"] - 1):]], axis=1
            ).astype(cache["conv"].dtype)
            new_cache = {"conv": new_conv, "ssm": final_state}

    # skip connection D, gate, norm, out projection
    y = y + xs * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, m["d_inner"])
    y = y * jax.nn.silu(z.astype(y.dtype))
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-5)
         * p["norm_scale"].astype(jnp.float32)).astype(dtype)
    out_proj = gather_weight(p["out_proj"].astype(dtype),
                             ("ssm_inner", "embed"), rules)
    return y @ out_proj, new_cache


def init_mamba_cache(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    m = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, m["d_conv"] - 1, m["conv_dim"]), dtype),
        "ssm": jnp.zeros((batch, m["n_heads"], m["headdim"], m["d_state"]),
                         jnp.float32),
    }


def mamba_cache_abstract(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    m = mamba_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct(
            (batch, m["d_conv"] - 1, m["conv_dim"]), dtype),
        "ssm": jax.ShapeDtypeStruct(
            (batch, m["n_heads"], m["headdim"], m["d_state"]), jnp.float32),
    }
