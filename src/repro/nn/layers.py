"""Layer zoo: linear, norms, RoPE (neox / glm-2d / none), GQA attention
(full, blockwise-flash, and cached decode incl. int8 KV), MLPs.

All functions are pure; params are dicts produced by the matching *_specs
function.  compute happens in cfg-selected dtype (bf16 default), params are
stored in fp32 and cast at the point of use.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.params import ParamSpec
from repro.nn.sharding import gather_weight

# ---------------------------------------------------------------------------
# linear / norm
# ---------------------------------------------------------------------------


def linear_specs(d_in: int, d_out: int, in_ax: str, out_ax: str,
                 bias: bool = False, scale: float = 1.0) -> dict[str, ParamSpec]:
    specs = {"w": ParamSpec((d_in, d_out), (in_ax, out_ax), init="fan_in",
                            scale=scale, fan_axis=-2)}
    if bias:
        specs["b"] = ParamSpec((d_out,), (out_ax,), init="zeros")
    return specs


def linear(p: dict[str, jax.Array], x: jax.Array,
           dtype=jnp.bfloat16) -> jax.Array:
    w = p["w"].astype(dtype)
    y = jnp.einsum("...i,io->...o", x.astype(dtype), w)
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def norm_specs(d: int, kind: str = "rmsnorm") -> dict[str, ParamSpec]:
    specs = {"scale": ParamSpec((d,), ("embed",), init="ones")}
    if kind == "layernorm":
        specs["bias"] = ParamSpec((d,), ("embed",), init="zeros")
    return specs


def apply_norm(p: dict[str, jax.Array], x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-5, dtype=jnp.bfloat16, rules=None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * gather_weight(p["scale"].astype(jnp.float32), ("embed",), rules)
    if "bias" in p:
        y = y + gather_weight(p["bias"].astype(jnp.float32), ("embed",),
                              rules)
    return y.astype(dtype)

# ---------------------------------------------------------------------------
# positions: RoPE (neox split-half, glm interleaved-half) + sinusoidal
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, rotary_pct: float, theta: float,
                     style: str) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    if style == "glm":
        rot = head_dim // 2          # ChatGLM rotates the first half, 2D style
    else:
        rot = int(head_dim * rotary_pct)
    rot -= rot % 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, head_dim: int,
               rotary_pct: float = 1.0, theta: float = 10000.0,
               style: str = "neox") -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    if style == "none":
        return x
    inv = rope_frequencies(head_dim, rotary_pct, theta, style)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., s, rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    rot = inv.shape[0] * 2
    xr, xp = x[..., :rot], x[..., rot:]
    xf = xr.astype(jnp.float32)
    if style == "glm":
        # interleaved pairing (x0,x1),(x2,x3),... — ChatGLM's 2D RoPE halves
        x1, x2 = xf[..., 0::2], xf[..., 1::2]
        o1 = x1 * cos - x2 * sin
        o2 = x2 * cos + x1 * sin
        out = jnp.stack([o1, o2], axis=-1).reshape(xf.shape)
    else:
        # neox split-half pairing (x_i, x_{i+rot/2})
        half = rot // 2
        x1, x2 = xf[..., :half], xf[..., half:]
        out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                              axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """MusicGen-style sinusoidal absolute position embedding."""
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d_model, 2, dtype=jnp.float32)
                             / d_model))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_specs(cfg) -> dict[str, Any]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", "head_dim"),
                        init="fan_in", fan_axis=0),
        "wk": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim"),
                        init="fan_in", fan_axis=0),
        "wv": ParamSpec((d, kv, dh), ("embed", "kv_heads", "head_dim"),
                        init="fan_in", fan_axis=0),
        "wo": ParamSpec((h, dh, d), ("heads", "head_dim", "embed"),
                        init="fan_in", fan_axis=1,
                        scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
        **({"bq": ParamSpec((h, dh), ("heads", "head_dim"), init="zeros"),
            "bk": ParamSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros"),
            "bv": ParamSpec((kv, dh), ("kv_heads", "head_dim"), init="zeros")}
           if cfg.qkv_bias else {}),
    }


def _qkv(p, x, cfg, positions, dtype, rules=None):
    wq = gather_weight(p["wq"].astype(dtype),
                       ("embed", "heads", "head_dim"), rules)
    wk = gather_weight(p["wk"].astype(dtype),
                       ("embed", "kv_heads", "head_dim"), rules)
    wv = gather_weight(p["wv"].astype(dtype),
                       ("embed", "kv_heads", "head_dim"), rules)
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = apply_rope(q, positions, cfg.d_head, cfg.rotary_pct, cfg.rope_theta,
                   cfg.rope_style)
    k = apply_rope(k, positions, cfg.d_head, cfg.rotary_pct, cfg.rope_theta,
                   cfg.rope_style)
    return q, k, v


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, kv, dh) -> (b, s, h, dh) by repeating each kv group."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=-2)


def full_attention(q, k, v, q_offset: int = 0, causal: bool = True,
                   kv_valid_len: jax.Array | None = None) -> jax.Array:
    """Materialized-scores attention. q:(b,sq,h,dh) k,v:(b,sk,h,dh).
    kv_valid_len: scalar or (b,) per-sequence valid cache length."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(dh)
    b, sq = q.shape[0], q.shape[1]
    sk = k.shape[1]
    mask = jnp.zeros((1, 1, sq, sk), jnp.bool_)
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = mask | (kpos > qpos)[None, None]
    if kv_valid_len is not None:
        valid = jnp.asarray(kv_valid_len)
        valid = jnp.broadcast_to(valid, (b,))          # scalar or (b,)
        mask = mask | (jnp.arange(sk)[None, None, None, :]
                       >= valid[:, None, None, None])
    scores = jnp.where(mask, -1e30, scores)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def blockwise_attention(q, k, v, block_q: int = 512, block_k: int = 1024,
                        causal: bool = True) -> jax.Array:
    """Flash-style attention in pure XLA: scan over KV blocks with a running
    (max, denom, acc) carry so the (sq, sk) score matrix never materializes.
    Used for long sequences (prefill_32k / train_4k) where materialized
    scores would blow VMEM/HBM."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nq, nk = sq // block_q, sk // block_k
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    scale = 1.0 / math.sqrt(dh)

    qb = q.reshape(b, nq, block_q, h, dh)

    def per_qblock(qi, qblk):
        # qblk: (b, block_q, h, dh)
        qpos = qi * block_q + jnp.arange(block_q)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(k, ki * block_k, block_k, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, ki * block_k, block_k, 1)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk) * scale
            s = s.astype(jnp.float32)
            if causal:
                kpos = ki * block_k + jnp.arange(block_k)
                s = jnp.where(kpos[None, None, None, :]
                              > qpos[None, None, :, None], -1e30, s)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, dh), jnp.float32)
        if causal:
            # only blocks ki <= (qi*block_q + block_q-1)//block_k contribute
            n_kv = jnp.minimum(
                (qi * block_q + block_q - 1) // block_k + 1, nk)
        else:
            n_kv = nk
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk), length=nk) \
            if not causal else _bounded_scan(kv_step, (m0, l0, a0), n_kv, nk)
        out = acc / l[..., None]
        return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (b, bq, h, dh)

    outs = jax.lax.map(lambda args: per_qblock(args[0], args[1]),
                       (jnp.arange(nq), qb.swapaxes(0, 1)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def blockwise_attention_skip(q, k, v, block_q: int = 512,
                             block_k: int = 1024) -> jax.Array:
    """Causal blockwise attention with STATIC upper-triangle skipping.

    Python loop over q blocks; each q block scans only its own causal prefix
    of kv blocks (static trip count), so no FLOPs are spent above the
    diagonal. ~2x fewer attention FLOPs than `blockwise_attention` for long
    sequences, at the cost of a larger (unrolled over q blocks) HLO.
    Enabled via ModelConfig.causal_skip — a §Perf hillclimb lever.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nq, nk = sq // block_q, sk // block_k
    assert sq % block_q == 0 and sk % block_k == 0
    scale = 1.0 / math.sqrt(dh)
    outs = []
    for qi in range(nq):
        qblk = jax.lax.slice_in_dim(q, qi * block_q, (qi + 1) * block_q, axis=1)
        qpos = qi * block_q + jnp.arange(block_q)
        hi = min((qi * block_q + block_q - 1) // block_k + 1, nk)

        def kv_step(carry, ki, qblk=qblk, qpos=qpos):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(k, ki * block_k, block_k, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, ki * block_k, block_k, 1)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk) * scale
            s = s.astype(jnp.float32)
            kpos = ki * block_k + jnp.arange(block_k)
            s = jnp.where(kpos[None, None, None, :]
                          > qpos[None, None, :, None], -1e30, s)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(q.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(hi), length=hi)
        outs.append((acc / l[..., None]).transpose(0, 2, 1, 3).astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def _bounded_scan(step, carry, n_dyn, n_max):
    """scan over range(n_max) but mask iterations >= n_dyn (causal skip)."""
    def wrapped(c, ki):
        new_c, _ = step(c, ki)
        take = ki < n_dyn
        c_out = jax.tree.map(
            lambda a, b_: jnp.where(take, a, b_), new_c, c)
        return c_out, None
    return jax.lax.scan(wrapped, carry, jnp.arange(n_max), length=n_max)


def attention(p, x, cfg, positions, *, mode: str = "train",
              cache: dict[str, jax.Array] | None = None,
              cache_index: jax.Array | None = None,
              dtype=jnp.bfloat16,
              rules=None) -> tuple[jax.Array, dict | None]:
    """GQA attention. mode: train | prefill | decode.

    decode: x is (b, 1, d); cache holds k/v (+ scales if int8) and is updated
    functionally at position `cache_index`.
    """
    q, k, v = _qkv(p, x.astype(dtype), cfg,
                   positions, dtype, rules)
    if mode == "decode":
        assert cache is not None and cache_index is not None
        cache = update_kv_cache(cache, k, v, cache_index)
        kf, vf = read_kv_cache(cache, dtype)
        kf = _repeat_kv(kf, cfg.n_heads)
        vf = _repeat_kv(vf, cfg.n_heads)
        out = full_attention(q, kf, vf, causal=False,
                             kv_valid_len=cache_index + 1)
    else:
        if mode == "prefill":
            assert cache is not None
            # write the whole prefix into the cache at offset 0
            cache = write_kv_prefix(cache, k, v)
        k = _repeat_kv(k, cfg.n_heads)
        v = _repeat_kv(v, cfg.n_heads)
        if x.shape[1] > cfg.attn_block_q and x.shape[1] % cfg.attn_block_q == 0:
            if cfg.causal_skip:
                out = blockwise_attention_skip(q, k, v, cfg.attn_block_q,
                                               cfg.attn_block_k)
            else:
                out = blockwise_attention(q, k, v, cfg.attn_block_q,
                                          cfg.attn_block_k)
        else:
            out = full_attention(q, k, v)
    wo = gather_weight(p["wo"].astype(dtype),
                       ("heads", "head_dim", "embed"), rules)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, cache

# ---------------------------------------------------------------------------
# KV cache (bf16 or int8 with per-token-head scales)
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, max_len: int, n_kv: int, d_head: int,
                  dtype=jnp.bfloat16, quantized: bool = False) -> dict:
    if quantized:
        return {
            "k": jnp.zeros((batch, max_len, n_kv, d_head), jnp.int8),
            "v": jnp.zeros((batch, max_len, n_kv, d_head), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, n_kv, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, n_kv, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, n_kv, d_head), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, d_head), dtype),
    }


def kv_cache_abstract(batch: int, max_len: int, n_kv: int, d_head: int,
                      dtype=jnp.bfloat16, quantized: bool = False) -> dict:
    c = init_kv_cache(1, 1, 1, 1, dtype, quantized)
    shapes = {
        "k": (batch, max_len, n_kv, d_head),
        "v": (batch, max_len, n_kv, d_head),
        "k_scale": (batch, max_len, n_kv, 1),
        "v_scale": (batch, max_len, n_kv, 1),
    }
    return {k: jax.ShapeDtypeStruct(shapes[k], v.dtype) for k, v in c.items()}


def _quantize_i8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def update_kv_cache(cache: dict, k_new: jax.Array, v_new: jax.Array,
                    index: jax.Array) -> dict:
    """Insert one token (b, 1, kv, dh) at position `index` (scalar shared
    by the batch, or (b,) per-slot — continuous batching writes each
    sequence at its own depth)."""
    out = dict(cache)
    index = jnp.asarray(index)

    def put(buf, val):
        val = val.astype(buf.dtype)
        if index.ndim == 0:
            return jax.lax.dynamic_update_slice_in_dim(buf, val, index, 1)
        b = buf.shape[0]
        return buf.at[jnp.arange(b), index].set(val[:, 0])

    if "k_scale" in cache:
        kq, ks = _quantize_i8(k_new)
        vq, vs = _quantize_i8(v_new)
        out["k"] = put(cache["k"], kq)
        out["v"] = put(cache["v"], vq)
        out["k_scale"] = put(cache["k_scale"], ks)
        out["v_scale"] = put(cache["v_scale"], vs)
    else:
        out["k"] = put(cache["k"], k_new)
        out["v"] = put(cache["v"], v_new)
    return out


def write_kv_prefix(cache: dict, k: jax.Array, v: jax.Array) -> dict:
    out = dict(cache)
    pl = k.shape[1]
    if "k_scale" in cache:
        kq, ks = _quantize_i8(k)
        vq, vs = _quantize_i8(v)
        out["k"] = cache["k"].at[:, :pl].set(kq)
        out["v"] = cache["v"].at[:, :pl].set(vq)
        out["k_scale"] = cache["k_scale"].at[:, :pl].set(ks)
        out["v_scale"] = cache["v_scale"].at[:, :pl].set(vs)
    else:
        out["k"] = cache["k"].at[:, :pl].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, :pl].set(v.astype(cache["v"].dtype))
    return out


def read_kv_cache(cache: dict, dtype=jnp.bfloat16):
    if "k_scale" in cache:
        k = cache["k"].astype(jnp.float32) * cache["k_scale"]
        v = cache["v"].astype(jnp.float32) * cache["v_scale"]
        return k.astype(dtype), v.astype(dtype)
    return cache["k"].astype(dtype), cache["v"].astype(dtype)

# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg) -> dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ff
    out_scale = 1.0 / math.sqrt(2 * max(cfg.n_layers, 1))
    if cfg.mlp_type == "swiglu":
        return {
            "wi": ParamSpec((d, f), ("embed", "ff"), init="fan_in"),
            "wg": ParamSpec((d, f), ("embed", "ff"), init="fan_in"),
            "wo": ParamSpec((f, d), ("ff", "embed"), init="fan_in",
                            scale=out_scale),
        }
    return {  # gelu
        "wi": ParamSpec((d, f), ("embed", "ff"), init="fan_in"),
        "bi": ParamSpec((f,), ("ff",), init="zeros"),
        "wo": ParamSpec((f, d), ("ff", "embed"), init="fan_in",
                        scale=out_scale),
        "bo": ParamSpec((d,), ("embed",), init="zeros"),
    }


def mlp(p, x, cfg, dtype=jnp.bfloat16, rules=None) -> jax.Array:
    x = x.astype(dtype)
    gw = lambda k, axes: gather_weight(p[k].astype(dtype), axes, rules)  # noqa: E731
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ gw("wg", ("embed", "ff"))) \
            * (x @ gw("wi", ("embed", "ff")))
        return h @ gw("wo", ("ff", "embed"))
    h = jax.nn.gelu(x @ gw("wi", ("embed", "ff")) + p["bi"].astype(dtype))
    return h @ gw("wo", ("ff", "embed")) + gw("bo", ("embed",))
