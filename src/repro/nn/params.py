"""ParamSpec pytrees: declare shapes + logical axes once, derive everything
(init values, abstract shapes for the dry-run, NamedShardings) from the spec.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.nn.sharding import LogicalRules, _resolve


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor."""
    shape: tuple[int, ...]
    logical_axes: tuple[str | None, ...]
    dtype: Any = jnp.float32
    init: str = "fan_in"          # fan_in | normal | zeros | ones | constant
    scale: float = 1.0            # stddev multiplier / constant value
    fan_axis: int = -2            # which axis is fan-in for "fan_in" init

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs axes {self.logical_axes}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.scale, spec.dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * spec.scale).astype(spec.dtype)
    if spec.init == "fan_in":
        fan = spec.shape[spec.fan_axis] if spec.shape else 1
        std = spec.scale / math.sqrt(max(fan, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * std).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs, key):
    """Initialize a pytree of ParamSpec into a pytree of arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct pytree — used by the dry-run, no allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        specs, is_leaf=_is_spec)


def specs_to_pspecs(specs, rules: LogicalRules, mesh_axis_names=None,
                    mesh: Mesh = None):
    """PartitionSpecs for a ParamSpec tree. Pass `mesh` to get size-aware
    resolution (drops mesh axes that don't divide the dim — required for
    pjit argument shardings)."""
    if mesh is not None:
        from repro.nn.sharding import resolve_sized
        return jax.tree.map(
            lambda s: resolve_sized(s.logical_axes, rules, mesh, s.shape),
            specs, is_leaf=_is_spec)
    return jax.tree.map(
        lambda s: _resolve(s.logical_axes, rules, mesh_axis_names),
        specs, is_leaf=_is_spec)


def specs_to_shardings(specs, rules: LogicalRules, mesh: Mesh):
    from repro.nn.sharding import resolve_sized
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, resolve_sized(s.logical_axes, rules, mesh, s.shape)),
        specs, is_leaf=_is_spec)


def stack_specs(specs, n: int, axis_name: str = "layer"):
    """Prepend a stacked-layer axis to every spec (for scan-over-layers)."""
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s,
            shape=(n,) + s.shape,
            logical_axes=(axis_name,) + s.logical_axes,
            fan_axis=s.fan_axis - 1 if s.fan_axis < 0 else s.fan_axis + 1,
        ),
        specs, is_leaf=_is_spec)


def cast_specs(specs, dtype):
    """Replace the dtype of floating-point specs (bf16 serving weights)."""
    import jax.numpy as _jnp

    def one(s):
        if _jnp.issubdtype(_jnp.dtype(s.dtype), _jnp.floating):
            return dataclasses.replace(s, dtype=dtype)
        return s

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(math.prod(s.shape) for s in leaves)


def param_bytes(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
               for s in leaves)
