from repro.optim.compression import (  # noqa: F401
    compress_decompress,
    error_feedback_compress,
)
from repro.optim.easgd import (  # noqa: F401
    EASGDState,
    easgd_init,
    easgd_sync,
    local_sgd_sync,
)
from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adagrad,
    adamw,
    clip_by_global_norm,
    sgd,
)
