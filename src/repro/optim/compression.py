"""Gradient compression for the slow (cross-pod) axis, with error feedback.

The multi-pod mesh reduces gradients over ICI links within a pod and the
data-center network between pods; compressing the inter-pod all-reduce to
bf16 (or int8) halves (quarters) the bytes on the slowest hop. Error
feedback (Seide et al.; Karimireddy et al. 2019) keeps the quantization
noise from biasing convergence: the residual of each step is added back
before the next compression.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_decompress(g: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """Straight-through quantize/dequantize of one gradient leaf."""
    if dtype == jnp.int8:
        scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
        return (q * scale).astype(g.dtype)
    return g.astype(dtype).astype(g.dtype)


def error_feedback_compress(grads: Any, residual: Any,
                            dtype=jnp.bfloat16) -> tuple[Any, Any]:
    """Returns (compressed_grads, new_residual). residual pytree mirrors
    grads (fp32)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        cq = compress_decompress(corrected, dtype)
        return cq.astype(g.dtype), corrected - cq.astype(jnp.float32)

    flat = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda t: t[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def init_residual(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_shape)
