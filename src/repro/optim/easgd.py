"""Elastic-Averaging SGD (Zhang et al. 2015) and local-SGD synchronization —
the paper's gradient-sync methods (section III-A.6), adapted to SPMD.

The paper's CPU fleet runs EASGD asynchronously between trainers and a
center dense PS, with HogWild threads inside a trainer. Lock-free async has
no TPU analogue (DESIGN.md section 7): here each *pod* is one EASGD trainer
(replica), replicas live as a leading `replica` axis sharded over the `pod`
mesh axis, and the elastic pull runs round-synchronously every tau steps:

    x_i <- x_i - alpha * (x_i - c)
    c   <- c + beta/R * sum_i (x_i - c)

which is exactly the EASGD update with a synchronous round schedule.
`local_sgd_sync` (alpha=1 limit with center == mean) gives ShadowSync-style
deferred full averaging. Both operate on stacked pytrees (leading dim R), so
they drop into pjit with P("pod") on the replica axis — cross-pod traffic
happens ONLY at sync steps, the paper's motivation for async methods.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EASGDState(NamedTuple):
    replicas: Any    # pytree, each leaf (R, ...) — per-pod trainer params
    center: Any      # pytree, each leaf (...)    — the center variable


def easgd_init(params, n_replicas: int) -> EASGDState:
    replicas = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_replicas,) + p.shape).copy(),
        params)
    return EASGDState(replicas=replicas, center=params)


def easgd_sync(state: EASGDState, alpha: float, beta: float) -> EASGDState:
    """One elastic-averaging round (runs every tau local steps)."""
    def pull(x, c):
        return x - alpha * (x - c[None].astype(x.dtype))

    def push(c, x):
        mean = jnp.mean(x.astype(jnp.float32), axis=0)
        return (c.astype(jnp.float32)
                + beta * (mean - c.astype(jnp.float32))).astype(c.dtype)

    new_replicas = jax.tree.map(pull, state.replicas, state.center)
    new_center = jax.tree.map(push, state.center, state.replicas)
    return EASGDState(new_replicas, new_center)


def local_sgd_sync(state: EASGDState) -> EASGDState:
    """ShadowSync/local-SGD limit: replicas collapse to their mean."""
    def avg(x):
        mean = jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype)
        return jnp.broadcast_to(mean[None], x.shape)

    new_replicas = jax.tree.map(avg, state.replicas)
    new_center = jax.tree.map(lambda x: x[0], new_replicas)
    return EASGDState(new_replicas, new_center)


def replica_step(state: EASGDState, grads_stacked, lr: float) -> EASGDState:
    """Per-replica SGD step; grads_stacked leaves are (R, ...)."""
    new_replicas = jax.tree.map(
        lambda x, g: x - lr * g.astype(x.dtype), state.replicas,
        grads_stacked)
    return EASGDState(new_replicas, state.center)
