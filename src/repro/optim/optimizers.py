"""Dense optimizers (the embedding path uses kernels/rowwise_adagrad).

Functional, optax-shaped but dependency-free:
  opt = adamw(lr=...); state = opt.init(params)
  new_params, new_state = opt.apply(params, grads, state, step)

The paper's production split (section IV, Fig. 4): MLP ("dense") parameters on
dense PSs with AdaGrad/SGD; embedding rows on sparse PSs with row-wise
AdaGrad. `adamw` is included for the LM-family archs.

Optimizer state mirrors the parameter pytree leaf-for-leaf, so parameter
PartitionSpecs apply verbatim to the state (ZeRO-style sharded optimizer
state falls out of fsdp param sharding for free).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    apply: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    name: str = "opt"


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, pre_clip_norm)."""
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def apply(params, grads, state, step):
        del step
        if momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                               params, grads)
            return new, state
        new_state = jax.tree.map(
            lambda m, g: momentum * m + g.astype(m.dtype), state, grads)
        new = jax.tree.map(lambda p, m: p - lr * m.astype(p.dtype),
                           params, new_state)
        return new, new_state

    return Optimizer(init, apply, "sgd")


def adagrad(lr: float, eps: float = 1e-8) -> Optimizer:
    """Dense AdaGrad — the paper's dense-PS optimizer."""
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(params, grads, state, step):
        del step
        new_state = jax.tree.map(
            lambda s, g: s + jnp.square(g.astype(jnp.float32)), state, grads)
        new = jax.tree.map(
            lambda p, g, s: (p.astype(jnp.float32)
                             - lr * g.astype(jnp.float32)
                             * jax.lax.rsqrt(s + eps)).astype(p.dtype),
            params, grads, new_state)
        return new, new_state

    return Optimizer(init, apply, "adagrad")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0,
          clip_norm: float | None = 1.0) -> Optimizer:
    """AdamW with decoupled weight decay and optional global-norm clipping,
    fp32 moments regardless of param dtype."""
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def apply(params, grads, state, step):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)
        new_m = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        new_v = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2)
            * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)

        def upd(p, m, v):
            u = (m / c1) * jax.lax.rsqrt(v / c2 + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new = jax.tree.map(upd, params, new_m, new_v)
        return new, {"m": new_m, "v": new_v}

    return Optimizer(init, apply, "adamw")
