"""Unified causal LM covering the 10 assigned architectures.

One parameterized decoder: GQA attention (RoPE neox/glm/none, optional QKV
bias, partial rotary), SwiGLU/GELU FF or top-k MoE, Mamba-2 SSD mixers, and
hybrid per-period layer patterns (Jamba). Layers are SCANNED over repeating
units (the smallest pattern period) with stacked params, keeping HLO size and
compile time flat in depth — essential for the 512-device dry-run.

Modality frontends are STUBS per the assignment: `vlm` consumes precomputed
patch embeddings, `audio` consumes precomputed EnCodec frame embeddings
(data/synthetic.py provides them; decode feeds back codebook embeddings).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.nn import layers as L
from repro.nn import mamba2 as M
from repro.nn import moe as MOE
from repro.nn.params import ParamSpec, stack_specs
from repro.nn.sharding import (TRAIN_RULES, LogicalRules, gather_weight,
                               shard_activation)

# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _block_specs(cfg: ModelConfig, kind: str, is_moe: bool) -> dict:
    specs: dict[str, Any] = {"ln1": L.norm_specs(cfg.d_model, cfg.norm_type)}
    if kind == "a":
        specs["attn"] = L.attention_specs(cfg)
    elif kind == "m":
        specs["mamba"] = M.mamba_specs(cfg)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        specs["ln2"] = L.norm_specs(cfg.d_model, cfg.norm_type)
        specs["ffn"] = MOE.moe_specs(cfg) if is_moe else L.mlp_specs(cfg)
    return specs


def _pattern_moe_flags(cfg: ModelConfig) -> tuple[bool, ...]:
    """MoE-ness per pattern position — must be unit-independent."""
    period = len(cfg.pattern)
    if cfg.n_experts > 0:
        assert period % cfg.moe_every == 0, (
            "pattern period must be a multiple of moe_every for scan layout")
    return tuple(cfg.is_moe_layer(i) for i in range(period))


def lm_param_specs(cfg: ModelConfig) -> dict:
    v, d = cfg.vocab_size, cfg.d_model
    p: dict[str, Any] = {"embed": {}}
    if cfg.frontend == "audio":
        p["embed"]["codebooks"] = ParamSpec(
            (cfg.n_codebooks, v, d), (None, "vocab", "embed"),
            init="normal", scale=0.02)
    else:
        p["embed"]["tok"] = ParamSpec((v, d), ("vocab", "embed"),
                                      init="normal", scale=0.02)
    flags = _pattern_moe_flags(cfg)
    p["blocks"] = {
        f"b{i}": stack_specs(_block_specs(cfg, kind, flags[i]), cfg.n_units)
        for i, kind in enumerate(cfg.pattern)}
    p["final_norm"] = L.norm_specs(cfg.d_model, cfg.norm_type)
    if not cfg.tie_embeddings:
        out_dim = v * cfg.n_codebooks if cfg.frontend == "audio" else v
        p["head"] = {"w": ParamSpec((d, out_dim), ("embed", "vocab"),
                                    init="fan_in")}
    return p

# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def embed_input(params: dict, batch: dict, cfg: ModelConfig,
                rules: LogicalRules,
                positions: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Returns (x (B, S, D), positions (B, S)). `positions` is supplied by
    the decode path (current cache index); defaults to arange(S)."""
    dtype = _dtype(cfg)
    if cfg.frontend != "audio":
        tok_table = gather_weight(params["embed"]["tok"],
                                  ("vocab", "embed"), rules)
    if cfg.frontend == "vision":
        tok = jnp.take(tok_table, batch["tokens"], axis=0)
        x = jnp.concatenate([batch["embeds"].astype(dtype),
                             tok.astype(dtype)], axis=1)
    elif cfg.frontend == "audio":
        x = batch["embeds"].astype(dtype)
    else:
        x = jnp.take(tok_table, batch["tokens"], axis=0).astype(dtype)
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.sinusoidal_pos:
        pos_emb = L.sinusoidal_positions(positions, cfg.d_model).astype(dtype)
        pos_emb = shard_activation(
            pos_emb, ("act_batch", "act_seq", "act_embed"), rules)
        x = x + pos_emb
    x = shard_activation(x, ("act_batch", "act_seq", "act_embed"), rules)
    return x, positions


def lm_logits(params: dict, x: jax.Array, cfg: ModelConfig,
              rules: LogicalRules) -> jax.Array:
    dtype = _dtype(cfg)
    x = L.apply_norm(params["final_norm"], x, cfg.norm_type, dtype=dtype,
                     rules=rules)
    if cfg.tie_embeddings:
        w = gather_weight(params["embed"]["tok"].astype(dtype),
                          ("vocab", "embed"), rules)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        w = gather_weight(params["head"]["w"].astype(dtype),
                          ("embed", "vocab"), rules)
        logits = x @ w
    if cfg.frontend == "audio":
        b, s, _ = logits.shape
        logits = logits.reshape(b, s, cfg.n_codebooks, cfg.vocab_size)
    return shard_activation(
        logits, ("act_batch", "act_seq", "act_vocab")
        if logits.ndim == 3 else ("act_batch", "act_seq", None, "act_vocab"),
        rules)

# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(bp: dict, x: jax.Array, cfg: ModelConfig, kind: str,
                 is_moe: bool, positions: jax.Array, mode: str,
                 cache: dict | None, cache_index, rules: LogicalRules):
    dtype = _dtype(cfg)
    h = L.apply_norm(bp["ln1"], x, cfg.norm_type, dtype=dtype, rules=rules)
    new_cache = cache
    if kind == "a":
        h, new_cache = L.attention(bp["attn"], h, cfg, positions, mode=mode,
                                   cache=cache, cache_index=cache_index,
                                   dtype=dtype, rules=rules)
    else:
        h, new_cache = M.mamba_block(bp["mamba"], h, cfg, mode=mode,
                                     cache=cache, dtype=dtype, rules=rules)
        if mode == "decode" and new_cache is None:
            new_cache = cache
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h = L.apply_norm(bp["ln2"], x, cfg.norm_type, dtype=dtype,
                         rules=rules)
        if is_moe:
            h, aux = MOE.moe(bp["ffn"], h, cfg, dtype=dtype, rules=rules)
        else:
            h = L.mlp(bp["ffn"], h, cfg, dtype=dtype, rules=rules)
        x = x + h
    x = shard_activation(x, ("act_batch", "act_seq", "act_embed"), rules)
    return x, new_cache, aux

# ---------------------------------------------------------------------------
# full forward (train / prefill / decode)
# ---------------------------------------------------------------------------


def lm_forward(params: dict, batch: dict, cfg: ModelConfig,
               mode: str = "train", caches: dict | None = None,
               cache_index: jax.Array | None = None,
               rules: LogicalRules = TRAIN_RULES
               ) -> tuple[jax.Array, jax.Array, dict | None]:
    """Returns (logits, aux_loss, new_caches)."""
    flags = _pattern_moe_flags(cfg)
    positions = None
    if mode == "decode":
        assert cache_index is not None
        b = next(iter(batch.values())).shape[0]
        ci = jnp.asarray(cache_index, jnp.int32)
        # scalar index: shared position; (b,) index: per-slot positions
        # (continuous batching)
        positions = jnp.broadcast_to(
            ci[None, None] if ci.ndim == 0 else ci[:, None], (b, 1))
    x, positions = embed_input(params, batch, cfg, rules, positions)

    def unit_body(carry, xs):
        x, aux = carry
        unit_params, unit_caches = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            cache_i = unit_caches.get(f"b{i}") if unit_caches else None
            x, nc, a = _apply_block(
                unit_params[f"b{i}"], x, cfg, kind, flags[i], positions,
                mode, cache_i, cache_index, rules)
            if nc is not None:
                new_caches[f"b{i}"] = nc
            aux = aux + a
        return (x, aux), (new_caches if new_caches else None)

    body = unit_body
    if mode == "train" and cfg.remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat == "dots" else None)
        body = jax.checkpoint(unit_body, policy=policy,
                              prevent_cse=False)

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)),
        (params["blocks"], caches), length=cfg.n_units)
    logits = lm_logits(params, x, cfg, rules)
    return logits, aux, new_caches


def lm_loss(params: dict, batch: dict, cfg: ModelConfig,
            rules: LogicalRules = TRAIN_RULES) -> tuple[jax.Array, dict]:
    logits, aux, _ = lm_forward(params, batch, cfg, "train", rules=rules)
    targets, mask = batch["targets"], batch["loss_mask"]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # one-hot contraction instead of take_along_axis: a gather over the
    # model-sharded vocab axis would force an all-gather of the logits;
    # the compare+select+reduce fuses and only the (B, S) partials cross
    # shards.
    v = lf.shape[-1]
    onehot = (targets[..., None]
              == jnp.arange(v, dtype=targets.dtype)).astype(lf.dtype)
    tgt = (lf * onehot).sum(axis=-1)
    nll = lse - tgt                                   # (B,S) or (B,S,K)
    if nll.ndim == 3:                                 # audio codebooks
        nll = nll.mean(axis=-1)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    total = ce + cfg.aux_loss_coef * aux
    return total, {"ce": ce, "aux": aux}

# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _unit_cache(cfg: ModelConfig, batch: int, max_len: int, abstract: bool):
    """Cache pytree for ONE unit (no leading n_units dim)."""
    quant = cfg.kv_cache_dtype == "int8"
    out = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "a":
            fn = L.kv_cache_abstract if abstract else L.init_kv_cache
            out[f"b{i}"] = fn(batch, max_len, cfg.n_kv_heads, cfg.d_head,
                              jnp.bfloat16, quant)
        else:
            fn = M.mamba_cache_abstract if abstract else M.init_mamba_cache
            out[f"b{i}"] = fn(batch, cfg)
    return out


def _stack_cache(unit_cache, n_units: int, abstract: bool):
    if abstract:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_units,) + s.shape, s.dtype),
            unit_cache)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_units,) + a.shape).copy(),
        unit_cache)


def init_caches(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return _stack_cache(_unit_cache(cfg, batch, max_len, False),
                        cfg.n_units, False)


def cache_abstract(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return _stack_cache(_unit_cache(cfg, batch, max_len, True),
                        cfg.n_units, True)


def cache_pspecs(cfg: ModelConfig, rules: LogicalRules, mesh,
                 batch: int, max_len: int):
    """PartitionSpec pytree matching init_caches/cache_abstract —
    size-aware (e.g. qwen's 40 kv heads can't shard 16-ways; the seq dim or
    nothing takes over per the rules)."""
    from repro.nn.sharding import resolve_sized

    abstract = cache_abstract(cfg, batch, max_len)
    kv_axes = ("layer", "act_batch", "cache_seq", "cache_kv", None)
    axes_tree = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "a":
            keys = ["k", "v"] + (["k_scale", "v_scale"]
                                 if cfg.kv_cache_dtype == "int8" else [])
            axes_tree[f"b{i}"] = {k: kv_axes for k in keys}
        else:
            axes_tree[f"b{i}"] = {
                "conv": ("layer", "act_batch", None, "conv_dim"),
                "ssm": ("layer", "act_batch", "ssm_heads", None, None),
            }
    return jax.tree.map(
        lambda axes, ab: resolve_sized(axes, rules, mesh, ab.shape),
        axes_tree, abstract,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------


def decode_step(params: dict, tokens: jax.Array, caches: dict,
                cache_index: jax.Array, cfg: ModelConfig,
                rules: LogicalRules) -> tuple[jax.Array, dict]:
    """One token for every sequence in the batch.

    tokens: (B, 1) int32 — or (B, 1, K) for audio codebooks.
    Returns (logits for the new position, updated caches)."""
    dtype = _dtype(cfg)
    if cfg.frontend == "audio":
        # sum the K codebook embeddings of the previous step's tokens
        emb = params["embed"]["codebooks"]           # (K, V, D)
        x = jnp.einsum("bskd->bsd", jnp.stack(
            [jnp.take(emb[k], tokens[..., k], axis=0)
             for k in range(cfg.n_codebooks)], axis=2)).astype(dtype)
        batch = {"embeds": x}
    elif cfg.frontend == "vision":
        batch = {"tokens": tokens, "embeds":
                 jnp.zeros((tokens.shape[0], 0, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": tokens}
    logits, _, new_caches = lm_forward(params, batch, cfg, "decode",
                                       caches, cache_index, rules)
    return logits[:, -1], new_caches


def prefill_step(params: dict, batch: dict, caches: dict, cfg: ModelConfig,
                 rules: LogicalRules) -> tuple[jax.Array, dict]:
    """Run the full prompt once, filling caches. Returns (last-position
    logits, caches)."""
    logits, _, new_caches = lm_forward(params, batch, cfg, "prefill",
                                       caches, None, rules)
    return logits[:, -1], new_caches
