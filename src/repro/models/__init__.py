from repro.models.lm import (  # noqa: F401
    cache_abstract,
    cache_pspecs,
    decode_step,
    init_caches,
    lm_forward,
    lm_loss,
    lm_param_specs,
    prefill_step,
)
