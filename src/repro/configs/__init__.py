"""Config registry: one module per assigned architecture + the paper's own
DLRM production models. `get_config(name)` returns the full-size config,
`get_smoke_config(name)` a reduced same-family config for CPU smoke tests.
"""
from repro.configs.base import (  # noqa: F401
    DLRMConfig,
    ModelConfig,
    Shape,
    DLRM_SHAPES,
    LM_SHAPES,
    shapes_for,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_NAMES,
    get_config,
    get_smoke_config,
    list_cells,
)
