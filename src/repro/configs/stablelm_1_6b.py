"""stablelm-1.6b [dense] — MHA, partial rotary 25%.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=5632, vocab_size=100352,
    mlp_type="swiglu", norm_type="layernorm", rotary_pct=0.25,
    rope_style="neox", tie_embeddings=False)
