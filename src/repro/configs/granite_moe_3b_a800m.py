"""granite-moe-3b-a800m [moe] — 40 experts top-8 per assignment.
[hf:ibm-granite; hf]

expert_pad=8: 40 experts do not divide the 16-way model axis; 8 never-routed
dummy experts pad the weight tables to 48 so expert-parallel sharding stays
even (GShard-style; routing semantics unchanged — DESIGN.md section 4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, d_ff=512, vocab_size=49155,
    n_experts=40, top_k=8, expert_pad=8, moe_every=1, mlp_type="swiglu",
    norm_type="rmsnorm", rope_style="neox", tie_embeddings=True)
