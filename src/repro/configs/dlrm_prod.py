"""The paper's production models M1/M2/M3 (Table II).

Hash sizes / lookup counts follow the paper's Fig. 6/7 power-law shapes:
per-table values drawn deterministically from a Pareto matched to the stated
means (5.7M / 7.3M / 3.7M hash entries; 28 / 17 / 49 mean lookups), clipped
to [30, 20M] as in Fig. 6. Embedding dim d = 64 (fixed d for all sparse
features, section III-A.1); truncation 32 (section V).
"""
from __future__ import annotations


from repro.configs.base import DLRMConfig


def _powerlaw(n: int, mean: float, lo: float, hi: float, alpha: float,
              seed: int) -> tuple[int, ...]:
    """Deterministic power-law sample rescaled to the requested mean."""
    import numpy as np
    rng = np.random.RandomState(seed)
    raw = rng.pareto(alpha, size=n) + 1.0
    raw = np.clip(raw / raw.mean() * mean, lo, hi)
    raw = np.clip(raw * (mean / raw.mean()), lo, hi)
    return tuple(int(round(v)) for v in raw)


def _dlrm(name: str, n_sparse: int, n_dense: int, hash_mean: float,
          lookups_mean: float, bottom: tuple[int, ...],
          top: tuple[int, ...], seed: int, notes: str) -> DLRMConfig:
    return DLRMConfig(
        name=name, n_dense_features=n_dense, n_sparse_features=n_sparse,
        embed_dim=64,
        hash_sizes=_powerlaw(n_sparse, hash_mean, 30, 2e7, 1.2, seed),
        mean_lookups=_powerlaw(n_sparse, lookups_mean, 1, 200, 1.5, seed + 1),
        truncation=32,
        bottom_mlp=bottom + (64,), top_mlp=top + (1,),
        interaction="dot", notes=notes)


DLRMS: dict[str, DLRMConfig] = {
    # Table II: 30 sparse / 800 dense, EMB tens of GB, 28 mean lookups
    "dlrm-m1": _dlrm("dlrm-m1", 30, 800, 5.7e6, 28, (512,),
                     (512, 512, 512), 11, "M1_prod (Table II)"),
    "dlrm-m2": _dlrm("dlrm-m2", 13, 504, 7.3e6, 17, (1024,),
                     (1024, 1024, 512), 22, "M2_prod (Table II)"),
    "dlrm-m3": _dlrm("dlrm-m3", 127, 809, 3.7e6, 49, (512,),
                     (512, 256, 512, 256, 512), 33,
                     "M3_prod (Table II) — embedding-dominant"),
}
