"""Config dataclasses for the LM family, DLRM, and the shape registry."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers the whole assigned LM family (dense / ssm / moe /
    vlm / audio / hybrid). Unused knobs stay at their neutral defaults."""
    name: str
    family: str                      # dense|ssm|moe|vlm|audio|hybrid
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_style: str = "neox"         # neox|glm|none
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    sinusoidal_pos: bool = False     # musicgen-style absolute positions
    attn_block_q: int = 512
    attn_block_k: int = 1024
    causal_skip: bool = False        # static causal block skipping (§Perf)
    # mlp
    d_ff: int = 0
    mlp_type: str = "swiglu"         # swiglu|gelu
    norm_type: str = "rmsnorm"       # rmsnorm|layernorm
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # apply MoE on layers where i % moe_every == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    expert_pad: int = 0              # dummy experts so e divides the TP axis
    moe_groups: int = 1              # GShard dispatch groups (= DP shards)
    # mamba / ssd
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    # hybrid layout: per-layer kind over one repeating period ("a"/"m")
    layer_pattern: tuple[str, ...] | None = None
    # embeddings / head
    tie_embeddings: bool = True
    # modality frontend stub: None | "vision" | "audio"
    frontend: str | None = None
    n_codebooks: int = 1             # musicgen: parallel codebook heads
    # numerics & memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    grad_reduce_dtype: str = "float32"  # bf16 halves grad reduce-scatter bytes
    remat: str = "full"              # none|dots|full
    kv_cache_dtype: str = "bfloat16"  # bfloat16|int8
    # distribution policy
    fsdp: bool = False               # shard weights over `data` too (ZeRO-3)
    notes: str = ""

    def __post_init__(self):
        if self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def pattern(self) -> tuple[str, ...]:
        """Per-period layer kinds; homogeneous models use a period of 1."""
        if self.layer_pattern is not None:
            return self.layer_pattern
        return ("m",) if self.family == "ssm" else ("a",)

    @property
    def n_units(self) -> int:
        period = len(self.pattern)
        assert self.n_layers % period == 0, (self.n_layers, period)
        return self.n_layers // period

    def is_moe_layer(self, global_idx: int) -> bool:
        if self.n_experts <= 0:
            return False
        return global_idx % self.moe_every == self.moe_offset

    def param_count_estimate(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        from repro.models.lm import lm_param_specs
        from repro.nn.params import param_count
        return param_count(lm_param_specs(self))

    def active_param_count_estimate(self) -> int:
        """FLOP-active params per token for MODEL_FLOPS = 6*N*D:
        input-embedding rows do no matmul FLOPs (excluded; the tied or untied
        LM head IS a matmul and stays); MoE counts only top_k experts."""
        total = self.param_count_estimate()
        if self.frontend == "audio":
            total -= self.n_codebooks * self.vocab_size * self.d_model
        else:
            total -= self.vocab_size * self.d_model  # input embedding
            if not self.tie_embeddings:
                pass  # head (vocab x d) still counted via its own weights
        if self.tie_embeddings and self.frontend != "audio":
            total += self.vocab_size * self.d_model  # tied head matmul
        if self.n_experts > 0:
            n_moe_layers = sum(self.is_moe_layer(i)
                               for i in range(self.n_layers))
            per_expert = 3 * self.d_model * self.d_ff
            total -= n_moe_layers * (self.n_experts
                                     - self.top_k) * per_expert
        return total


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    """The paper's model (Fig. 3 / Table II)."""
    name: str
    family: str = "dlrm"
    n_dense_features: int = 512
    n_sparse_features: int = 32
    embed_dim: int = 64                       # d in the paper
    hash_sizes: tuple[int, ...] = ()          # per-table; len == n_sparse
    mean_lookups: tuple[int, ...] = ()        # per-table pooling lengths
    truncation: int = 32                      # paper section V lookup cap
    bottom_mlp: tuple[int, ...] = (512, 256, 64)
    top_mlp: tuple[int, ...] = (512, 512, 256, 1)
    interaction: str = "dot"                  # dot|cat (paper section III-A.3)
    # numerics / placement
    param_dtype: str = "float32"
    compute_dtype: str = "float32"            # paper trains fp32
    placement: str = "auto"                   # auto|table_wise|row_wise|column_wise|replicated
    lookup_impl: str = "gather"               # gather (pjit) | psum (shard_map, PS-side pooling)
    grad_reduce_dtype: str = "float32"        # bf16 halves the gsum psum bytes
    hbm_budget_gb: float = 6.0                # per-chip EMB budget (16 GB chip
                                              # minus grads/dense/activations)
    notes: str = ""

    def __post_init__(self):
        assert len(self.hash_sizes) == self.n_sparse_features
        assert len(self.mean_lookups) == self.n_sparse_features

    def table_bytes(self) -> tuple[int, ...]:
        item = 4 if self.param_dtype == "float32" else 2
        return tuple(h * self.embed_dim * item for h in self.hash_sizes)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # train|prefill|decode|dlrm_train|dlrm_infer
    seq_len: int = 0
    global_batch: int = 0


LM_SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": Shape("prefill_32k", "prefill", seq_len=32768,
                         global_batch=32),
    "decode_32k": Shape("decode_32k", "decode", seq_len=32768,
                        global_batch=128),
    "long_500k": Shape("long_500k", "decode", seq_len=524288, global_batch=1),
}

DLRM_SHAPES: dict[str, Shape] = {
    "train_b64k": Shape("train_b64k", "dlrm_train", global_batch=65536),
    "infer_b8k": Shape("infer_b8k", "dlrm_infer", global_batch=8192),
}

#: archs with sub-quadratic sequence mixing get long_500k (DESIGN.md section 4)
SUBQUADRATIC = ("mamba2-780m", "jamba-v0.1-52b")


def shapes_for(arch: str) -> dict[str, Shape]:
    if arch.startswith("dlrm"):
        return dict(DLRM_SHAPES)
    out = dict(LM_SHAPES)
    if arch not in SUBQUADRATIC:
        del out["long_500k"]
    return out
