"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2 every
other layer. int8 KV + fsdp for the 52 B scale. [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig

_PATTERN = ("m", "m", "m", "a", "m", "m", "m", "m")  # attention 1:7

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    layer_pattern=_PATTERN, ssm_state=16, ssm_headdim=64,
    ssm_expand=2, ssm_conv=4, ssm_ngroups=1,
    mlp_type="swiglu", norm_type="rmsnorm", rope_style="none",
    tie_embeddings=False, fsdp=True, kv_cache_dtype="int8")
