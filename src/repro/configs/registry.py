"""Architecture registry: the 10 assigned archs (one module per arch under
repro/configs/) + the paper's own production DLRMs (Table II) in
repro/configs/dlrm_prod.py. Each entry also derives a REDUCED smoke config
of the same family for CPU tests."""
from __future__ import annotations

import dataclasses

from repro.configs import (chatglm3_6b, granite_moe_1b_a400m,
                           granite_moe_3b_a800m, internvl2_26b,
                           jamba_v0_1_52b, mamba2_780m, musicgen_large,
                           qwen1_5_32b, stablelm_1_6b, starcoder2_3b)
from repro.configs.base import DLRMConfig, ModelConfig, Shape, shapes_for
from repro.configs.dlrm_prod import DLRMS

_ARCH_MODULES = (
    starcoder2_3b, stablelm_1_6b, qwen1_5_32b, chatglm3_6b, mamba2_780m,
    granite_moe_1b_a400m, granite_moe_3b_a800m, internvl2_26b,
    musicgen_large, jamba_v0_1_52b,
)

ARCHS: dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG
                                 for m in _ARCH_MODULES}

ARCH_NAMES: list[str] = list(ARCHS) + list(DLRMS)

# ---------------------------------------------------------------------------
# Reduced smoke configs: same family, tiny dims.
# ---------------------------------------------------------------------------


def _smoke(cfg: ModelConfig) -> ModelConfig:
    period = len(cfg.pattern)
    heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    kv = min(cfg.n_kv_heads, heads) if cfg.n_kv_heads else 0
    if kv and heads % kv:
        kv = 1
    return dataclasses.replace(
        cfg,
        n_layers=2 * period,
        d_model=64,
        n_heads=heads, n_kv_heads=kv, d_head=16 if heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        attn_block_q=16, attn_block_k=16,
        remat="none", fsdp=False,
    )


def _smoke_dlrm(cfg: DLRMConfig) -> DLRMConfig:
    n = min(cfg.n_sparse_features, 6)
    return dataclasses.replace(
        cfg,
        n_dense_features=32, n_sparse_features=n,
        embed_dim=16,
        hash_sizes=tuple([101, 211, 331, 97, 53, 1009][:n]),
        mean_lookups=tuple([3, 5, 2, 8, 1, 4][:n]),
        truncation=8,
        bottom_mlp=(32, 16), top_mlp=(32, 16, 1),
        hbm_budget_gb=0.001,
    )


def get_config(name: str):
    if name in ARCHS:
        return ARCHS[name]
    if name in DLRMS:
        return DLRMS[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")


def get_smoke_config(name: str):
    cfg = get_config(name)
    return _smoke_dlrm(cfg) if isinstance(cfg, DLRMConfig) else _smoke(cfg)


def list_cells(include_dlrm: bool = True) -> list[tuple[str, Shape]]:
    """Every (arch x shape) dry-run cell."""
    cells = []
    for name in ARCHS:
        for shape in shapes_for(name).values():
            cells.append((name, shape))
    if include_dlrm:
        for name in DLRMS:
            for shape in shapes_for(name).values():
                cells.append((name, shape))
    return cells
