"""mamba2-780m [ssm] — SSD, attention-free, state=128. [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    vocab_size=50280, d_ff=0, ssm_state=128, ssm_headdim=64,
    ssm_expand=2, ssm_conv=4, ssm_ngroups=1, rope_style="none",
    norm_type="rmsnorm", tie_embeddings=True)
