"""qwen1.5-32b [dense] — QKV bias, assigned kv=40 (MHA). [hf:Qwen; hf]

int8 KV cache: the 32k x 128 decode cache is 5.5 TB in bf16 (21.5 GB/chip on
256 chips — over the 16 GB v5e HBM); int8 + per-token-head scales halves it.
fsdp=True: 32 B params -> optimizer state must shard over `data` too."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=27392, vocab_size=152064,
    mlp_type="swiglu", norm_type="rmsnorm", qkv_bias=True,
    rope_style="neox", tie_embeddings=False, fsdp=True,
    kv_cache_dtype="int8")
