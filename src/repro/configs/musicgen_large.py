"""musicgen-large [audio] — decoder-only over EnCodec tokens, 4 parallel
codebooks; EnCodec frontend STUBBED (input_specs supplies frame embeddings).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048,
    mlp_type="gelu", norm_type="layernorm", rope_style="none",
    sinusoidal_pos=True, frontend="audio", n_codebooks=4,
    tie_embeddings=False)
