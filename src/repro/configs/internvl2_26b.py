"""internvl2-26b [vlm] — InternViT frontend STUBBED (input_specs supplies
patch embeddings); InternLM2-20B-style backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab_size=92553,
    mlp_type="swiglu", norm_type="rmsnorm", rope_style="neox",
    frontend="vision", tie_embeddings=False, fsdp=True)
