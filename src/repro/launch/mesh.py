"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 placeholder devices before importing jax).

Mesh semantics (DESIGN.md section 5): `data` = the paper's trainer axis,
`model` = the paper's sparse-parameter-server axis, `pod` = pod-level data
parallelism (and the EASGD replica axis).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh for CPU integration tests (requires
    xla_force_host_platform_device_count >= prod(shape))."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_hosts: int, axis: str = "data") -> jax.sharding.Mesh:
    """1-D mesh over the data-parallel hosts of the multi-host cached tier
    (core/cache.py): the capacity tier row-shards over this axis and the
    routed sparse update shard_maps over it (train/steps.py
    build_cached_train_step's multi-host dispatch)."""
    return jax.make_mesh((n_hosts,), (axis,))
