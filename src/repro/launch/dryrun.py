import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production mesh, prove it fits, and extract the roofline
terms from the compiled artifact.

MUST be executed as its own process (`python -m repro.launch.dryrun ...`):
the XLA_FLAGS line above runs before any other import — jax locks the device
count on first init. Never import this module from tests.

Per cell this emits <out>/<arch>__<shape>__<mesh>.json with:
  flops / vpu_flops / major_bytes (global, loop-trip-corrected StableHLO)
  collectives by type (per-chip bytes, post-SPMD HLO, loop-trip-corrected)
  memory_analysis (per-device arg/output/temp bytes — the "fits" proof)
  roofline terms in seconds + the dominant term
  MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), N = active params
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (DLRM_SHAPES, LM_SHAPES, get_config,  # noqa: E402
                           shapes_for)
from repro.configs.base import DLRMConfig, Shape  # noqa: E402
from repro.configs.registry import ARCHS, DLRMS  # noqa: E402
from repro.core.embedding import EmbeddingBagCollection  # noqa: E402
from repro.data.synthetic import dlrm_batch_specs, lm_batch_specs  # noqa: E402
from repro.launch.analysis import (CollectiveAnalysis,  # noqa: E402
                                   StableHloAnalysis)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (HW, roofline_terms)  # noqa: E402
from repro.models.lm import (cache_abstract, cache_pspecs,  # noqa: E402
                             decode_step, lm_param_specs, prefill_step)
from repro.nn.params import (abstract_params, param_count,  # noqa: E402
                             specs_to_pspecs)
from repro.nn.sharding import (FSDP_RULES, LONG_SERVE_RULES,  # noqa: E402
                               SERVE_RULES, TRAIN_RULES, _resolve)
from repro.optim.optimizers import adagrad, adamw  # noqa: E402
from repro.train.steps import (build_dlrm_train_step,  # noqa: E402
                               build_lm_train_step, dlrm_init_state)

# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def _rules_for(cfg, shape: Shape, overrides: dict | None = None):
    if shape.kind in ("dlrm_train", "dlrm_infer"):
        rules = dict(TRAIN_RULES)        # DLRM: paper-faithful DP+PS mapping
    elif shape.kind == "train":
        # FSDP + sequence parallelism is the fit-first default for every LM
        # arch (replicated fp32 grads alone exceed 16 GB/chip at >= 1.6B)
        rules = dict(FSDP_RULES)
    elif shape.name.startswith("long"):
        rules = dict(LONG_SERVE_RULES)
    else:
        rules = dict(SERVE_RULES)
    if overrides:
        rules.update(overrides)
    return rules


def _named(mesh, pspec_tree):
    return jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(mesh, rules, batch_specs):
    from repro.nn.sharding import resolve_sized

    def one(s):
        sp = resolve_sized(("batch",) + (None,) * (len(s.shape) - 1), rules,
                           mesh, s.shape)
        return NamedSharding(mesh, sp)
    return jax.tree.map(one, batch_specs)


def build_cell(arch: str, shape: Shape, mesh,
               rules_overrides: dict | None = None,
               config_overrides: dict | None = None):
    """Returns (fn, args_abstract, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch)
    if config_overrides:
        cfg = dataclasses.replace(cfg, **config_overrides)
    rules = _rules_for(cfg, shape, rules_overrides)

    if isinstance(cfg, DLRMConfig):
        return _build_dlrm_cell(cfg, shape, mesh, rules)
    return _build_lm_cell(cfg, shape, mesh, rules)


def _dp_size(mesh, rules) -> int:
    """Effective data-parallel degree = product of mesh axes carrying the
    batch dim (zero_dp maps batch over model too)."""
    axes = rules.get("batch") or ("pod", "data")
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape.get(a, 1)
    return out


def _auto_accum(cfg, shape: Shape, mesh, rules) -> int:
    """Gradient-accumulation factor so saved activations + the CE region fit
    the per-chip HBM budget (the paper's section V-B batch-size lever used
    as a memory knob).

    saves  = tokens_per_datashard x d x 2B x n_layers   (scan carry, bf16)
    ce     = tokens_per_datashard x vocab/TP x 12B      (logits fp32 region)
    """
    dp = _dp_size(mesh, rules)
    tp = mesh.shape.get("model", 1) if "model" not in (
        rules.get("batch") or ()) else 1
    tokens = shape.global_batch * shape.seq_len / dp
    saves = tokens * cfg.d_model * 2 * cfg.n_layers
    if cfg.family == "ssm" or cfg.layer_pattern:
        saves *= 2.2                       # conv/ssd intermediates
    if cfg.n_experts:
        # dispatch tables + (g, e, cap, d) tiles + their backward
        saves += tokens * cfg.d_model * cfg.top_k * cfg.capacity_factor * 10
    vocab_eff = cfg.vocab_size * (cfg.n_codebooks
                                  if cfg.frontend == "audio" else 1)
    ce = tokens * (vocab_eff / tp) * 12
    if cfg.frontend == "audio":
        ce += tokens * cfg.d_model * 8     # fp32 frame-embedding inputs
    budget = 6e9
    accum = 1
    max_accum = max(1, shape.global_batch // dp)
    while (saves + ce) / accum > budget and accum < max_accum:
        accum *= 2
    return min(accum, max_accum)


def _sharded_gb(specs, pspecs, mesh) -> float:
    """Analytic per-chip GB of a ParamSpec tree under its PartitionSpecs."""
    import math as _m
    is_spec = lambda x: hasattr(x, "logical_axes")  # noqa: E731
    total = 0.0
    for s, sp in zip(jax.tree.leaves(specs, is_leaf=is_spec),
                     jax.tree.leaves(pspecs,
                                     is_leaf=lambda x: isinstance(x, P))):
        shards = 1
        for e in sp:
            for a in (e if isinstance(e, tuple) else ((e,) if e else ())):
                shards *= mesh.shape[a]
        total += _m.prod(s.shape) * jnp.dtype(s.dtype).itemsize / shards
    return total / 1e9


def _hbm_estimate_lm(cfg, shape, mesh, specs, pspecs, accum) -> float:
    """Analytic per-chip HBM (GB): params (+grads/opt for train) + saved
    activations + CE region + caches. The CPU-backend memory_analysis
    OVERSTATES bf16 programs ~2-3x (f32-upcast temp copies — evidence in
    EXPERIMENTS.md section Dry-run); this is the TPU-native estimate."""
    dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    tp = mesh.shape.get("model", 1)
    p_gb = _sharded_gb(specs, pspecs, mesh)
    gb = p_gb
    if shape.kind == "train":
        gb += 3 * p_gb                       # grads + adam m,v (fp32 = p)
        tokens = shape.global_batch * shape.seq_len / dp / max(accum, 1)
        ssd = 2.2 if (cfg.family == "ssm" or cfg.layer_pattern) else 1.0
        gb += tokens * cfg.d_model * 2 * cfg.n_layers * ssd / 1e9
        vocab_eff = cfg.vocab_size * (cfg.n_codebooks
                                      if cfg.frontend == "audio" else 1)
        gb += tokens * (vocab_eff / tp) * 12 / 1e9
        if cfg.n_experts:
            gb += tokens * cfg.d_model * cfg.top_k * 6 / 1e9
    else:
        import math as _m
        caches = cache_abstract(cfg, shape.global_batch, shape.seq_len)
        cache_bytes = sum(_m.prod(c.shape) * jnp.dtype(c.dtype).itemsize
                          for c in jax.tree.leaves(caches))
        gb += cache_bytes / (dp * tp) / 1e9  # batch x (kv|seq) sharded
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len / dp
            gb += tokens * cfg.d_model * 2 * 4 / 1e9   # transient acts
    return gb


def _build_lm_cell(cfg, shape: Shape, mesh, rules):
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    if cfg.n_experts > 0 and shape.kind != "decode":
        # GShard grouped dispatch: one group per data shard
        dpe = _dp_size(mesh, rules) if shape.kind == "train" else dp
        tokens = shape.global_batch * max(shape.seq_len, 1)
        g = dpe if tokens % dpe == 0 else 1
        cfg = dataclasses.replace(cfg, moe_groups=g)
    if shape.kind in ("prefill", "decode") and cfg.n_kv_heads % tp != 0:
        # kv heads can't shard over the TP axis -> shard the cache seq dim
        # instead (flash-decoding layout)
        rules = dict(rules, cache_seq="model", cache_kv=None)
    if shape.kind == "prefill":
        # prefill: dh-fallback would all-reduce 32k-seq score matrices
        # (measured 70x worse); store weights FSDP-sharded over `data` and
        # gather per layer instead (bf16 weight all-gather ~0.25s/pass).
        rules = dict(rules)
        rules.pop("_fallback", None)
        rules.update(embed=("data",), _gather_weights=True)
    specs = lm_param_specs(cfg)
    if shape.kind in ("prefill", "decode"):
        # serving holds bf16 weights (no optimizer master copies)
        from repro.nn.params import cast_specs
        specs = cast_specs(specs, jnp.bfloat16)
    params_abs = abstract_params(specs)
    pspecs = specs_to_pspecs(specs, rules, mesh=mesh)
    params_sh = _named(mesh, pspecs)
    n_params = param_count(specs)
    n_active = cfg.active_param_count_estimate()
    accum0 = _auto_accum(cfg, shape, mesh, rules) if shape.kind == "train" \
        else 1
    extra: dict[str, Any] = {
        "hbm_estimate_gb": round(
            _hbm_estimate_lm(cfg, shape, mesh, specs, pspecs, accum0), 2)}

    if shape.kind == "train":
        opt = adamw(3e-4, weight_decay=0.1)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = {"m": params_sh, "v": params_sh}
        batch_abs = lm_batch_specs(cfg, shape.global_batch, shape.seq_len)
        batch_sh = _batch_shardings(mesh, rules, batch_abs)
        idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
        rep = NamedSharding(mesh, P())
        accum = accum0
        step = build_lm_train_step(cfg, opt, rules, accum_steps=accum,
                                   grad_dtype=cfg.grad_reduce_dtype)
        fn = jax.jit(step,
                     in_shardings=(params_sh, opt_sh, batch_sh, rep),
                     out_shardings=(params_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        args = (params_abs, opt_abs, batch_abs, idx_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
        extra["accum_steps"] = accum
    elif shape.kind == "prefill":
        caches_abs = cache_abstract(cfg, shape.global_batch, shape.seq_len)
        caches_sh = _named(mesh, cache_pspecs(cfg, rules, mesh,
                                              shape.global_batch,
                                              shape.seq_len))
        batch_abs = lm_batch_specs(cfg, shape.global_batch, shape.seq_len)
        for k in ("targets", "loss_mask"):
            batch_abs.pop(k, None)
        batch_sh = _batch_shardings(mesh, rules, batch_abs)
        fn = jax.jit(
            lambda p, b, c: prefill_step(p, b, c, cfg, rules),
            in_shardings=(params_sh, batch_sh, caches_sh),
            out_shardings=(None, caches_sh),
            donate_argnums=(2,))
        args = (params_abs, batch_abs, caches_abs)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        caches_abs = cache_abstract(cfg, shape.global_batch, shape.seq_len)
        caches_sh = _named(mesh, cache_pspecs(cfg, rules, mesh,
                                              shape.global_batch,
                                              shape.seq_len))
        if cfg.frontend == "audio":
            tok_abs = jax.ShapeDtypeStruct(
                (shape.global_batch, 1, cfg.n_codebooks), jnp.int32)
        else:
            tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1),
                                           jnp.int32)
        tok_sh = _batch_shardings(mesh, rules, tok_abs)
        idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            lambda p, t, c, i: decode_step(p, t, c, i, cfg, rules),
            in_shardings=(params_sh, tok_sh, caches_sh, NamedSharding(
                mesh, P())),
            out_shardings=(None, caches_sh),
            donate_argnums=(2,))
        args = (params_abs, tok_abs, caches_abs, idx_abs)
        tokens = shape.global_batch            # one token per sequence
        model_flops = 2.0 * n_active * tokens
    return fn, args, {"model_flops": model_flops, "params": n_params,
                      "active_params": n_active, "cfg": cfg, **extra}


def _build_dlrm_cell(cfg: DLRMConfig, shape: Shape, mesh, rules):
    n_shards = mesh.shape.get("model", 1)
    dp = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    ebc = EmbeddingBagCollection.build(cfg, n_shards, second_axis_size=dp)
    from repro.core.dlrm import dlrm_forward, dlrm_param_specs
    specs = dlrm_param_specs(cfg, ebc)
    params_abs = abstract_params(specs)
    pspecs = specs_to_pspecs(specs, rules, mesh=mesh)
    pspecs["emb"]["mega"] = ebc.plan.pspec     # planner overrides rules
    params_sh = _named(mesh, pspecs)
    import math
    dense_params = sum(
        math.prod(s.shape) for s in jax.tree.leaves(
            {"bottom": specs["bottom"], "top": specs["top"]},
            is_leaf=lambda x: hasattr(x, "logical_axes")))

    if shape.kind == "dlrm_train":
        opt = adagrad(0.01)
        step = build_dlrm_train_step(cfg, ebc, opt, rules=rules)
        state_abs = jax.eval_shape(
            lambda p: dlrm_init_state(ebc, opt, p), params_abs)
        state_sh = {
            "dense": {"bottom": pspecs["bottom"], "top": pspecs["top"]},
            "accum": P(*ebc.plan.pspec[:1]),
        }
        state_sh = _named(mesh, state_sh)
        batch_abs = dlrm_batch_specs(cfg, shape.global_batch)
        batch_sh = _batch_shardings(mesh, rules, batch_abs)
        idx_abs = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(step,
                     in_shardings=(params_sh, state_sh, batch_sh,
                                   NamedSharding(mesh, P())),
                     out_shardings=(params_sh, state_sh, None),
                     donate_argnums=(0, 1))
        args = (params_abs, state_abs, batch_abs, idx_abs)
        model_flops = 6.0 * dense_params * shape.global_batch
    else:  # dlrm_infer
        batch_abs = dlrm_batch_specs(cfg, shape.global_batch)
        batch_sh = _batch_shardings(mesh, rules, batch_abs)
        fn = jax.jit(
            lambda p, b: dlrm_forward(p, b, cfg, ebc, rules=rules),
            in_shardings=(params_sh, batch_sh), out_shardings=None)
        args = (params_abs, batch_abs)
        model_flops = 2.0 * dense_params * shape.global_batch
    lookup_bytes = (shape.global_batch * ebc.lookups_per_example()
                    * cfg.embed_dim * 4)
    # analytic per-chip HBM: table + gradient-aggregation copy + accумulator
    # + dense stack (params/grads/adagrad) + batch transients
    table_gb = max(ebc.plan.bytes_per_shard) / 1e9
    est = (2 * table_gb                        # mega + gsum aggregation
           + table_gb / cfg.embed_dim         # rowwise accum (1 fp32/row)
           + dense_params * 12 / 1e9          # p + grad + adagrad accum
           + shape.global_batch / dp * cfg.n_sparse_features
           * (cfg.truncation * 4 + cfg.embed_dim * 8) / 1e9)
    return fn, args, {"hbm_estimate_gb": round(est, 2),
                      "model_flops": model_flops,
                      "params": param_count(specs),
                      "active_params": param_count(specs), "cfg": cfg,
                      "placement": ebc.plan.strategy,
                      "lookup_bytes": lookup_bytes,
                      "load_imbalance": ebc.plan.load_imbalance}

# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: Shape, multi_pod: bool,
             rules_overrides=None, config_overrides=None,
             skip_collectives: bool = False) -> dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(mesh.devices.flatten())
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
        "ok": False,
    }
    t0 = time.time()
    try:
        # the mesh context makes with_sharding_constraint (shard_activation /
        # gather_weight) resolve logical axes — without it every activation
        # constraint silently no-ops and GSPMD guesses.
        with mesh:
            fn, args, meta = build_cell(arch, shape, mesh, rules_overrides,
                                        config_overrides)
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            sa = StableHloAnalysis(lowered.as_text())
            cost = sa.cost()
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
        }
        from repro.compat import cost_analysis_dict
        xla_cost = cost_analysis_dict(compiled)
        rec["xla_flops_uncorrected"] = xla_cost.get("flops", -1.0)
        if skip_collectives:
            coll_by_type, coll_total = {}, 0.0
        else:
            ca = CollectiveAnalysis(compiled.as_text())
            coll_by_type, coll_total = ca.by_type, ca.total_bytes
            rec["collective_warnings"] = ca.warnings[:5]
            rec["per_chip_dot_flops"] = ca.dot_flops
            rec["compute_s_per_chip"] = ca.dot_flops / HW.peak_flops_bf16
            top = sorted(ca.op_log, key=lambda t: -t[1] * t[2])[:8]
            rec["top_collectives"] = [
                {"op": o, "bytes_per_call": b, "mult": m} for o, b, m in top]
        rec.update({
            "flops": cost.mxu_flops,
            "vpu_flops": cost.vpu_flops,
            "major_bytes": cost.major_bytes,
            "gather_bytes": cost.gather_bytes,
            "scatter_bytes": cost.scatter_bytes,
            "collectives_per_chip": coll_by_type,
            "collective_bytes_per_chip": coll_total,
            "model_flops": meta["model_flops"],
            "params": meta["params"],
            "active_params": meta["active_params"],
            "stablehlo_warnings": sa.warnings[:5],
        })
        for k in ("placement", "lookup_bytes", "load_imbalance",
                  "accum_steps", "hbm_estimate_gb"):
            if k in meta:
                rec[k] = meta[k]
        rec.update(roofline_terms(
            flops=cost.mxu_flops, bytes_hbm=cost.major_bytes,
            collective_bytes_per_chip=coll_total, chips=n_chips,
            model_flops=meta["model_flops"]))
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec

# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all' / 'lm' / 'dlrm'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--skip-collectives", action="store_true",
                    help="skip post-SPMD HLO parse (faster)")
    ap.add_argument("--force", action="store_true",
                    help="rerun cells that already have a result file")
    args = ap.parse_args()

    if args.arch == "all":
        archs = list(ARCHS) + list(DLRMS)
    elif args.arch == "lm":
        archs = list(ARCHS)
    elif args.arch == "dlrm":
        archs = list(DLRMS)
    else:
        archs = args.arch.split(",")

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        shapes = shapes_for(arch)
        names = (list(shapes) if args.shape == "all"
                 else [s for s in args.shape.split(",") if s in shapes])
        for sname in names:
            for multi in meshes:
                tag = f"{arch}__{sname}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip] {tag}")
                    continue
                print(f"[run ] {tag}", flush=True)
                rec = run_cell(arch, shapes[sname], multi,
                               skip_collectives=args.skip_collectives)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = "OK" if rec["ok"] else "FAIL " + rec.get("error", "")
                print(f"[done] {tag}: {status} ({rec['total_s']}s)",
                      flush=True)


if __name__ == "__main__":
    main()
