"""Roofline model for the target hardware (TPU v5e-class chip).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = per-chip collective bytes / link_bw
                    (equivalently global collective bytes / (chips x link_bw))

FLOPs/bytes are GLOBAL (from pre-partition StableHLO, loop-corrected);
collective bytes are PER-CHIP (from post-SPMD HLO). The dominant term is the
step-time lower bound the perf loop iterates on; roofline_fraction =
model_flops / (dominant_s x chips x peak) is "useful-FLOP utilization at the
bound" (an MFU upper bound estimate).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HWSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link
    hbm_bytes: float = 16e9              # capacity per chip


HW = HWSpec()


def roofline_terms(flops: float, bytes_hbm: float,
                   collective_bytes_per_chip: float, chips: int,
                   model_flops: float, hw: HWSpec = HW) -> dict:
    compute_s = flops / (chips * hw.peak_flops_bf16)
    memory_s = bytes_hbm / (chips * hw.hbm_bw)
    collective_s = collective_bytes_per_chip / hw.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    util = (model_flops / (bound_s * chips * hw.peak_flops_bf16)
            if bound_s > 0 else 0.0)
    return {
        **terms,
        "dominant": dominant,
        "bound_s": bound_s,
        "model_flops_ratio": (model_flops / flops) if flops else 0.0,
        "roofline_fraction": util,
    }
