import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Performance-iteration harness (section Perf): re-lower a dry-run cell under a
named VARIANT (sharding rules / config change), re-analyse, and append the
(hypothesis, before, after) record to runs/perf/<cell>__<variant>.json.

Each variant below documents its napkin-math hypothesis; EXPERIMENTS.md
section Perf narrates confirmed/refuted.

    python -m repro.launch.perf --cell qwen1.5-32b/train_4k --variant zero_dp
    python -m repro.launch.perf --cell qwen1.5-32b/train_4k --all
"""
import argparse   # noqa: E402
import json       # noqa: E402
from typing import Any  # noqa: E402

from repro.configs import shapes_for  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402
from repro.nn.sharding import ZERO_DP_RULES  # noqa: E402

# variant = {"rules": overrides-or-table, "config": config overrides,
#            "hypothesis": one-liner}
VARIANTS: dict[str, dict[str, dict[str, Any]]] = {
    "qwen1.5-32b/train_4k": {
        "baseline": {"hypothesis": "paper-faithful DP(trainer) x TP(PS) "
                     "mapping; expect TP activation all-reduces + FSDP "
                     "gathers to dominate"},
        "causal_skip": {
            "config": {"causal_skip": True},
            "hypothesis": "static causal block skipping removes the "
            "masked upper-triangle attention work: ~2x fewer attention "
            "FLOPs (~8% of total at 4k) and the matching slice traffic"},
        "head_pad48": {
            "config": {"n_heads": 48, "n_kv_heads": 48, "d_head": 128},
            "hypothesis": "40 heads don't divide TP=16 so attention runs "
            "replicated on every model shard (per-chip dot FLOPs >> "
            "global/256); padding to 48 heads shards it 16-ways: per-chip "
            "attention compute drops ~13x at +20% attention params"},
        "zero_dp": {
            "rules": ZERO_DP_RULES,
            "hypothesis": "drop TP entirely: batch over all 256 chips "
            "kills the ~2 GB/layer TP activation all-reduces; only bf16 "
            "weight gathers (3 x 64 GB x 15/16 per step) remain -> "
            "collective term ~4 s -> ~1.2 s"},
        "zero_dp_skip": {
            "rules": ZERO_DP_RULES,
            "config": {"causal_skip": True},
            "hypothesis": "compose the two wins"},
        "zero_dp_skip_bf16grad": {
            "rules": ZERO_DP_RULES,
            "config": {"causal_skip": True,
                       "grad_reduce_dtype": "bfloat16"},
            "hypothesis": "fp32 grad reduce-scatter moves 2 x 128 GB "
            "x 255/256 per step (~5.1 s of the remaining 11.1 s "
            "collective bound); bf16 halves it -> bound ~8.5 s"},
    },
    "granite-moe-1b-a400m/train_4k": {
        "baseline": {"hypothesis": "expert-parallel MoE: dispatch "
                     "all-to-all + FSDP gathers dominate"},
        "cf10": {
            "config": {"capacity_factor": 1.0},
            "hypothesis": "capacity 1.25 -> 1.0 cuts expert tile bytes and "
            "dispatch traffic 20% at the cost of more dropped tokens"},
        "zero_dp": {
            "rules": ZERO_DP_RULES,
            "hypothesis": "experts gathered per layer (2.4 GB bf16) make "
            "dispatch group-LOCAL: the all-to-all disappears; collective "
            "term becomes pure weight-gather traffic"},
        "zero_dp_cf10": {
            "rules": ZERO_DP_RULES,
            "config": {"capacity_factor": 1.0},
            "hypothesis": "compose zero_dp with tighter capacity: expert "
            "tiles shrink 20% on top of the local dispatch"},
        "zero_dp_noremat": {
            "rules": ZERO_DP_RULES,
            "config": {"remat": "none"},
            "hypothesis": "at 4096 tokens/chip the 1B model's activations "
            "fit without remat (~1.6 GB); dropping the rematerialized "
            "forward removes one of the three weight-gather passes -> "
            "collective ~ -1/3"},
    },
    "internvl2-26b/train_4k": {
        "baseline": {"hypothesis": "26B dense; same TP-AR-bound regime as "
                     "qwen but divisible heads (48): expect zero_dp to "
                     "generalize"},
        "zero_dp": {
            "rules": ZERO_DP_RULES,
            "hypothesis": "TP activation ARs vanish; weight gathers "
            "(3 x 52 GB bf16) + grad reduction remain"},
        "zero_dp_skip": {
            "rules": ZERO_DP_RULES,
            "config": {"causal_skip": True},
            "hypothesis": "compose with causal skipping"},
    },
    "qwen1.5-32b/prefill_32k": {
        "baseline": {"hypothesis": "32k prefill: attention is ~40% of "
                     "FLOPs and the dynamic blockwise path does 2x the "
                     "causal work (model/HLO 0.62)"},
        "causal_skip": {
            "config": {"causal_skip": True},
            "hypothesis": "static triangle skipping: ~1.8x fewer "
            "attention FLOPs at 32k and half the KV re-read traffic"},
        "head_pad48_skip": {
            "config": {"n_heads": 48, "n_kv_heads": 48, "d_head": 128,
                       "causal_skip": True},
            "hypothesis": "pad heads to 48 so attention shards 16-ways "
            "(kills the 6.8x per-chip replication) AND skip causal "
            "upper-triangle blocks: per-chip ~2 s, KV cache +20%"},
        "dp_serve": {
            "rules": {"batch": ("pod", "data", "model"),
                      "act_batch": ("pod", "data", "model"),
                      "heads": None, "kv_heads": None, "ff": None,
                      "vocab": None, "act_vocab": None, "act_heads": None,
                      "act_ff": None, "cache_kv": None, "cache_seq": None,
                      "_fallback": None},
            "hypothesis": "32 sequences over 256 chips = seq-only "
            "parallelism is impossible (batch 32 < 256); GSPMD pads 8x -> "
            "expect refutation (kept as the negative control)"},
    },
    "dlrm-m3/train_b64k": {
        "baseline": {"hypothesis": "2-axis row-wise table; naive gather "
                     "moves un-pooled (B,F,L,d) rows across shards"},
        "pooled_psum": {
            "config": {"placement": "row_wise", "lookup_impl": "psum",
                       "hbm_budget_gb": 8.0},
            "hypothesis": "PS-side pooling (shard_map + psum of pooled "
            "(B,F,d)) cuts forward cross-shard bytes by ~L=32x vs "
            "gathering rows"},
        "column_wise": {
            "config": {"placement": "column_wise"},
            "hypothesis": "column-wise placement balances load perfectly "
            "but every lookup touches all 16 shards: traffic ~same, "
            "latency-bound on real HW (paper's d=64 is only 4 lanes/shard "
            "- expect no win; refutation expected)"},
        "pooled_psum_bf16": {
            "config": {"placement": "row_wise", "lookup_impl": "psum",
                       "hbm_budget_gb": 8.0,
                       "grad_reduce_dtype": "bfloat16"},
            "hypothesis": "the remaining 0.29 s is the single fp32 gsum "
            "psum (2 x 7.4 GB ring); bf16 halves it -> ~0.15 s"},
    },
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch/shape, e.g. qwen1.5-32b/train_4k")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/perf")
    args = ap.parse_args()

    arch, shape_name = args.cell.split("/")
    shape = shapes_for(arch)[shape_name]
    cell_variants = VARIANTS[args.cell]
    names = list(cell_variants) if args.all else [args.variant]
    os.makedirs(args.out, exist_ok=True)

    for name in names:
        spec = cell_variants[name]
        print(f"[perf] {args.cell} :: {name}", flush=True)
        rec = run_cell(arch, shape, args.multi_pod,
                       rules_overrides=spec.get("rules"),
                       config_overrides=spec.get("config"))
        rec["variant"] = name
        rec["hypothesis"] = spec.get("hypothesis", "")
        path = os.path.join(
            args.out, f"{arch}__{shape_name}__{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["ok"]:
            print(f"   compute={rec['compute_s']:.3f}s "
                  f"(per-chip {rec.get('compute_s_per_chip', -1):.3f}s) "
                  f"memory={rec['memory_s']:.3f}s "
                  f"collective={rec['collective_s']:.3f}s "
                  f"dominant={rec['dominant']} "
                  f"bound={rec['bound_s']:.3f}s", flush=True)
        else:
            print(f"   FAIL {rec.get('error', '')[:120]}", flush=True)


if __name__ == "__main__":
    main()
