"""Training launcher (end-to-end driver, deliverable b).

Runs REAL training on the available devices (CPU here; the same script runs
on a pod by virtue of pjit + make_production_mesh). For CPU runs use a smoke
arch: `python -m repro.launch.train --arch stablelm-1.6b --smoke --steps 50`.

Features exercised: sharded params, data pipeline with host prefetch,
AdamW/AdaGrad split, checkpoint/restore (resumable), preemption handling,
straggler logging, EASGD / local-SGD pod sync (optional).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DLRMConfig
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import make_dlrm_batch, make_lm_batch
from repro.models.lm import lm_param_specs
from repro.nn.params import init_params
from repro.nn.sharding import TRAIN_RULES
from repro.optim.optimizers import adagrad, adamw
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (PreemptionHandler,
                                         StragglerDetector,
                                         run_resilient_loop)
from repro.train.steps import (build_dlrm_train_step, build_lm_train_step,
                               dlrm_init_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    is_dlrm = isinstance(cfg, DLRMConfig)
    key = jax.random.PRNGKey(0)

    ckpt = CheckpointManager(f"{args.ckpt_dir}/{args.arch}")
    preempt = PreemptionHandler()
    straggler = StragglerDetector()

    if is_dlrm:
        ebc = EmbeddingBagCollection.build(cfg, n_shards=1)
        params = init_params(dlrm_param_specs(cfg, ebc), key)
        opt = adagrad(0.01)
        state = dlrm_init_state(ebc, opt, params)
        step_fn = jax.jit(build_dlrm_train_step(cfg, ebc, opt))

        def gen(step, seed):
            raw = make_dlrm_batch(cfg, args.batch, step, seed)
            raw["idx"] = np.asarray(ebc.offset_indices(
                jnp.asarray(raw["idx"])))
            return raw
    else:
        params = init_params(lm_param_specs(cfg), key)
        opt = adamw(args.lr)
        state = opt.init(params)
        step_fn = jax.jit(build_lm_train_step(cfg, opt, TRAIN_RULES))

        def gen(step, seed):
            return make_lm_batch(cfg, args.batch, args.seq, step, seed)

    loader = ShardedLoader(gen, args.batch)
    pipeline = loader.pipeline(prefetch=2)

    start = 0
    if args.resume and ckpt.latest_step() is not None:
        blob = ckpt.restore({"params": params, "state": state})
        params, state = blob["params"], blob["state"]
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    losses = []

    def one_step(step):
        nonlocal params, state
        _, batch = next(pipeline)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, metrics = step_fn(params, state, batch,
                                         jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f}")

    def save(step):
        ckpt.save(step, {"params": params, "state": state}, async_=True)

    last = run_resilient_loop(one_step, args.steps, save, args.ckpt_every,
                              preempt, straggler, start_step=start)
    ckpt.wait()
    pipeline.close()
    print(f"done at step {last}; loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers flagged: {len(straggler.flagged_steps)}")


if __name__ == "__main__":
    main()
