"""Training launcher (end-to-end driver, deliverable b).

Runs REAL training on the available devices (CPU here; the same script runs
on a pod by virtue of pjit + make_production_mesh). For CPU runs use a smoke
arch: `python -m repro.launch.train --arch stablelm-1.6b --smoke --steps 50`.

Features exercised: sharded params, data pipeline with host prefetch,
AdamW/AdaGrad split, checkpoint/restore (resumable), preemption handling,
straggler logging, EASGD / local-SGD pod sync (optional).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DLRMConfig
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.pipeline import ShardedLoader
from repro.data.synthetic import make_dlrm_batch, make_lm_batch
from repro.models.lm import lm_param_specs
from repro.nn.params import init_params
from repro.nn.sharding import TRAIN_RULES
from repro.optim.optimizers import adagrad, adamw
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (FaultInjector, PreemptionHandler,
                                         StragglerDetector, TrainState,
                                         restore_train_state,
                                         run_chaos_loop, run_resilient_loop,
                                         save_train_state)
from repro.train.steps import (build_dlrm_train_step, build_lm_train_step,
                               dlrm_init_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="runs/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--chaos", action="store_true",
                    help="run under a seeded fault schedule (reader death, "
                         "torn checkpoints, preemption) with crash-"
                         "consistent recovery — docs/fault_tolerance.md")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the fault schedule (same seed => same "
                         "schedule)")
    ap.add_argument("--chaos-faults", type=int, default=3,
                    help="number of scheduled faults over the run")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    is_dlrm = isinstance(cfg, DLRMConfig)
    key = jax.random.PRNGKey(0)

    inj = None
    if args.chaos:
        # cache.fetch is excluded: this launcher drives the UNCACHED step
        inj = FaultInjector.from_seed(
            args.chaos_seed, args.steps, n_faults=args.chaos_faults,
            sites=("pipeline.batch", "checkpoint.write", "loop.step"))
        print("chaos schedule: " + ", ".join(
            f"{s.site}[{s.at}]={s.kind}" for s in inj.schedule))
    ckpt = CheckpointManager(f"{args.ckpt_dir}/{args.arch}", injector=inj)
    preempt = PreemptionHandler()
    straggler = StragglerDetector()

    if is_dlrm:
        ebc = EmbeddingBagCollection.build(cfg, n_shards=1)
        specs = dlrm_param_specs(cfg, ebc)
        params = init_params(specs, key)
        opt = adagrad(0.01)

        def fresh_state(p):
            return dlrm_init_state(ebc, opt, p)

        step_fn = jax.jit(build_dlrm_train_step(cfg, ebc, opt))

        def gen(step, seed):
            raw = make_dlrm_batch(cfg, args.batch, step, seed)
            raw["idx"] = np.asarray(ebc.offset_indices(
                jnp.asarray(raw["idx"])))
            return raw
    else:
        specs = lm_param_specs(cfg)
        params = init_params(specs, key)
        opt = adamw(args.lr)

        def fresh_state(p):
            return opt.init(p)

        step_fn = jax.jit(build_lm_train_step(cfg, opt, TRAIN_RULES))

        def gen(step, seed):
            return make_lm_batch(cfg, args.batch, args.seq, step, seed)

    state = fresh_state(params)
    loader = ShardedLoader(gen, args.batch)

    if args.chaos:
        return _chaos_main(args, inj, ckpt, preempt, loader, specs, key,
                           fresh_state, step_fn)

    pipeline = loader.pipeline(prefetch=2)

    start = 0
    if args.resume and ckpt.latest_step() is not None:
        blob = ckpt.restore({"params": params, "state": state})
        params, state = blob["params"], blob["state"]
        start = ckpt.latest_step()
        print(f"resumed from step {start}")

    losses = []

    def one_step(step):
        nonlocal params, state
        _, batch = next(pipeline)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, metrics = step_fn(params, state, batch,
                                         jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f}")

    def save(step):
        ckpt.save(step, {"params": params, "state": state}, async_=True)

    last = run_resilient_loop(one_step, args.steps, save, args.ckpt_every,
                              preempt, straggler, start_step=start)
    ckpt.wait()
    pipeline.close()
    print(f"done at step {last}; loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers flagged: {len(straggler.flagged_steps)}")


def _chaos_main(args, inj, ckpt, preempt, loader, specs, key,
                fresh_state, step_fn):
    """--chaos: seeded fault schedule + crash-consistent recovery. Every
    failure rebuilds the job from the newest INTACT TrainState bundle
    (params + optimizer + pipeline cursor) and replays; losses stay
    bit-equal to a fault-free run (tests/test_chaos.py proves the
    invariant; this path demos it end-to-end on the launcher)."""
    job: dict = {"pipe": None, "params": None, "state": None}
    losses: dict[int, float] = {}

    def restore_cb():
        if job["pipe"] is not None:
            job["pipe"].close()
        params = init_params(specs, key)
        state = fresh_state(params)
        start = 0
        try:
            ts = restore_train_state(ckpt, TrainState(params, state, None, 0))
            params, state, start = ts.params, ts.opt_state, ts.step
            print(f"chaos: restored step {ts.step} "
                  f"(intact checkpoint: {ckpt.last_restored_step})")
        except FileNotFoundError:
            pass
        job.update(params=params, state=state,
                   pipe=loader.pipeline(prefetch=2, start_step=start,
                                        injector=inj))
        return start

    def save_cb(step):
        save_train_state(ckpt, TrainState(job["params"], job["state"],
                                          None, step))

    def one_step(step):
        t, batch = next(job["pipe"])
        assert t == step, (t, step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, state, metrics = step_fn(job["params"], job["state"], batch,
                                         jnp.asarray(step, jnp.int32))
        job["params"], job["state"] = params, state
        losses[step] = float(metrics["loss"])
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {losses[step]:.4f}")

    rep = run_chaos_loop(one_step, args.steps, save_cb=save_cb,
                         restore_cb=restore_cb,
                         checkpoint_every=args.ckpt_every,
                         preemption=preempt, injector=inj)
    job["pipe"].close()
    fired = ", ".join(f"{s}[{at}]={k}" for s, at, k in inj.fired)
    print(f"chaos: fired {fired or 'nothing'}")
    print(f"chaos done at step {rep.last_step}: {rep.restarts} restarts; "
          f"loss {losses[0]:.4f} -> {losses[max(losses)]:.4f}")


if __name__ == "__main__":
    main()
