"""Serving launcher: batched-request generation with the slot engine.

CPU-sized demo: `python -m repro.launch.serve --arch stablelm-1.6b --smoke
--requests 8`.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.lm import lm_param_specs
from repro.nn.params import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    assert cfg.frontend is None, "serve demo drives token-only archs"
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(params, cfg, batch_slots=args.slots,
                         max_len=args.max_len, rules={})

    rng = np.random.RandomState(0)
    t0 = time.time()
    for uid in range(args.requests):
        prompt = rng.randint(0, cfg.vocab_size,
                             size=(rng.randint(4, 12),)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.new_tokens))
    done = engine.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s, {engine.steps_run} engine steps)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:8]}...")


if __name__ == "__main__":
    main()
