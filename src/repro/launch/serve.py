"""Serving launcher: LM slot engine or the overload-robust DLRM tier.

CPU-sized demos:

    python -m repro.launch.serve --arch stablelm-1.6b --smoke --requests 8
    python -m repro.launch.serve --arch dlrm-m1 --smoke --requests 32
    python -m repro.launch.serve --arch dlrm-m1 --smoke --requests 32 --chaos

The DLRM mode replays seeded Zipf traffic through `DLRMServeEngine` and
prints a parseable SLO summary (`serve[dlrm]: key=value ...` — asserted in
tests/test_cli_e2e.py). `--chaos` arms a seeded FaultInjector on the
`serve.fetch` / `serve.admit` sites: the replay then demonstrates the
degrade-don't-die contract (docs/serving.md) instead of dying.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.configs.base import DLRMConfig


def _serve_lm(cfg, args) -> None:
    from repro.models.lm import lm_param_specs
    from repro.nn.params import init_params
    from repro.serve.engine import Request, ServeEngine

    assert cfg.frontend is None, "serve demo drives token-only archs"
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(params, cfg, batch_slots=args.slots,
                         max_len=args.max_len, rules={})

    rng = np.random.RandomState(0)
    t0 = time.time()
    for uid in range(args.requests):
        prompt = rng.randint(0, cfg.vocab_size,
                             size=(rng.randint(4, 12),)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.new_tokens))
    done = engine.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    print(f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s, {engine.steps_run} engine steps)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:8]}...")


def _serve_dlrm(cfg, args) -> None:
    from repro.core.cache import CachedEmbeddingBagCollection
    from repro.core.dlrm import dlrm_param_specs
    from repro.core.embedding import EmbeddingBagCollection
    from repro.data.synthetic import make_dlrm_batch
    from repro.nn.params import init_params
    from repro.serve import DLRMServeEngine, ServeRequest

    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=args.cache_rows)
    injector = retry = None
    if args.chaos:
        from repro.train.fault_tolerance import FaultInjector, RetryPolicy
        injector = FaultInjector.from_seed(
            args.chaos_seed, args.requests,
            sites=("serve.fetch", "serve.admit"), n_faults=3)
        retry = RetryPolicy(max_retries=1, backoff_s=1e-4)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=args.max_queue,
                             max_batch=args.max_batch, injector=injector,
                             retry=retry)

    t0 = time.time()
    for uid in range(args.requests):
        raw = make_dlrm_batch(cfg, args.batch, step=uid,
                              zipf_alpha=args.zipf_alpha)
        idx = np.asarray(ebc.offset_indices(np.asarray(raw["idx"])))
        engine.submit(ServeRequest(uid, raw["dense"], idx))
        # offered load: submit a burst, then let the engine catch up
        if (uid + 1) % args.burst == 0:
            engine.step()
    engine.run()
    dt = time.time() - t0
    m = engine.metrics.snapshot()
    print(f"serve[dlrm]: served={int(m['served'])} shed={int(m['shed'])} "
          f"degraded={int(m['degraded'])} "
          f"hit_rate={engine.cache_stats.hit_rate:.4f} "
          f"shed_rate={m['shed_rate']:.4f} "
          f"degraded_fraction={m['degraded_fraction']:.4f} "
          f"p50_ms={m['p50_latency'] * 1e3:.3f} "
          f"p99_ms={m['p99_latency'] * 1e3:.3f} "
          f"batches={int(m['batches'])} breaker={engine.breaker.state} "
          f"wall_s={dt:.2f}")
    if args.chaos:
        print(f"  chaos: fired={injector.fired} "
              f"transitions={engine.breaker.transitions}")


def main():
    """Entry point: dispatch on the arch's config type (LM vs DLRM)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    # LM knobs
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    # DLRM knobs
    ap.add_argument("--batch", type=int, default=4,
                    help="examples per DLRM request")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="engine batch slots (examples per dispatch)")
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--burst", type=int, default=4,
                    help="requests submitted per engine step (offered load)")
    ap.add_argument("--zipf-alpha", type=float, default=1.05)
    ap.add_argument("--chaos", action="store_true",
                    help="arm a seeded FaultInjector on serve.fetch/admit")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    if isinstance(cfg, DLRMConfig):
        _serve_dlrm(cfg, args)
    else:
        _serve_lm(cfg, args)


if __name__ == "__main__":
    main()
