"""Compiled-artifact analyzers for the roofline report.

XLA's `compiled.cost_analysis()` counts `while` bodies ONCE, so a scanned
64-layer model under-reports FLOPs by ~64x. These parsers walk the program
text with loop-trip multipliers instead:

  StableHloAnalysis   parses `lowered.as_text()` (pre-partitioning, global
                      shapes): dot_general FLOPs, major-op HBM bytes
                      (dots, gathers, scatters, slices — the fused-world
                      traffic model), elementwise VPU flops, with every
                      `stablehlo.while` body multiplied by its trip count
                      (recovered from the `cond` region's LT constant) and
                      `func.call` edges followed.

  CollectiveAnalysis  parses `compiled.as_text()` (post-SPMD, per-device
                      shapes): per-chip collective bytes by op type, with
                      while-trip multipliers, ring-algorithm byte factors,
                      and group sizes from replica_groups.

Both are validated against cost_analysis() on loop-free graphs in
tests/test_analysis.py.

`sparse_backward_traffic` is the companion analytic model for the sparse
optimizer path: intermediate bytes the legacy vs fused backward materialize
between autodiff's pooled gradients and the table update.
`embedding_forward_traffic` mirrors it for the forward: bytes between the
mega table and the pooled bags for the legacy per-slot gather vs the
plan-driven dedup'd gather, with `zipf_expected_unique` supplying the
deterministic unique-row count of a bounded-Zipf access stream.
`multihost_exchange_traffic` prices the multi-host cached tier's three
all-to-all legs (miss fetch, routed grads, working-set refresh) against
the coherence-free per-lookup PS exchange. `serve_replay_traffic` prices
the read-only serving path (shed and degraded traffic never reaches the
capacity tier; no writeback leg exists).
"""
from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "i64": 8, "i32": 4, "i16": 2, "i8": 1,
    "i1": 1, "ui8": 1, "ui32": 4,
}

# ---------------------------------------------------------------------------
# sparse-backward intermediate-byte accounting (roofline companion)
# ---------------------------------------------------------------------------


def sparse_backward_traffic(batch: int, n_features: int, truncation: int,
                            embed_dim: int, itemsize: int = 4,
                            index_itemsize: int = 4) -> dict[str, float]:
    """Bytes of INTERMEDIATE tensors each sparse-backward path materializes
    between autodiff's pooled (B, F, D) gradients and the row-wise AdaGrad
    update — the tensors that cross op/kernel boundaries, counted once each
    (pallas_call operands are real HBM buffers, never fused away).

    legacy (per_lookup_grads + dedup_grads_ref + rowwise_adagrad):
      * the (B*F*L, D) per-lookup broadcast handed to the update op,
      * the sorted full-width gradient payload inside the dedup
        (grads[order], same shape), and
      * the deduplicated (B*F*L, D) gsum operand of the two-pass kernel.
    fused (sparse_plan + fused_bag_backward_adagrad):
      * the int32 plan only — unique_rows (N,), bag_offsets (N+1,),
        bag_ids (N,); the kernel reads pooled bag grads straight from the
        autodiff output and aggregates in VMEM.

    Returns legacy_bytes, fused_bytes and their ratio ("reduction") ~= D —
    >= truncation for every D >= truncation config, e.g. 128x at the prod
    m3 shape (D=128, L=32; asserted >= L in tests/test_sparse_fused.py).
    """
    n = batch * n_features * truncation
    legacy = 3.0 * n * embed_dim * itemsize
    fused = (2.0 * n + n + 1.0) * index_itemsize
    return {"legacy_bytes": legacy, "fused_bytes": fused,
            "reduction": legacy / fused}


def embedding_forward_traffic(batch: int, n_features: int, truncation: int,
                              embed_dim: int, n_unique: float,
                              itemsize: int = 4, index_itemsize: int = 4,
                              plan_shared: bool = True) -> dict[str, float]:
    """Bytes the legacy vs dedup'd embedding FORWARD moves between the mega
    table and the pooled (B, F, D) bags — the forward companion of
    `sparse_backward_traffic`, same accounting discipline (tensors that
    cross op/kernel boundaries, counted once each per step).

    legacy (per-slot gather, `lookup` without a plan / embedding_bag_kernel):
      * one HBM row read per lookup slot — the kernel DMAs every slot, pads
        included, so legacy_row_reads = B*F*L;
      * three full-width (B*F*L, D) per-slot tensors on the jnp path: the
        gather result, the validity-masked fp32 copy, and the pooling
        pass's re-read of it.
    dedup (plan-driven gather, `lookup(plan=...)` / dedup_embedding_bag):
      * each plan entry (unique row) read from the table exactly once —
        dedup_row_reads = n_unique, the batch duplication factor fewer;
      * the int32 CSR plan — counted here only when `plan_shared=False`:
        the plan-once-used-thrice contract builds it per batch for the
        BACKWARD's model (`sparse_backward_traffic` already charges
        (3N+1) index bytes), and the forward rides the same artifact.

    `n_unique` is the batch's unique-row count (or its static plan
    capacity): measure it, or use `zipf_expected_unique` for the
    deterministic bounded-Zipf expectation. Returns legacy/dedup bytes and
    row reads with their ratios; the ISSUE acceptance asserts
    reduction >= truncation at the prod shape in the Zipf-head reuse
    regime (tests/test_dedup_forward.py).
    """
    n = batch * n_features * truncation
    legacy = 3.0 * n * embed_dim * itemsize
    plan_bytes = 0.0 if plan_shared else (3.0 * n + 1.0) * index_itemsize
    dedup = n_unique * embed_dim * itemsize + plan_bytes
    return {"legacy_bytes": legacy, "dedup_bytes": dedup,
            "reduction": legacy / dedup,
            "legacy_row_reads": float(n),
            "dedup_row_reads": float(n_unique),
            "row_read_reduction": n / n_unique}


def multihost_exchange_traffic(batch: int, n_features: int, truncation: int,
                               embed_dim: int, n_hosts: int,
                               unique_per_host: float, unique_global: float,
                               hit_rate: float, itemsize: int = 4,
                               index_itemsize: int = 4) -> dict[str, float]:
    """Cross-host bytes per step of the multi-host cached tier
    (docs/cache.md "Multi-host coherence") — the companion of
    `sparse_backward_traffic` / `embedding_forward_traffic` for the three
    all-to-all legs, under a uniform row->owner map (a remote-owner
    fraction of (H-1)/H, which row-sharding a hashed id space achieves):

      fetch    each host's misses leave the owning shards:
               H * U_h * (1 - hit_rate) rows of payload;
      grads    each (row, bag) pair whose pooled gradient must reach a
               remote owner ships (D * itemsize) — pairs = B*F*L valid
               lookups (the repo routes per-bag grads so owner reduction
               keeps flat-batch order, i.e. bit-exactness; a production
               per-(host,row) partial-sum variant would ship H*U_h rows
               instead, reported as `grad_rowsum_bytes`);
      refresh  every working-set row returns post-update from its owner:
               H * U_h rows of payload.

    The baseline is the coherence-free alternative the paper's PS
    architecture implies at this scale: every host pushes PER-LOOKUP
    gradients and pulls per-lookup rows for its whole batch slice —
    2 * B*F*L * (H-1)/H * D * itemsize — with no dedup and no cache.
    `dup_rows` counts the per-step rows reduced once at the owner instead
    of updated H_dup times (H * U_h - U_g). Returns the per-leg bytes,
    their `total_bytes`, the baseline, and `reduction` = baseline / total.
    H = 1 degenerates to zero cross-host bytes (reduction = inf guarded
    to the baseline itself).
    """
    remote = (n_hosts - 1) / n_hosts
    row_bytes = embed_dim * itemsize
    pairs = float(batch * n_features * truncation)
    fetch_bytes = (n_hosts * unique_per_host * (1.0 - hit_rate)
                   * remote * (row_bytes + index_itemsize))
    grad_bytes = pairs * remote * (row_bytes + index_itemsize)
    grad_rowsum_bytes = (n_hosts * unique_per_host * remote
                         * (row_bytes + index_itemsize))
    refresh_bytes = n_hosts * unique_per_host * remote * row_bytes
    total = fetch_bytes + grad_bytes + refresh_bytes
    baseline = 2.0 * pairs * remote * row_bytes
    return {"fetch_bytes": fetch_bytes,
            "grad_bytes": grad_bytes,
            "grad_rowsum_bytes": grad_rowsum_bytes,
            "refresh_bytes": refresh_bytes,
            "total_bytes": total,
            "rowsum_total_bytes": (fetch_bytes + grad_rowsum_bytes
                                   + refresh_bytes),
            "baseline_bytes": baseline,
            "dup_rows": n_hosts * unique_per_host - unique_global,
            "reduction": baseline / total if total else baseline,
            "rowsum_reduction": (baseline / (fetch_bytes + grad_rowsum_bytes
                                             + refresh_bytes)
                                 if n_hosts > 1 else baseline)}


def zipf_expected_unique(n_draws: float, hash_size: int,
                         alpha: float = 1.05,
                         chunk: int = 1_000_000) -> float:
    """Expected number of DISTINCT rows among `n_draws` i.i.d. draws from
    the bounded Zipf(alpha) over [0, hash_size) (the
    `data.synthetic.bounded_zipf_rows` distribution):

        E[unique] = sum_r 1 - (1 - p_r)^n,   p_r ∝ (r+1)^-alpha.

    Exact chunked float64 sum — deterministic (no sampling), O(hash_size),
    fine up to the paper's 2e7-row clip. This is the duplication-factor
    denominator of `embedding_forward_traffic` for synthetic traffic."""
    import numpy as np  # local: this module otherwise imports stdlib only
    h = int(hash_size)
    norm = 0.0
    for lo in range(1, h + 1, chunk):
        r = np.arange(lo, min(lo + chunk, h + 1), dtype=np.float64)
        norm += float((r ** -alpha).sum())
    total = 0.0
    for lo in range(1, h + 1, chunk):
        r = np.arange(lo, min(lo + chunk, h + 1), dtype=np.float64)
        p = (r ** -alpha) / norm
        # 1-(1-p)^n via expm1/log1p: stable for the tiny tail probabilities
        total += float((-np.expm1(n_draws * np.log1p(-p))).sum())
    return total


def cache_admission_traffic(fetched_rows: float, embed_dim: int,
                            fetch_chunks: float = 0.0,
                            overfetch_rows: float = 0.0,
                            itemsize: int = 4,
                            accum_itemsize: int = 4,
                            descriptor_bytes: int = 32) -> dict[str, float]:
    """Capacity->cache transfer bytes of the cached tier's admission path
    (docs/cache.md "Chunk-granular transfers") — companion of
    `multihost_exchange_traffic` for the fetch leg's DMA shape.

    Every admitted row moves `row_bytes` of payload (the fp32 embedding row
    plus its row-wise AdaGrad accumulator, which rides every fetch so
    optimizer state stays coherent across tiers). On top of the payload,
    each DMA descriptor costs `descriptor_bytes` of control overhead — the
    per-transfer setup cost that makes single-row gathers latency-bound.

    Single-row transfers issue one descriptor per row. Chunk-granular
    transfers issue one descriptor per contiguous block (`fetch_chunks`,
    the `cache_fetch_chunks` stat) but over-fetch `overfetch_rows` of cold
    padding (the `cache_overfetch_rows` stat). The crossover is the
    admission-policy lever: EMA admission plus the ids-by-frequency reorder
    (`core.placement.frequency_reorder`) keeps the Zipf head contiguous, so
    blocks stay dense and the descriptor savings dominate the padding.

    Feed per-arm stats from `CacheStats.snapshot()`; `fetch_chunks=0`
    means the single-row path (descriptors = rows). Returns the payload
    and descriptor bytes of both shapes for the GIVEN miss stream plus
    `chunked_vs_single`, their ratio (< 1 when chunking wins).
    """
    row_bytes = float(embed_dim * itemsize + accum_itemsize)
    single_bytes = fetched_rows * (row_bytes + descriptor_bytes)
    n_desc = fetch_chunks if fetch_chunks > 0 else fetched_rows
    chunked_bytes = ((fetched_rows + overfetch_rows) * row_bytes
                     + n_desc * descriptor_bytes)
    return {"row_bytes": row_bytes,
            "payload_bytes": fetched_rows * row_bytes,
            "single_row_bytes": single_bytes,
            "chunked_bytes": chunked_bytes,
            "descriptors": n_desc,
            "chunked_vs_single": (chunked_bytes / single_bytes
                                  if single_bytes else 1.0)}


def tier_hierarchy_traffic(fetched_rows: float, embed_dim: int,
                           dram_hit_rate: float,
                           bulk_chunk: int = 32,
                           bulk_latency_us: float = 50.0,
                           chunk_density: float = 1.0,
                           demotion_rows: float | None = None,
                           dram_latency_us: float = 0.5,
                           itemsize: int = 4, accum_itemsize: int = 4,
                           descriptor_bytes: int = 32) -> dict[str, float]:
    """Per-tier bytes x latency model of the HBM -> DRAM -> bulk hierarchy
    (core/tiers.py) — the pricing `recommend_placement` uses to mark
    tables cached_host (DRAM-backed) vs cached_bulk (bulk-backed).

    The miss stream that reaches the capacity level (`fetched_rows` per
    step, e.g. `zipf_expected_unique` discounted by the device hit rate)
    splits by `dram_hit_rate` (the `TierCacheStats.dram_hit_rate`
    convention): the DRAM share pays one descriptor + payload at DRAM
    latency; the bulk share PROMOTES through block-granular reads —
    `ceil(rows / (bulk_chunk * chunk_density))` blocks, each moving a full
    `bulk_chunk`-row block (over-fetch included) and costing
    `bulk_latency_us`. In steady state every promotion displaces one DRAM
    row, so demotions write the same block traffic back unless
    `demotion_rows` overrides the equilibrium.

    Returns the per-leg bytes and microseconds plus `total_latency_us`
    (what a fully synchronous schedule would stall) and `bulk_vs_dram`,
    the hierarchy's latency relative to an all-DRAM capacity tier (>= 1;
    the async stream's job is hiding the difference — the measured
    counterpart is `tiers/bulk_overlap` in benchmarks/tiers_bench.py)."""
    row_bytes = float(embed_dim * itemsize + accum_itemsize)
    dram_rows = fetched_rows * min(max(dram_hit_rate, 0.0), 1.0)
    bulk_rows = max(fetched_rows - dram_rows, 0.0)
    density = min(max(chunk_density, 1e-9), 1.0)
    rows_per_block = max(float(bulk_chunk) * density, 1e-9)
    read_blocks = math.ceil(bulk_rows / rows_per_block) if bulk_rows else 0
    demote = bulk_rows if demotion_rows is None else float(demotion_rows)
    write_blocks = math.ceil(demote / rows_per_block) if demote else 0
    block_bytes = float(bulk_chunk) * row_bytes + descriptor_bytes
    dram_bytes = dram_rows * (row_bytes + descriptor_bytes)
    bulk_read_bytes = read_blocks * block_bytes
    bulk_write_bytes = write_blocks * block_bytes
    dram_us = dram_rows * dram_latency_us
    bulk_us = (read_blocks + write_blocks) * bulk_latency_us
    all_dram_us = fetched_rows * dram_latency_us
    total_us = dram_us + bulk_us
    return {"row_bytes": row_bytes,
            "dram_rows": dram_rows,
            "bulk_rows": bulk_rows,
            "demotion_rows": demote,
            "bulk_read_blocks": float(read_blocks),
            "bulk_write_blocks": float(write_blocks),
            "dram_bytes": dram_bytes,
            "bulk_read_bytes": bulk_read_bytes,
            "bulk_write_bytes": bulk_write_bytes,
            "total_bytes": dram_bytes + bulk_read_bytes + bulk_write_bytes,
            "dram_latency_us": dram_us,
            "bulk_latency_us": bulk_us,
            "total_latency_us": total_us,
            "bulk_vs_dram": (total_us / all_dram_us
                             if all_dram_us > 0 else 1.0)}


def serve_replay_traffic(requests: float, examples: int, n_features: int,
                         truncation: int, embed_dim: int, hit_rate: float,
                         shed_rate: float = 0.0,
                         degraded_fraction: float = 0.0,
                         itemsize: int = 4, accum_itemsize: int = 4,
                         descriptor_bytes: int = 32) -> dict[str, float]:
    """Capacity-tier bytes of the SERVING path for a traffic replay
    (serve/dlrm_engine.py, benchmarks/serve_bench.py) — the read-only
    mirror of `cache_admission_traffic`.

    Serving differs from training in three byte-relevant ways: shed
    requests (`shed_rate`) never touch the capacity tier at all; degraded
    batches (`degraded_fraction`) resolve misses from the host-local stale
    snapshot, so their fetch leg costs nothing; and the tier is read-only,
    so there is NO writeback leg ever (dirty evictions do not exist).
    Each surviving unique miss moves the fp32 row plus its accumulator
    (the fetch path is shared with training) plus one DMA descriptor.

    `hit_rate` is the FBGEMM convention (1 - unique_misses / accesses) —
    feed `CacheStats.hit_rate` and `ServeMetrics.snapshot()` figures from
    a replay, or `zipf_expected_unique` for a closed-form stream. Returns
    the cached fetch bytes, the uncached oracle bytes (every access pulls
    a full row), and `uncached_vs_cached`, their ratio (> 1 when the
    cache + shedding + stale-serve stack wins; higher is better)."""
    served = requests * (1.0 - shed_rate)
    accesses = served * examples * n_features * truncation
    row_bytes = float(embed_dim * itemsize + accum_itemsize)
    fetched = accesses * (1.0 - hit_rate) * (1.0 - degraded_fraction)
    fetch_bytes = fetched * (row_bytes + descriptor_bytes)
    uncached_bytes = accesses * embed_dim * itemsize
    return {"accesses": accesses,
            "fetched_rows": fetched,
            "fetch_bytes": fetch_bytes,
            "writeback_bytes": 0.0,
            "uncached_bytes": uncached_bytes,
            "uncached_vs_cached": (uncached_bytes / fetch_bytes
                                   if fetch_bytes else float("inf"))}


def tablewise_exchange_traffic(batch: int, n_features: int, truncation: int,
                               embed_dim: int, n_hosts: int,
                               itemsize: int = 4,
                               features_per_owner=None) -> dict[str, float]:
    """Cross-host bytes per step of the TABLE-WISE hybrid placement
    (train/steps.py `build_tablewise_train_step`, docs/parallelism.md):
    whole tables live on owning hosts and only the POOLED (B, F, d)
    activations cross the wire — forward pooled outputs out, pooled bag
    gradients back, each with a remote fraction of (H-1)/H. Per-lookup
    rows never move, so the exchange is independent of both the bag
    length L and the batch's unique-row working set:

        fwd = bwd = (H-1)/H * B * F * d * itemsize.

    The per-(host, owner) pair leg carries only the owner's OWN tables
    for the destination's batch slice — ceil(B/H) * max_t F_t * d *
    itemsize — which is why the all-to-all stays under B*F*d*itemsize
    per leg at any scale (`features_per_owner`, e.g. a bincount of
    `core.placement` owners, sharpens max_t F_t from the uniform
    ceil(F/H) default).

    `rowshard_bytes` is the comparison the bench rows gate: the
    row-sharded naive gather ships the un-pooled (B, F, L, d) rows both
    ways, so `pooling_reduction` = rowshard / total ≈ L. Complements
    `multihost_exchange_traffic` (the row-sharded CACHED tier, whose
    traffic scales with unique rows instead) — `recommend_placement`
    prices all three."""
    remote = (n_hosts - 1) / max(n_hosts, 1)
    act_bytes = float(embed_dim * itemsize)
    fwd = remote * batch * n_features * act_bytes
    if features_per_owner is not None and len(features_per_owner):
        max_f = max(int(f) for f in features_per_owner)
    else:
        max_f = -(-n_features // max(n_hosts, 1))
    pair_leg = -(-batch // max(n_hosts, 1)) * max_f * act_bytes
    pairs = float(batch * n_features * truncation)
    rowshard = 2.0 * pairs * remote * act_bytes
    total = 2.0 * fwd
    return {"fwd_bytes": fwd,
            "bwd_bytes": fwd,
            "total_bytes": total,
            "pair_leg_bytes": pair_leg,
            "rowshard_bytes": rowshard,
            "pooling_reduction": rowshard / total if total else 1.0}


def recommend_placement(hash_sizes, mean_lookups, embed_dim: int,
                        batch: int, truncation: int, n_hosts: int,
                        hbm_budget_bytes: float, alpha: float = 1.05,
                        hit_rate: float = 0.0,
                        itemsize: int = 4,
                        dram_budget_bytes: float = 0.0,
                        bulk_chunk: int = 32,
                        bulk_latency_us: float = 50.0) -> dict:
    """Compose the traffic models into a per-table placement pick — the
    analytic closing of the loop "Building a Performance Model for DLRM
    Training on GPUs" (arxiv 2201.07821) argues for: place by priced
    bytes, not by hand.

    Prices three strategies for a (batch, truncation) step over Zipf(α)
    synthetic traffic:
      replicated   every host holds every table — zero exchange; only
                   available when the whole collection fits one host's
                   budget;
      table_wise   pooled all-to-all (`tablewise_exchange_traffic`), with
                   owners from `core.placement.plan_placement` bin-packing
                   each table's priced cost (its pooled legs plus its
                   expected per-step unique-row update footprint). Tables
                   whose bytes exceed one host's budget become
                   column_wise with ceil(bytes / budget) D-slices;
      cached_host  the row-sharded cached tier
                   (`multihost_exchange_traffic`), unique counts from
                   `zipf_expected_unique`, misses discounted by
                   `hit_rate`.

    A positive `dram_budget_bytes` additionally tiers the CAPACITY level
    (the N-tier hierarchy, core/tiers.py): tables fill host DRAM greedily
    by heat density (expected unique rows per byte — hottest bytes stay in
    DRAM) and the overflow is marked for the bulk tier. Each per-table
    entry then carries `"tier": "dram" | "bulk"` — i.e. cached_host vs
    cached_bulk — and the result gains a `"tiering"` dict with the split
    and its `tier_hierarchy_traffic` pricing at (`bulk_chunk`,
    `bulk_latency_us`).

    Returns {"pick", "fits_one_host", "tablewise", "rowshard",
    "per_table": [{"table", "strategy", "owner", "column_shards",
    "bytes", "cost", "tier"}], "plan", "tiering"} — `plan` is the
    PlacementPlan behind the table_wise pricing, ready to hand to
    `EmbeddingBagCollection`. The deterministic bench rows
    (benchmarks/dlrm_bench.py `tablewise/...`) validate the tablewise
    model against the step's measured exchange metrics."""
    import numpy as np  # local: this module otherwise imports stdlib only

    from repro.core.placement import plan_placement
    hh = [int(h) for h in hash_sizes]
    n_f = len(hh)
    lk = [min(float(length), float(truncation)) for length in mean_lookups]
    row_bytes = float(embed_dim * itemsize)
    # params + the row-wise AdaGrad accumulator both occupy the owner
    table_bytes = [h * row_bytes + h * 4.0 for h in hh]
    fits = (hbm_budget_bytes <= 0
            or sum(table_bytes) <= float(hbm_budget_bytes))
    uniq_t = [zipf_expected_unique(batch * lk[t], hh[t], alpha)
              for t in range(n_f)]
    # priced cost per table: its share of the pooled legs (uniform — the
    # pooled payload is per-table-independent) + its owner-side update
    # footprint; the bin-pack balances the sum across owners
    remote = (n_hosts - 1) / max(n_hosts, 1)
    pooled_leg = 2.0 * remote * batch * row_bytes
    costs = [pooled_leg + uniq_t[t] * row_bytes for t in range(n_f)]
    plan = plan_placement(hh, mean_lookups, embed_dim, n_hosts,
                          hbm_budget_bytes, strategy="table_wise",
                          itemsize=itemsize, table_costs=costs)
    owners = [int(o // max(plan.shard_rows, 1))
              for o in plan.table_offsets]
    f_per_owner = np.bincount(np.asarray(owners), minlength=n_hosts)
    tw = tablewise_exchange_traffic(batch, n_f, truncation, embed_dim,
                                    n_hosts, itemsize,
                                    features_per_owner=f_per_owner)
    u_g = float(sum(uniq_t))
    u_h = float(sum(zipf_expected_unique(batch / max(n_hosts, 1) * lk[t],
                                         hh[t], alpha) for t in range(n_f)))
    mean_lk = sum(lk) / max(n_f, 1)
    rs = multihost_exchange_traffic(batch, n_f, mean_lk, embed_dim,
                                    n_hosts, u_h, u_g, hit_rate, itemsize)
    if fits:
        pick = "replicated"
    elif tw["total_bytes"] <= rs["total_bytes"]:
        pick = "table_wise"
    else:
        pick = "cached_host"
    per_table = []
    for t in range(n_f):
        cs = int(plan.column_shards[t]) if plan.column_shards else 1
        strategy = ("replicated" if fits
                    else "column_wise" if cs > 1 else "table_wise")
        per_table.append({"table": t, "strategy": strategy,
                          "owner": owners[t], "column_shards": cs,
                          "bytes": table_bytes[t], "cost": costs[t],
                          "tier": "dram"})
    tiering = None
    if dram_budget_bytes > 0:
        # greedy DRAM fill by heat density (expected unique rows touched
        # per byte held): the hottest bytes stay a DRAM hit, the coldest
        # tables page through the bulk tier
        order = sorted(range(n_f),
                       key=lambda t: -(uniq_t[t] / max(table_bytes[t], 1.0)))
        spent, dram_tables, bulk_tables = 0.0, [], []
        for t in order:
            if spent + table_bytes[t] <= float(dram_budget_bytes):
                spent += table_bytes[t]
                dram_tables.append(t)
            else:
                per_table[t]["tier"] = "bulk"
                bulk_tables.append(t)
        fetched = u_g * (1.0 - min(max(hit_rate, 0.0), 1.0))
        uniq_dram = sum(uniq_t[t] for t in dram_tables)
        dram_hit = uniq_dram / u_g if u_g > 0 else 1.0
        tiering = {"dram_tables": sorted(dram_tables),
                   "bulk_tables": sorted(bulk_tables),
                   "dram_bytes": spent,
                   "bulk_bytes": sum(table_bytes[t] for t in bulk_tables),
                   "dram_hit_rate": dram_hit,
                   "traffic": tier_hierarchy_traffic(
                       fetched, embed_dim, dram_hit, bulk_chunk=bulk_chunk,
                       bulk_latency_us=bulk_latency_us, itemsize=itemsize)}
    return {"pick": pick, "fits_one_host": fits, "tablewise": tw,
            "rowshard": rs, "per_table": per_table, "plan": plan,
            "tiering": tiering}


# ---------------------------------------------------------------------------
# StableHLO (lowered.as_text())
# ---------------------------------------------------------------------------

_TENSOR_RE = re.compile(r"tensor<([^>]*)>")
_FUNC_RE = re.compile(r"func\.func (?:public |private )?@([\w.$-]+)\(")
_CALL_RE = re.compile(r"(?:func\.)?call @([\w.$-]+)\(")
_TRIP_RE = re.compile(r"dense<(\d+)> : tensor<i32>")
_CONTRACT_RE = re.compile(r"contracting_dims = \[([\d, ]*)\] x \[([\d, ]*)\]")

_ELEMENTWISE = (
    "stablehlo.add", "stablehlo.subtract", "stablehlo.multiply",
    "stablehlo.divide", "stablehlo.maximum", "stablehlo.minimum",
    "stablehlo.tanh", "stablehlo.exponential", "stablehlo.logistic",
    "stablehlo.log", "stablehlo.rsqrt", "stablehlo.sqrt", "stablehlo.power",
    "stablehlo.negate", "stablehlo.select", "stablehlo.compare",
    "stablehlo.abs", "stablehlo.floor", "stablehlo.round",
)
_MAJOR_BYTES_OPS = (
    "stablehlo.gather", "stablehlo.scatter", "stablehlo.dynamic_slice",
    "stablehlo.dynamic_update_slice", "stablehlo.sort", "stablehlo.iota",
    "stablehlo.reduce",
)


def _tensor_numel_bytes(t: str) -> tuple[int, int, list[int]]:
    """'64x128xf32' -> (numel, bytes, dims); 'f32' -> (1, 4, [])."""
    parts = t.split("x")
    if len(parts) == 1:
        dt = parts[0]
        return 1, _DTYPE_BYTES.get(dt, 4), []
    dims = [int(p) for p in parts[:-1]]
    dt = parts[-1]
    n = math.prod(dims)
    return n, n * _DTYPE_BYTES.get(dt, 4), dims


@dataclasses.dataclass
class OpCost:
    mxu_flops: float = 0.0        # dot_general flops
    vpu_flops: float = 0.0        # elementwise flops (1/elt)
    major_bytes: float = 0.0      # dots+gathers+scatters operand/result bytes
    dot_count: int = 0
    gather_bytes: float = 0.0
    scatter_bytes: float = 0.0

    def add(self, other: "OpCost", mult: float = 1.0):
        self.mxu_flops += other.mxu_flops * mult
        self.vpu_flops += other.vpu_flops * mult
        self.major_bytes += other.major_bytes * mult
        self.dot_count += int(other.dot_count * mult)
        self.gather_bytes += other.gather_bytes * mult
        self.scatter_bytes += other.scatter_bytes * mult


class StableHloAnalysis:
    def __init__(self, text: str):
        self.functions = self._split_functions(text)
        self._cache: dict[str, OpCost] = {}
        self.warnings: list[str] = []

    # -- public ---------------------------------------------------------------

    def cost(self, entry: str = "main") -> OpCost:
        return self._fn_cost(entry)

    # -- parsing --------------------------------------------------------------

    @staticmethod
    def _split_functions(text: str) -> dict[str, list[str]]:
        fns: dict[str, list[str]] = {}
        lines = text.splitlines()
        i = 0
        while i < len(lines):
            m = _FUNC_RE.search(lines[i])
            if not m:
                i += 1
                continue
            name = m.group(1)
            depth = lines[i].count("{") - lines[i].count("}")
            body = []
            i += 1
            while i < len(lines) and depth > 0:
                depth += lines[i].count("{") - lines[i].count("}")
                if depth > 0:
                    body.append(lines[i])
                i += 1
            fns[name] = body
        return fns

    def _fn_cost(self, name: str) -> OpCost:
        if name in self._cache:
            return self._cache[name]
        self._cache[name] = OpCost()      # break recursion
        body = self.functions.get(name)
        if body is None:
            self.warnings.append(f"missing function @{name}")
            return self._cache[name]
        cost = self._walk(body, 0, len(body))[0]
        self._cache[name] = cost
        return cost

    def _walk(self, lines: list[str], start: int, end: int
              ) -> tuple[OpCost, int]:
        """Walk [start, end) at one region level, returning (cost, next)."""
        cost = OpCost()
        i = start
        while i < end:
            ln = lines[i]
            if "stablehlo.while" in ln and "=" in ln:
                trip, i = self._while(lines, i, end, cost)
                continue
            self._op_cost(ln, cost)
            for m in _CALL_RE.finditer(ln):
                cost.add(self._fn_cost(m.group(1)))
            i += 1
        return cost, i

    def _while(self, lines: list[str], i: int, end: int, cost: OpCost
               ) -> tuple[int, int]:
        """Parse `stablehlo.while ... cond { } do { }`, add body cost x trip.

        The cond region is trivial (compare + constant) and contains no
        nested regions; it ends at the `} do {` line. The do region may nest
        (inner whiles, scatter/reduce regions) — tracked by net brace depth.
        Returns (trip_count, index after the closing `}`)."""
        j = i + 1
        while j < end and "cond {" not in lines[j]:
            if "stablehlo" in lines[j]:        # not a region-form while
                self.warnings.append("while without cond region")
                return 1, i + 1
            j += 1
        cond_lines: list[str] = []
        j += 1
        while j < end and "} do {" not in lines[j]:
            cond_lines.append(lines[j])
            j += 1
        body_lines: list[str] = []
        depth = 1
        j += 1
        while j < end and depth > 0:
            depth += lines[j].count("{") - lines[j].count("}")
            if depth <= 0:
                break
            body_lines.append(lines[j])
            j += 1
        trips = [int(m.group(1)) for m in
                 _TRIP_RE.finditer("\n".join(cond_lines))]
        trip = max(trips) if trips else 1
        if not trips:
            self.warnings.append("while without parsable trip count")
        body_cost, _ = self._walk(body_lines, 0, len(body_lines))
        cost.add(body_cost, trip)
        return trip, j + 1

    def _op_cost(self, ln: str, cost: OpCost):
        if "stablehlo.dot_general" in ln:
            tensors = _TENSOR_RE.findall(ln)
            if len(tensors) >= 3:
                lhs, _, res = tensors[-3], tensors[-2], tensors[-1]
                _, lhs_b, lhs_dims = _tensor_numel_bytes(lhs)
                rn, res_b, _ = _tensor_numel_bytes(res)
                _, rhs_b, _ = _tensor_numel_bytes(tensors[-2])
                m = _CONTRACT_RE.search(ln)
                k = 1
                if m and m.group(1).strip():
                    for d in m.group(1).split(","):
                        k *= lhs_dims[int(d)]
                cost.mxu_flops += 2.0 * rn * k
                cost.major_bytes += lhs_b + rhs_b + res_b
                cost.dot_count += 1
            return
        stripped = ln.strip()
        for op in _ELEMENTWISE:
            if f"{op} " in stripped or f"{op}(" in stripped:
                tensors = _TENSOR_RE.findall(ln)
                if tensors:
                    n, _, _ = _tensor_numel_bytes(tensors[-1])
                    cost.vpu_flops += n
                return
        for op in _MAJOR_BYTES_OPS:
            if op in stripped:
                tensors = _TENSOR_RE.findall(ln)
                if not tensors:
                    return
                sizes = [_tensor_numel_bytes(t)[1] for t in tensors]
                # traffic model: sliced/gathered access moves the SLICE,
                # not the whole operand
                if op in ("stablehlo.gather", "stablehlo.dynamic_slice"):
                    b = 2.0 * sizes[-1]          # read slice + write result
                    cost.gather_bytes += b
                elif op == "stablehlo.dynamic_update_slice":
                    upd = sizes[1] if len(sizes) > 1 else sizes[-1]
                    b = 2.0 * upd                # rmw of the updated window
                elif op == "stablehlo.scatter":
                    upd = sizes[len(sizes) // 2] if len(sizes) > 2 \
                        else sizes[-1]
                    b = 3.0 * upd                # read+write rows, read upd
                    cost.scatter_bytes += b
                elif op == "stablehlo.iota":
                    b = sizes[-1]                # write only
                else:                            # sort / reduce: in + out
                    b = sum(sizes)
                cost.major_bytes += b
                return

# ---------------------------------------------------------------------------
# post-SPMD HLO (compiled.as_text()) — collectives
# ---------------------------------------------------------------------------

_HLO_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HLO_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.$-]+), body=%?([\w.$-]+)")
_HLO_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_HLO_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.$-]+)")
_HLO_CONST_RE = re.compile(r"s32\[\] constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dt: str, dims: str) -> float:
    n = math.prod(int(d) for d in dims.split(",") if d) if dims else 1
    return n * _DTYPE_BYTES.get(dt, 4)


_HLO_DOT_RE = re.compile(
    r"%([\w.$-]+) = (\w+)\[([\d,]*)\][^=]* dot\(%?([\w.$-]+),")
_HLO_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_HLO_DEF_RE = re.compile(r"^\s*(?:ROOT )?%([\w.$-]+) = (\w+)\[([\d,]*)\]")


class CollectiveAnalysis:
    """Per-chip collective traffic (bytes) by op type AND per-chip dot
    FLOPs, loop-aware. Post-SPMD shapes are per-device, so dot_flops here
    includes replication waste (e.g. qwen's non-divisible 40 heads leaving
    attention replicated across the TP axis) that the global StableHLO
    count cannot see."""

    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self.warnings: list[str] = []
        self.by_type: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
        self.op_log: list[tuple[str, float, int]] = []
        self.dot_flops: float = 0.0          # per chip, loop-corrected
        entry = next((n for n, (is_entry, _) in self.computations.items()
                      if is_entry), None)
        if entry is None:
            self.warnings.append("no ENTRY computation found")
        else:
            self._walk(entry, 1.0, set())

    @property
    def total_bytes(self) -> float:
        return sum(self.by_type.values())

    @staticmethod
    def _split(text: str) -> dict[str, tuple[bool, list[str]]]:
        """Computation header: `[ENTRY ]%name (args) -> type {` (args may
        nest parens); ops are ` %x = ...` lines; body ends at a bare `}`."""
        comps: dict[str, tuple[bool, list[str]]] = {}
        cur, body = None, []
        for ln in text.splitlines():
            s = ln.strip()
            if cur is None:
                if (s.endswith("{") and ") -> " in s
                        and (s.startswith("%") or s.startswith("ENTRY "))):
                    is_entry = s.startswith("ENTRY ")
                    name = s[len("ENTRY "):] if is_entry else s
                    name = name.lstrip("%").split(" ")[0]
                    cur = name
                    body = []
                    comps[cur] = (is_entry, body)
                continue
            if s == "}":
                cur = None
                continue
            body.append(ln)
        return comps

    def _trip_count(self, ln: str, cond_name: str) -> int:
        m = _HLO_TRIP_RE.search(ln)
        if m:
            return int(m.group(1))
        _, body = self.computations.get(cond_name, (False, []))
        consts = [int(mm.group(1)) for bl in body
                  for mm in _HLO_CONST_RE.finditer(bl)]
        if not consts:
            self.warnings.append(f"no trip count in {cond_name}")
            return 1
        return max(consts)

    def _walk(self, comp: str, mult: float, stack: set):
        if comp in stack:
            return
        _, body = self.computations.get(comp, (False, []))
        shapes: dict[str, tuple[str, str]] = {}
        for ln in body:
            dm = _HLO_DEF_RE.match(ln)
            if dm:
                shapes[dm.group(1)] = (dm.group(2), dm.group(3))
        for ln in body:
            wm = _HLO_WHILE_RE.search(ln)
            if wm:
                trip = self._trip_count(ln, wm.group(1))
                self._walk(wm.group(2), mult * trip, stack | {comp})
                continue
            handled = self._collective(ln, mult)
            if handled:
                continue
            dotm = _HLO_DOT_RE.search(ln)
            if dotm:
                self._dot(ln, dotm, shapes, mult)
                continue
            if "custom-call" in ln and ("matmul" in ln or "dot" in ln.lower()):
                self.warnings.append("dot lowered to custom-call (uncounted)")
            # follow fusions/calls (cheap; collectives rarely inside)
            if " fusion(" in ln or " call(" in ln:
                for m in _HLO_CALL_RE.finditer(ln):
                    self._walk(m.group(1), mult, stack | {comp})

    def _dot(self, ln: str, dotm, shapes, mult: float):
        res_dims = [int(d) for d in dotm.group(3).split(",") if d]
        lhs = shapes.get(dotm.group(4))
        cm = _HLO_CONTRACT_RE.search(ln)
        if lhs is None or cm is None:
            self.warnings.append("unparsable dot")
            return
        lhs_dims = [int(d) for d in lhs[1].split(",") if d]
        k = 1
        for ci in cm.group(1).split(","):
            if ci:
                k *= lhs_dims[int(ci)]
        self.dot_flops += 2.0 * math.prod(res_dims) * k * mult

    def _group_size(self, ln: str, default: int) -> int:
        m = _GROUPS_IOTA_RE.search(ln)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(ln)
        if m:
            return len(m.group(1).split(","))
        return default

    def _collective(self, ln: str, mult: float) -> bool:
        name = next((c for c in _COLLECTIVES
                     if f" {c}(" in ln or f"{c}-start(" in ln), None)
        if name is None:
            return False
        if f"{name}-done" in ln:
            return True
        # result shapes: everything left of the op INVOCATION (the
        # instruction name itself also contains the op string, so split on
        # the "op(" form)
        lhs = ln
        for delim in (f" {name}(", f" {name}-start("):
            if delim in ln:
                lhs = ln.split(delim)[0]
                break
        shapes = _HLO_SHAPE_RE.findall(lhs)
        res_bytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = self._group_size(ln, 2)
        ring = (g - 1) / max(g, 1)
        if name == "all-reduce":
            traffic = 2.0 * res_bytes * ring
        elif name == "all-gather":
            traffic = res_bytes * ring
        elif name == "reduce-scatter":
            traffic = res_bytes * (g - 1)      # operand ~= result x g
        elif name == "all-to-all":
            traffic = res_bytes * ring
        else:                                   # collective-permute
            traffic = res_bytes
        self.by_type[name] += traffic * mult
        self.op_log.append((name, traffic, int(mult)))
        return True
