"""Fault tolerance: preemption-safe checkpointing, straggler detection,
elastic re-meshing.

At thousands of nodes (the scale the paper's fleet data comes from),
*something* is always failing: the training loop treats preemption as a
normal event (checkpoint-now + clean exit, resumable), watches per-step host
time for stragglers (the paper's section VII cites tail-at-scale and
CPR-style partial recovery), and can resume the SAME global state on a
DIFFERENT mesh shape (checkpoint.py restore with new shardings).
"""
from __future__ import annotations

import collections
import contextlib
import signal
import time
from collections.abc import Callable


class PreemptionHandler:
    """SIGTERM/SIGINT -> checkpoint-now flag. The train loop polls
    `should_stop` each step and exits through the checkpoint path."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = False
        self._prev = {}
        for s in signals:
            with contextlib.suppress(ValueError):    # non-main thread (tests)
                self._prev[s] = signal.signal(s, self._handler)

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def trigger(self):               # for tests / manual drain
        self._stop = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerDetector:
    """EWMA + z-score on step wall-times.

    On a real pod each host reports step time; a controller flags hosts whose
    time is `z_threshold` sigmas above the fleet EWMA and triggers hot-spare
    swap (the paper's remedy for PS imbalance is re-partitioning — same
    signal). Here it watches the single-process step time and exposes the
    flag + history for the loop/tests.
    """

    def __init__(self, window: int = 50, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.window = window
        self.z_threshold = z_threshold
        self.warmup = warmup
        self.times: collections.deque[float] = collections.deque(maxlen=window)
        self.flagged_steps: list[int] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Returns True when this step is a straggler."""
        import numpy as np
        is_straggler = False
        if len(self.times) >= self.warmup:
            mean = float(np.mean(self.times))
            std = float(np.std(self.times)) + 1e-9
            if (seconds - mean) / std > self.z_threshold:
                is_straggler = True
                self.flagged_steps.append(self._step)
        self.times.append(seconds)
        self._step += 1
        return is_straggler


class StepTimer:
    def __init__(self):
        self.t0 = time.monotonic()

    def lap(self) -> float:
        now = time.monotonic()
        dt = now - self.t0
        self.t0 = now
        return dt


def run_resilient_loop(step_fn: Callable, n_steps: int,
                       checkpoint_cb: Callable[[int], None],
                       checkpoint_every: int,
                       preemption: PreemptionHandler | None = None,
                       straggler: StragglerDetector | None = None,
                       on_straggler: Callable[[int], None] | None = None,
                       start_step: int = 0) -> int:
    """Generic resilient loop driver; returns the last completed step.

    step_fn(step) performs one train step (device sync included).
    """
    timer = StepTimer()
    step = start_step
    while step < n_steps:
        step_fn(step)
        dt = timer.lap()
        if straggler is not None and straggler.record(dt) and on_straggler:
            on_straggler(step)
        step += 1
        if step % checkpoint_every == 0:
            checkpoint_cb(step)
        if preemption is not None and preemption.should_stop:
            checkpoint_cb(step)
            break
    return step
