"""Fault injection + crash-consistent recovery (docs/fault_tolerance.md).

At thousands of nodes (the scale the paper's fleet data comes from),
*something* is always failing: the training loop treats preemption as a
normal event (checkpoint-now + clean exit, resumable), watches per-step host
time for stragglers (the paper's section VII cites tail-at-scale and
CPR-style partial recovery), and can resume the SAME global state on a
DIFFERENT mesh shape (checkpoint.py restore with new shardings; elastic
table-wise re-pack below).

This module holds the whole resilience stack:

  * `FaultInjector` — deterministic, seed-driven fault schedules fired at
    named hook points (`pipeline.batch`, `cache.fetch`, `checkpoint.write`,
    `loop.step`, plus the serving-side `serve.fetch` / `serve.admit`)
    threaded through data/pipeline.py, core/cache.py, train/checkpoint.py
    and serve/dlrm_engine.py. Faults: reader-thread death, transient
    capacity-fetch error, fetch latency spike, torn checkpoint leaf,
    preemption at step k, simulated host loss.
  * `RetryPolicy` — bounded retry-with-backoff for transient fetch faults
    (consumed inside core/cache.py's fetch paths, duck-typed so core never
    imports train).
  * `DegradationManager` — the async -> strict_sync degradation state
    machine: demote after N consecutive async failures, promote back after
    a clean window (both paths are bit-identical, only the schedule
    changes, so degradation never perturbs numerics).
  * `TrainState` + save/restore helpers — params, optimizer state, cache
    tier `state_dict`, pipeline cursor and RNG checkpointed as ONE atomic
    unit (per-leaf CRCs live in the manifest, checkpoint.py).
  * `run_resilient_loop` / `run_chaos_loop` — the chaos soak drivers; the
    invariant (any fault schedule => final losses identical to the
    fault-free run) is asserted in tests/test_chaos.py.
  * `elastic_tablewise_repack` — host-loss recovery for table_wise
    placements: re-run the bin-pack for the surviving owner count and
    re-scatter restored rows under the new placement.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import signal
import threading
import time
from collections.abc import Callable
from typing import Any

import numpy as np

# -- fault taxonomy ---------------------------------------------------------

#: hook points a FaultSpec can target (call sites fire these by name).
#: `serve.fetch` guards the serving tier's capacity fetches and
#: `serve.admit` its admission path (serve/dlrm_engine.py); `bulk.fetch`
#: guards the bulk-tier promotion reads (core/tiers.py).
SITES = ("pipeline.batch", "cache.fetch", "bulk.fetch", "checkpoint.write",
         "loop.step", "serve.fetch", "serve.admit")

#: raising kinds ("error"/"kill") throw at the hook point; cooperative kinds
#: ("latency"/"torn"/"preempt"/"host_loss") return the spec for the call
#: site to interpret
KINDS = ("error", "kill", "latency", "torn", "preempt", "host_loss")


class InjectedFault(RuntimeError):
    """Base class for faults raised by `FaultInjector.fire`."""

    transient = False


class TransientFetchFault(InjectedFault):
    """Retryable capacity-fetch failure (storage hiccup / RPC timeout).

    Carries `transient = True`, which is what core/cache.py's retry guard
    keys on (duck-typed: core never imports this module)."""

    transient = True


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault: fire `kind` at the `at`-th call of `site`.

    `at` is a 0-based per-site call counter over the injector's lifetime
    (for `pipeline.batch` with a fresh pipeline from step 0 it coincides
    with the batch step; for `cache.fetch` it counts fetch dispatches).
    `arg` is kind-specific: latency seconds, torn leaf index, lost host."""

    site: str
    at: int
    kind: str = "error"
    arg: float | int | None = None
    fired: bool = False


class FaultInjector:
    """Deterministic fault-schedule registry.

    Call sites invoke `fire(site)`; the injector matches the site's call
    counter against the schedule. Raising kinds throw (`error` ->
    TransientFetchFault on the fetch/admit sites (`cache.fetch`,
    `serve.fetch`, `serve.admit`), InjectedFault elsewhere; `kill`
    -> SystemExit, the reader-thread death). Cooperative kinds return the
    FaultSpec for the call site to act on (`torn` -> checkpoint leaf
    corruption, `preempt` -> SIGTERM-equivalent stop, `host_loss` ->
    elastic re-pack) — and `latency` sleeps in place. Thread-safe: the
    pipeline reader thread and the train loop share one injector.
    """

    def __init__(self, schedule: list[FaultSpec] | tuple[FaultSpec, ...] = ()):
        self.schedule = list(schedule)
        for s in self.schedule:
            if s.site not in SITES:
                raise ValueError(f"unknown fault site {s.site!r}")
            if s.kind not in KINDS:
                raise ValueError(f"unknown fault kind {s.kind!r}")
        self.calls: collections.Counter = collections.Counter()
        self.fired: list[tuple[str, int, str]] = []
        self._lock = threading.Lock()

    @classmethod
    def from_seed(cls, seed: int, n_steps: int,
                  sites: tuple[str, ...] = ("pipeline.batch", "cache.fetch",
                                            "loop.step"),
                  n_faults: int = 3) -> FaultInjector:
        """Seed-driven schedule: `n_faults` faults over `n_steps` calls,
        each at a random site with a site-appropriate random kind. Same
        seed => same schedule (the chaos tests' determinism contract)."""
        kinds = {"pipeline.batch": ("kill", "error"),
                 "cache.fetch": ("error", "latency"),
                 "bulk.fetch": ("error", "latency"),
                 "checkpoint.write": ("torn",),
                 "loop.step": ("preempt",),
                 "serve.fetch": ("error", "latency"),
                 "serve.admit": ("error",)}
        rng = np.random.RandomState(seed)
        seen: set[tuple[str, int]] = set()
        sched: list[FaultSpec] = []
        while len(sched) < n_faults:
            site = sites[int(rng.randint(len(sites)))]
            opts = kinds[site]
            kind = opts[int(rng.randint(len(opts)))]
            at = int(rng.randint(1, max(n_steps, 2)))
            if (site, at) in seen:
                continue
            seen.add((site, at))
            arg = 0.002 if kind == "latency" else None
            sched.append(FaultSpec(site, at, kind, arg))
        sched.sort(key=lambda s: (s.site, s.at))
        return cls(sched)

    def fire(self, site: str, **ctx) -> FaultSpec | None:
        """Advance `site`'s call counter; raise or return the matching
        scheduled fault (None when nothing is due). `ctx` is recorded on
        cooperative specs for debugging (e.g. step=...)."""
        with self._lock:
            at = self.calls[site]
            self.calls[site] += 1
            spec = next((s for s in self.schedule
                         if not s.fired and s.site == site and s.at == at),
                        None)
            if spec is None:
                return None
            spec.fired = True
            self.fired.append((site, at, spec.kind))
        if spec.kind == "latency":
            time.sleep(float(spec.arg or 0.002))
            return spec
        if spec.kind == "error":
            if site in ("cache.fetch", "bulk.fetch", "serve.fetch",
                        "serve.admit"):
                raise TransientFetchFault(
                    f"injected transient fetch fault at {site}[{at}]")
            raise InjectedFault(f"injected fault at {site}[{at}]")
        if spec.kind == "kill":
            raise SystemExit(f"injected kill at {site}[{at}]")
        return spec            # cooperative: torn / preempt / host_loss


# -- retry + degradation ----------------------------------------------------


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry-with-backoff for transient fetch faults. Consumed by
    core/cache.py's fetch guard (duck-typed: `max_retries` + `sleep`)."""

    max_retries: int = 3
    backoff_s: float = 1e-3
    multiplier: float = 2.0
    max_backoff_s: float = 0.05

    def sleep(self, attempt: int) -> None:
        """Exponential backoff before retry number `attempt` (1-based)."""
        time.sleep(min(self.backoff_s * self.multiplier ** (attempt - 1),
                       self.max_backoff_s))


class DegradationManager:
    """The async -> strict_sync degradation state machine.

    After `demote_after` CONSECUTIVE async-path failures (transient fetch
    faults that exhausted their retries), `mode` flips to "strict_sync":
    the driver stops staging next batches, so every batch plans + commits
    inside its own step — no overlap to lose to a flaky capacity tier.
    After `promote_after` consecutive clean steps it flips back. Both
    schedules are bit-identical (tests/test_cache_async.py), so the state
    machine trades throughput for stability without touching numerics.
    """

    def __init__(self, demote_after: int = 2, promote_after: int = 4):
        self.demote_after = demote_after
        self.promote_after = promote_after
        self.mode = "async"
        self.demotions = 0
        self.promotions = 0
        self.transitions: list[tuple[str, int]] = []   # (mode, event count)
        self._failures = 0
        self._clean = 0
        self._events = 0

    @property
    def degraded(self) -> bool:
        """True while the strict_sync fallback schedule is active."""
        return self.mode == "strict_sync"

    def record_failure(self) -> None:
        """One async-path failure (retries exhausted)."""
        self._events += 1
        self._failures += 1
        self._clean = 0
        if self.mode == "async" and self._failures >= self.demote_after:
            self.mode = "strict_sync"
            self.demotions += 1
            self.transitions.append(("strict_sync", self._events))

    def record_success(self) -> None:
        """One clean step in the current mode."""
        self._events += 1
        self._failures = 0
        if self.mode == "strict_sync":
            self._clean += 1
            if self._clean >= self.promote_after:
                self.mode = "async"
                self.promotions += 1
                self._clean = 0
                self.transitions.append(("async", self._events))


# -- atomic TrainState bundle ----------------------------------------------


@dataclasses.dataclass
class TrainState:
    """Everything a resumed run needs, checkpointed as ONE atomic unit:
    dense params, dense optimizer state, the cache tier's `state_dict`
    (device slabs + host slot maps + EMA counters + stats, PR 7), the
    pipeline cursor (next step to run — ShardedLoader/synthetic batches
    are deterministic per step, so the cursor IS the data state), and an
    optional host RNG state. A params-only checkpoint cannot resume the
    cached tiers bit-exactly (accumulators live per-slot while a row is
    cached), which is why the bundle exists."""

    params: Any
    opt_state: Any
    cache: Any = None
    step: int = 0
    rng: Any = None

    def tree(self) -> dict:
        """The checkpointable pytree (numpy/jax leaves only)."""
        t = {"params": self.params, "opt": self.opt_state,
             "cursor": np.int64(self.step)}
        if self.cache is not None:
            t["cache"] = self.cache
        if self.rng is not None:
            t["rng"] = np.asarray(self.rng)
        return t


def save_train_state(mgr, state: TrainState, async_: bool = False) -> None:
    """Checkpoint the bundle at its cursor step (atomic + CRC'd leaves)."""
    mgr.save(state.step, state.tree(), async_=async_)


def restore_train_state(mgr, example: TrainState, step: int | None = None,
                        shardings=None) -> TrainState:
    """Restore the bundle; `example` fixes the tree structure (fresh
    params/opt/cache state_dict from the restarting job). With step=None
    the manager falls back past corrupt checkpoints to the newest intact
    one (mgr.last_restored_step says which)."""
    tree = mgr.restore(example.tree(), step=step, shardings=shardings)
    return TrainState(params=tree["params"], opt_state=tree["opt"],
                      cache=tree.get("cache"), step=int(tree["cursor"]),
                      rng=None if "rng" not in tree
                      else np.asarray(tree["rng"]))


# -- elastic table-wise restore --------------------------------------------


def elastic_tablewise_repack(cfg, old_ebc, mega, accum, n_shards_new: int):
    """Host-loss recovery for a table_wise placement: re-run the
    `plan_placement` LPT bin-pack for the surviving `n_shards_new` owners
    and re-scatter the restored mega/accum rows under the new placement.

    Row renumbering does not change the math — per-bag pooling order and
    per-row AdaGrad are invariant under a permutation of global row ids —
    so a repacked run's losses are bit-equal to the uninterrupted one
    (tests/test_chaos.py). Returns (new_ebc, new_mega, new_accum); batches
    must be re-offset with the NEW collection's `offset_indices`.
    """
    import jax.numpy as jnp

    from repro.core.embedding import EmbeddingBagCollection
    from repro.core.placement import elastic_table_remap

    new_ebc = EmbeddingBagCollection.build(cfg, n_shards=n_shards_new,
                                           strategy="table_wise")
    src, dst = elastic_table_remap(old_ebc.plan, new_ebc.plan,
                                   cfg.hash_sizes)
    mega = jnp.asarray(mega)
    accum = jnp.asarray(accum)
    new_mega = jnp.zeros((new_ebc.plan.total_rows, mega.shape[1]),
                         mega.dtype).at[jnp.asarray(dst)].set(
        mega[jnp.asarray(src)])
    new_accum = jnp.zeros((new_ebc.plan.total_rows,),
                          accum.dtype).at[jnp.asarray(dst)].set(
        accum[jnp.asarray(src)])
    return new_ebc, new_mega, new_accum


# -- preemption / stragglers ------------------------------------------------


class PreemptionHandler:
    """SIGTERM/SIGINT -> checkpoint-now flag. The train loop polls
    `should_stop` each step and exits through the checkpoint path."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = False
        self._prev = {}
        for s in signals:
            with contextlib.suppress(ValueError):    # non-main thread (tests)
                self._prev[s] = signal.signal(s, self._handler)

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        """True once a preemption signal (or `trigger`) has fired."""
        return self._stop

    def trigger(self):
        """Raise the stop flag in-process (tests / manual drain)."""
        self._stop = True

    def clear(self):
        """Re-arm after a handled preemption (simulated-restart drivers)."""
        self._stop = False

    def restore(self):
        """Reinstall the signal handlers this handler displaced."""
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerDetector:
    """EWMA + z-score on step wall-times.

    On a real pod each host reports step time; a controller flags hosts whose
    time is `z_threshold` sigmas above the fleet EWMA and triggers hot-spare
    swap (the paper's remedy for PS imbalance is re-partitioning — same
    signal). Here it watches the single-process step time and exposes the
    flag + history for the loop/tests.
    """

    def __init__(self, window: int = 50, z_threshold: float = 3.0,
                 warmup: int = 5):
        self.window = window
        self.z_threshold = z_threshold
        self.warmup = warmup
        self.times: collections.deque[float] = collections.deque(maxlen=window)
        self.flagged_steps: list[int] = []
        self._step = 0

    def record(self, seconds: float) -> bool:
        """Returns True when this step is a straggler."""
        is_straggler = False
        if len(self.times) >= self.warmup:
            mean = float(np.mean(self.times))
            std = float(np.std(self.times)) + 1e-9
            if (seconds - mean) / std > self.z_threshold:
                is_straggler = True
                self.flagged_steps.append(self._step)
        self.times.append(seconds)
        self._step += 1
        return is_straggler


class StepTimer:
    """Monotonic lap timer for per-step wall times."""

    def __init__(self):
        self.t0 = time.monotonic()

    def lap(self) -> float:
        """Seconds since construction or the previous lap."""
        now = time.monotonic()
        dt = now - self.t0
        self.t0 = now
        return dt


# -- loop drivers -----------------------------------------------------------


def run_resilient_loop(step_fn: Callable, n_steps: int,
                       checkpoint_cb: Callable[[int], None],
                       checkpoint_every: int,
                       preemption: PreemptionHandler | None = None,
                       straggler: StragglerDetector | None = None,
                       on_straggler: Callable[[int], None] | None = None,
                       start_step: int = 0,
                       injector: FaultInjector | None = None) -> int:
    """Generic resilient loop driver; returns the last completed step.

    step_fn(step) performs one train step (device sync included). A
    preemption coinciding with a scheduled checkpoint saves ONCE (the
    scheduled save already covers the step). `injector` fires the
    "loop.step" site before each step; a "preempt" spec triggers the
    preemption handler exactly as a SIGTERM would.
    """
    timer = StepTimer()
    step = start_step
    while step < n_steps:
        if injector is not None and preemption is not None:
            spec = injector.fire("loop.step", step=step)
            if spec is not None and spec.kind == "preempt":
                preemption.trigger()
        step_fn(step)
        dt = timer.lap()
        if straggler is not None and straggler.record(dt) and on_straggler:
            on_straggler(step)
        step += 1
        saved = False
        if step % checkpoint_every == 0:
            checkpoint_cb(step)
            saved = True
        if preemption is not None and preemption.should_stop:
            if not saved:
                checkpoint_cb(step)
            break
    return step


def _recoverable(e: BaseException) -> bool:
    """Faults the chaos driver restores from: anything flagged transient,
    injected faults, and pipeline/runtime failures (a dead reader surfaces
    as RuntimeError). Programming errors (ValueError etc.) propagate."""
    return getattr(e, "transient", False) or isinstance(e, RuntimeError)


@dataclasses.dataclass
class ChaosReport:
    """What a `run_chaos_loop` soak actually did."""

    last_step: int = 0
    restarts: int = 0
    degraded_steps: int = 0
    recovery_s: list = dataclasses.field(default_factory=list)


def run_chaos_loop(step_fn: Callable[[int], None], n_steps: int, *,
                   save_cb: Callable[[int], None],
                   restore_cb: Callable[[], int],
                   checkpoint_every: int = 10,
                   preemption: PreemptionHandler | None = None,
                   injector: FaultInjector | None = None,
                   degradation: DegradationManager | None = None,
                   max_restarts: int = 8) -> ChaosReport:
    """Chaos soak driver: run to `n_steps` through any recoverable fault.

    `step_fn(step)` runs one step and may raise (injected transients that
    exhausted their retries, reader-thread death, torn state...).
    `save_cb(step)` checkpoints the TrainState bundle AFTER `step` steps;
    `restore_cb()` rebuilds the whole job from the newest intact
    checkpoint — params, optimizer, cache tier, pipeline — and returns the
    step to resume from (0 when nothing is saved yet). On a recoverable
    failure the driver restores and replays; replayed steps recompute
    identical losses (synthetic batches are deterministic per step and the
    bundle is bit-exact), which is the chaos invariant tests assert. A
    preemption saves (once) and then simulates the restart in-process:
    clear the flag, restore, continue. `degradation` is notified of
    failures/successes so the caller's step_fn can consult `.mode`.
    """
    rep = ChaosReport()
    step = restore_cb()
    while step < n_steps:
        if injector is not None:
            spec = injector.fire("loop.step", step=step)
            if (spec is not None and spec.kind == "preempt"
                    and preemption is not None):
                preemption.trigger()
        try:
            step_fn(step)
        except Exception as e:
            if not _recoverable(e) or rep.restarts >= max_restarts:
                raise
            if degradation is not None and getattr(e, "transient", False):
                degradation.record_failure()
            rep.restarts += 1
            t0 = time.monotonic()
            step = restore_cb()
            rep.recovery_s.append(time.monotonic() - t0)
            continue
        if degradation is not None:
            degradation.record_success()
            if degradation.degraded:
                rep.degraded_steps += 1
        step += 1
        saved = False
        if checkpoint_every and step % checkpoint_every == 0:
            save_cb(step)
            saved = True
        if preemption is not None and preemption.should_stop:
            if not saved:
                save_cb(step)
            preemption.clear()
            rep.restarts += 1
            t0 = time.monotonic()
            step = restore_cb()
            rep.recovery_s.append(time.monotonic() - t0)
    rep.last_step = step
    return rep
