"""Train-step builders: the jitted SPMD functions the launcher lowers/runs.

LM path: AdamW on all params, optional gradient accumulation (microbatching)
via lax.scan over grad chunks — the batch-size lever of paper section V-B
without blowing activation memory.

DLRM path (the paper's split, Fig. 4): dense params via dense AdaGrad,
embedding mega-table via deduplicated row-wise AdaGrad fed with
(indices, pooled-gradients) — no dense gradient for the table is ever
materialized. Both optimizers run inside one jit so XLA overlaps the
embedding-update scatter with the dense backward's collectives.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig, ModelConfig
from repro.core.cache import (CachedEmbeddingBagCollection,
                              MultiHostCachedEmbeddingBagCollection)
from repro.core.dlrm import _bce, dlrm_forward_dense, dlrm_grads
from repro.core.embedding import EmbeddingBagCollection
from repro.core.tiers import AsyncCachedTier, EmbeddingTier
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kref
from repro.kernels.sparse_plan import (build_sparse_plan_host,
                                       host_plan_from_batch,
                                       host_plans_from_batch,
                                       plan_from_batch,
                                       split_plan_by_owner)
from repro.models.lm import lm_loss
from repro.nn.sharding import (TRAIN_RULES, LogicalRules,
                               _live_mesh_axis_names)
from repro.optim.optimizers import Optimizer


def _constrain(x, pspec):
    """with_sharding_constraint that no-ops outside a mesh context."""
    if not _live_mesh_axis_names():
        return x
    return jax.lax.with_sharding_constraint(x, pspec)

# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------


def build_lm_train_step(cfg: ModelConfig, opt: Optimizer,
                        rules: LogicalRules = TRAIN_RULES,
                        accum_steps: int = 1,
                        grad_dtype: str = "float32") -> Callable:
    """Returns step(params, opt_state, batch, step_idx) ->
    (params, opt_state, metrics).

    grad_dtype="bfloat16" casts gradients before the cross-shard reduction
    (the ZeRO reduce-scatter / DP all-reduce moves half the bytes; fp32
    moments in the optimizer absorb the rounding — standard mixed-precision
    practice and the paper-era bandwidth lever, DESIGN.md section 5)."""

    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg, rules)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, parts), grads = grad_fn(params, batch)
        return loss, parts, grads

    def accumulated(params, batch):
        # split the batch into accum_steps chunks along the batch dim
        def chunk(i, x):
            size = x.shape[0] // accum_steps
            return jax.lax.dynamic_slice_in_dim(x, i * size, size, 0)

        def body(carry, i):
            loss_sum, grads_sum = carry
            mb = jax.tree.map(functools.partial(chunk, i), batch)
            (loss, _), grads = grad_fn(params, mb)
            grads_sum = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), grads_sum, grads)
            return (loss_sum + loss, grads_sum), None

        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_grads),
            jnp.arange(accum_steps))
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        return loss_sum * inv, {}, grads

    def step(params, opt_state, batch, step_idx):
        if accum_steps > 1:
            loss, parts, grads = accumulated(params, batch)
        else:
            loss, parts, grads = single(params, batch)
        if grad_dtype == "bfloat16":
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        new_params, new_state = opt.apply(params, grads, opt_state, step_idx)
        metrics = {"loss": loss, **{k: v for k, v in parts.items()}}
        return new_params, new_state, metrics

    return step

# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------


def build_dlrm_train_step(cfg: DLRMConfig, ebc: EmbeddingBagCollection,
                          dense_opt: Optimizer, sparse_lr: float = 0.05,
                          sparse_eps: float = 1e-8, interpret: bool = False,
                          rules: LogicalRules = TRAIN_RULES,
                          sparse_apply: str = "dense") -> Callable:
    """Returns step(params, state, batch, step_idx) -> (params, state,
    metrics) where state = {"dense": dense_opt_state, "accum": (rows,) f32}.

    sparse_apply:
      "dense"  — scatter-add over the (sharded) full row space; right for
                 SPMD where each model shard owns its rows (the PS side).
      "sparse" — dedup to unique rows, update only those: O(lookups) not
                 O(table height); right for single-host runs (matches the
                 paper's flat CPU hash-size curve, Fig. 12). Same math as
                 the Pallas rowwise_adagrad kernel path.
    """

    row_pspec = ebc.plan.pspec                 # (rows, d) mega-table sharding

    def sparse_update_nrows(mega, accum, idx, g_pooled, plan=None):
        """O(n) unique-row apply through the fused sparse backward: the
        index-only bucketing plan (built on device, or ahead of time by
        `data.sparse_plan_hook` in the reader thread) replaces the legacy
        per-lookup broadcast + full-width dedup sort."""
        return kernel_ops.fused_sparse_backward(
            mega, accum, idx, g_pooled, sparse_lr, sparse_eps, plan=plan,
            interpret=interpret)

    def sparse_update_shardmap(mega, accum, idx, g_pooled, plan=None):
        """shard_map PS-side aggregation: each (model, data) shard buckets
        ITS batch slice with the index-only planner, segment-sums the
        POOLED bag grads per locally-owned unique row, scatters the compact
        result into a LOCAL (rows_local, d) buffer (zero collectives), then
        ONE psum over the batch axes merges partials. Replaces the
        feature-scan that broadcast every bag grad to (b, lk, d) per
        feature; the pjit scatter-in-scan alternative additionally
        re-all-reduces the whole gsum buffer per feature (measured 127x the
        traffic — EXPERIMENTS.md Perf, dlrm-m3)."""
        from jax.sharding import PartitionSpec as SP

        from repro.compat import shard_map
        from repro.nn.sharding import _live_mesh
        mesh = _live_mesh()
        h, d = mega.shape
        model_axis = "model"
        batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)
        rows_local = h // mesh.shape[model_axis]

        def local(mega_sh, accum_sh, idx_loc, g_loc):
            shard = jax.lax.axis_index(model_axis)
            lo = shard * rows_local
            b, f, lk = idx_loc.shape
            inside = (idx_loc >= lo) & (idx_loc < lo + rows_local)
            loc = jnp.where(inside, idx_loc - lo, -1)
            lplan = kernel_ops.build_sparse_plan(loc)
            gsum_u = kref.bag_grad_sums(          # (b*f*lk, d) compact sums
                lplan.unique_rows, lplan.bag_offsets, lplan.bag_ids,
                g_loc.reshape(b * f, d))
            drop = jnp.where(lplan.unique_rows >= 0, lplan.unique_rows,
                             rows_local)          # oob -> dropped
            gsum = jnp.zeros((rows_local, d), jnp.float32).at[drop].set(
                gsum_u, mode="drop")
            if cfg.grad_reduce_dtype == "bfloat16":
                gsum = jax.lax.psum(gsum.astype(jnp.bfloat16),
                                    batch_axes).astype(jnp.float32)
            else:
                gsum = jax.lax.psum(gsum, batch_axes)  # ONE merge
            touched = jnp.any(gsum != 0.0, axis=-1)
            g2 = jnp.mean(jnp.square(gsum), axis=-1)
            acc_new = accum_sh + jnp.where(touched, g2, 0.0)
            upd = sparse_lr * gsum * jax.lax.rsqrt(acc_new[:, None]
                                                   + sparse_eps)
            new_mega = mega_sh - jnp.where(touched[:, None], upd,
                                           0.0).astype(mega_sh.dtype)
            return new_mega, acc_new

        return shard_map(
            local, mesh=mesh,
            in_specs=(SP(model_axis, None), SP(model_axis),
                      SP(batch_axes, None, None), SP(batch_axes, None, None)),
            out_specs=(SP(model_axis, None), SP(model_axis)),
        )(mega, accum, idx, g_pooled)

    def sparse_update(mega, accum, idx, g_pooled, plan=None):
        """Row-wise AdaGrad with dedup via scatter-add onto the SHARDED
        row space (same math as kernels/ref.rowwise_adagrad_ref, with
        sharding constraints so the aggregation buffer lives on the
        `model` shards — the PS-side gradient aggregation of section VII).
        The scatter scans over features so the (B, L, d) broadcast of each
        bag's gradient never materializes for all 127 tables at once."""
        h, d = mega.shape
        b, f, lk = idx.shape

        def add_feature(gsum, xs):
            idx_f, g_f = xs                   # (b, lk), (b, d)
            valid = idx_f >= 0
            safe = jnp.where(valid, idx_f, h)
            upd = jnp.broadcast_to(g_f[:, None, :], (b, lk, d))
            upd = jnp.where(valid[..., None], upd, 0.0)
            gsum = gsum.at[safe.reshape(-1)].add(upd.reshape(b * lk, d))
            return gsum, None

        gsum0 = jnp.zeros((h + 1, d), jnp.float32)
        gsum0 = _constrain(gsum0, row_pspec)
        gsum, _ = jax.lax.scan(
            add_feature, gsum0,
            (jnp.swapaxes(idx, 0, 1), jnp.swapaxes(g_pooled, 0, 1)))
        gsum = _constrain(gsum[:h], row_pspec)
        touched = jnp.any(gsum != 0.0, axis=-1)
        g2 = jnp.mean(jnp.square(gsum), axis=-1)
        new_accum = accum + jnp.where(touched, g2, 0.0)
        upd = sparse_lr * gsum * jax.lax.rsqrt(new_accum[:, None]
                                               + sparse_eps)
        new_mega = (mega - jnp.where(touched[:, None], upd, 0.0)
                    .astype(mega.dtype))
        return new_mega, new_accum

    def step(params, state, batch, step_idx):
        loss, g_dense, (idx, g_pooled) = dlrm_grads(
            params, batch, cfg, ebc, interpret, rules)
        new_dense, new_dense_state = dense_opt.apply(
            {"bottom": params["bottom"], "top": params["top"]},
            g_dense, state["dense"], step_idx)
        if sparse_apply == "sparse":
            apply_fn = sparse_update_nrows
        elif cfg.lookup_impl == "psum":
            apply_fn = sparse_update_shardmap
        else:
            apply_fn = sparse_update
        # a plan attached by data.sparse_plan_hook (built in the reader
        # thread, overlapping the previous step's compute) short-circuits
        # the on-device bucketing of the fused nrows path
        new_mega, new_accum = apply_fn(
            params["emb"]["mega"], state["accum"], idx, g_pooled,
            plan_from_batch(batch))
        new_params = {**new_dense, "emb": {"mega": new_mega}}
        new_state = {"dense": new_dense_state, "accum": new_accum}
        lookups = jnp.sum(batch["idx"] >= 0).astype(jnp.float32)
        return new_params, new_state, {"loss": loss, "lookups": lookups}

    return step


def dlrm_init_state(ebc: EmbeddingBagCollection, dense_opt: Optimizer,
                    params: dict) -> dict:
    """Optimizer state bundle for the uncached DLRM step (dense + mega)."""
    return {
        "dense": dense_opt.init({"bottom": params["bottom"],
                                 "top": params["top"]}),
        "accum": jnp.zeros((ebc.plan.total_rows,), jnp.float32),
    }

# ---------------------------------------------------------------------------
# DLRM with the cached embedding tier (core/cache.py)
# ---------------------------------------------------------------------------


def _build_cached_inner(cfg: DLRMConfig, cc, dense_opt: Optimizer,
                        sparse_lr: float, sparse_eps: float,
                        interpret: bool, rules: LogicalRules) -> Callable:
    """Jitted device half shared by the sync and async cached steps:
    forward/backward/update entirely against the (donated) cache slab. A
    slot-relabelled plan in the batch (`CachedEmbeddingBagCollection.
    plan_to_slots`) is consumed TWICE here: the forward's lookup dedups its
    slab gather through it (via `dlrm_grads` -> `ebc.lookup(plan=...)`) and
    the fused bag backward buckets by it — the bucketing sort never runs on
    the device."""

    def inner(dense_params, dense_state, cache, cache_accum, batch, step_idx):
        params = {**dense_params, "emb": {"mega": cache}}
        loss, g_dense, (idx, g_pooled) = dlrm_grads(
            params, batch, cfg, cc.ebc, interpret, rules)
        new_dense, new_dense_state = dense_opt.apply(
            dense_params, g_dense, dense_state, step_idx)
        new_cache, new_accum = kernel_ops.fused_sparse_backward(
            cache, cache_accum, idx, g_pooled, sparse_lr, sparse_eps,
            plan=plan_from_batch(batch), use_kernel=cc.use_kernel,
            interpret=interpret)
        lookups = jnp.sum(batch["idx"] >= 0).astype(jnp.float32)
        return (new_dense, new_dense_state, new_cache, new_accum,
                {"loss": loss, "lookups": lookups})

    return jax.jit(inner, donate_argnums=(2, 3))


def _build_sync_cached_step(cfg: DLRMConfig, cc, dense_opt: Optimizer,
                            sparse_lr: float, sparse_eps: float,
                            interpret: bool, rules: LogicalRules) -> Callable:
    """Sync-schedule half of `build_cached_train_step` (the cached_host
    tier consumed through the `EmbeddingTier` protocol).

    Split execution: the HOST half (tier.take) makes the batch's rows
    cache-resident and remaps indices to slot space; the jitted DEVICE half
    then runs forward/backward/update entirely against the small cache
    array — per-step device cost scales with cache_rows, not table height.
    Row-wise AdaGrad updates land on cached rows (slots were marked dirty
    by take) and reach the capacity tier on eviction or flush.

    Returns step(params, state, cache_state, batch, step_idx,
    next_batch=None) -> (params, state, metrics) where params = {"bottom",
    "top"} (dense only — the embedding lives in cache_state), state =
    {"dense": ...}, and batch carries OFFSET global indices. Pass the
    pipeline's upcoming batch as `next_batch`: its "uniq_rows" (attached by
    data.dedup_indices_hook in the reader thread) are admitted AFTER the
    device work is dispatched, so the capacity-tier fetch overlaps compute.
    """

    inner_jit = _build_cached_inner(cfg, cc, dense_opt, sparse_lr,
                                    sparse_eps, interpret, rules)

    def step(params, state, cache_state, batch, step_idx, next_batch=None):
        # a hook-attached plan feeds the miss planner too (its live prefix
        # IS the sorted unique row set) — the np.unique re-sort is gone
        local = cc.take(cache_state, batch["idx"], train=True,
                        plan=host_plan_from_batch(batch))
        dev_batch = {**batch, "idx": jnp.asarray(local)}
        dev_batch.pop("uniq_rows", None)
        if "plan_rows" in batch:
            # the reader thread's bucketing plan is in global row space; the
            # batch's rows are all resident after take, so a cheap host
            # relabel (row -> slot) carries it onto the cache slab
            dev_batch.update(cc.plan_to_slots(cache_state, batch))
        new_dense, new_dense_state, new_cache, new_accum, metrics = inner_jit(
            params, state["dense"], cache_state.cache,
            cache_state.cache_accum, dev_batch, step_idx)
        cc.mark_updated(cache_state, new_cache, new_accum)
        if next_batch is not None and "uniq_rows" in next_batch:
            # the jitted step above is dispatched asynchronously — admitting
            # the next batch's rows here overlaps fetch with device compute
            cc.prefetch_rows(cache_state, next_batch["uniq_rows"])
        metrics = {**metrics, **cc.stats(cache_state).snapshot()}
        return new_dense, {"dense": new_dense_state}, metrics

    return step


def cached_dlrm_init_state(cc, dense_opt: Optimizer, params: dict) -> dict:
    """Dense-only optimizer state; the sparse accumulator lives in the
    CacheState tiers (cap_accum / cache_accum)."""
    return {"dense": dense_opt.init({"bottom": params["bottom"],
                                     "top": params["top"]})}


def _build_async_cached_step(cfg: DLRMConfig, tier: AsyncCachedTier,
                             dense_opt: Optimizer, sparse_lr: float,
                             sparse_eps: float, interpret: bool,
                             rules: LogicalRules,
                             strict_sync: bool) -> Callable:
    """Overlapped half of `build_cached_train_step`: batch k+1's
    capacity-tier fetch runs while batch k's dense forward/backward
    executes (docs/cache.md "Async fetch stream"). Per call:

      1. `tier.take` — batch k's staged plan (made during step k-1) is
         popped and every pending shadow fetch COMMITS: a cheap on-device
         row swap, dispatched after batch k-1's update so dirty-victim
         writebacks carry post-update values.
      2. the jitted device half runs against the committed cache slab;
      3. `tier.stage(next_batch)` — batch k+1's miss rows start fetching
         into a fresh shadow slab, off the critical path;
      4. optional `prefetch_rows` (k-step pipeline lookahead, see
         data.lookahead_rows) are queued best-effort behind it.

    `strict_sync=True` is the fallback flag: every batch is planned and
    committed inside its own step (no overlap, no staged state) — the
    behaviour is bit-identical either way (asserted in
    tests/test_cache_async.py), only the schedule changes.

    Returns step(params, state, astate, batch, step_idx, next_batch=None,
    prefetch_rows=None) -> (params, state, metrics); astate is an
    AsyncCacheState from `tier.init_state`; batch carries OFFSET global
    indices (e.g. from data.dedup_indices_hook).
    """

    inner_jit = _build_cached_inner(cfg, tier.cc, dense_opt, sparse_lr,
                                    sparse_eps, interpret, rules)

    def step(params, state, astate, batch, step_idx, next_batch=None,
             prefetch_rows=None):
        local = tier.take(astate, batch["idx"], train=True,
                          plan=host_plan_from_batch(batch))
        dev_batch = {**batch, "idx": jnp.asarray(local)}
        dev_batch.pop("uniq_rows", None)
        if "plan_rows" in batch:
            dev_batch.update(tier.plan_to_slots(astate, batch))
        new_dense, new_dense_state, new_cache, new_accum, metrics = inner_jit(
            params, state["dense"], astate.cache, astate.cache_accum,
            dev_batch, step_idx)
        tier.mark_updated(astate, new_cache, new_accum)
        # snapshot BEFORE staging batch k+1 so step k's metrics cover only
        # batches that ran — identical between overlapped and strict_sync
        # schedules (the point of the fallback flag is A/B comparison)
        metrics = {**metrics, **tier.stats(astate).snapshot()}
        if not strict_sync and next_batch is not None:
            # dispatched after the jitted step: the fetch only READS the
            # tiers, so it overlaps the in-flight compute; its commit waits
            # for the next step boundary
            tier.stage(astate, next_batch["idx"], train=True,
                       plan=host_plan_from_batch(next_batch))
        if not strict_sync and prefetch_rows is not None:
            tier.prefetch_rows(astate, prefetch_rows)
        return new_dense, {"dense": new_dense_state}, metrics

    return step


# ---------------------------------------------------------------------------
# DLRM with the multi-host cached tier (docs/cache.md "Multi-host coherence")
# ---------------------------------------------------------------------------


def _build_multihost_cached_step(cfg: DLRMConfig, mc,
                                 dense_opt: Optimizer,
                                 sparse_lr: float, sparse_eps: float,
                                 interpret: bool, rules: LogicalRules,
                                 strict_sync: bool, mesh,
                                 host_axis: str) -> Callable:
    """Multi-host half of `build_cached_train_step`
    (`MultiHostCachedEmbeddingBagCollection`): H hosts each
    run a hot cache over a capacity tier row-sharded across the same hosts.

    Split execution per step (docs/cache.md):
      HOST   `mc.plan_step` — per-host hit/miss split off the reader
             thread's sub-plans, LFU admission, owner grouping (the
             plan-driven all-to-all worklist), stale-copy invalidation;
      DEVICE one jitted dispatch: (1) install planned misses from the
             owning shards, (2) per-host pooled lookup against the slabs,
             concatenated back to the global batch for the dense
             forward/backward, (3) the ROUTED sparse update — per-owner
             segments of the global plan, each owner reducing duplicate
             rows once in host order before its fused AdaGrad apply
             (shard_map over `mesh`'s host axis when given, the segmented
             single-launch kernel otherwise), (4) refresh each host's
             working set from the post-update capacity.

    The batch split (host h owns examples [h*B/H, (h+1)*B/H)) makes owner
    reduction order == flat-batch order, so the tier is BIT-EXACT vs the
    dense single-host oracle — and on 1 host vs the single-host cached
    path (tests/test_cache_multihost.py).

    `strict_sync=True` disables the only overlapped piece (the next-batch
    prefetch); results are bit-identical either way. Returns step(params,
    state, mstate, batch, step_idx, next_batch=None) -> (params, state,
    metrics); batch carries OFFSET global indices and, optionally, the
    hook-attached plan artifacts (`data.sparse_plan_hook(n_hosts=H)`)."""

    hn = mc.n_hosts
    ebc = mc.ebc

    def inner(dense_params, dense_state, capacity, cap_accum, caches, dev,
              step_idx):
        # 1) the fetch all-to-all: planned misses leave the owning shards
        #    (mc.fill_slabs is the SAME install the eager eval/prefetch
        #    paths run — one operation, traced here)
        caches = mc.fill_slabs(caches, capacity, dev["miss_rows"],
                               dev["miss_slots"])
        # 2) per-host pooled lookups, concatenated to the global batch —
        #    pooling is per-example, so this is bitwise the oracle's lookup
        pooled = jnp.concatenate(
            [ebc.lookup({"mega": caches[h]}, dev["local_idx"][h], rules)
             for h in range(hn)], axis=0)

        def loss_fn(dp, pl_):
            logits = dlrm_forward_dense({**dp, "emb": None}, dev["dense"],
                                        pl_, cfg, interpret)
            return _bce(logits, dev["label"])

        loss, (g_dense, g_pooled) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(dense_params, pooled)
        new_dense, new_dense_state = dense_opt.apply(
            dense_params, g_dense, dense_state, step_idx)
        pooled2 = g_pooled.astype(jnp.float32).reshape(-1, caches.shape[-1])
        # 3) the routed update: per-owner segments, duplicates reduced once
        if mesh is not None:
            from jax.sharding import PartitionSpec as SP

            from repro.compat import shard_map

            def owner_update(cap_sh, acc_sh, rows_sh, offs_sh, bags, g2):
                return kernel_ops.fused_sparse_backward_segments(
                    cap_sh, acc_sh, rows_sh, offs_sh, bags, g2, sparse_lr,
                    eps=sparse_eps, use_kernel=mc.use_kernel,
                    interpret=interpret)

            new_cap, new_acc = shard_map(
                owner_update, mesh=mesh,
                in_specs=(SP(host_axis, None), SP(host_axis),
                          SP(host_axis, None), SP(host_axis, None),
                          SP(None), SP(None, None)),
                out_specs=(SP(host_axis, None), SP(host_axis)),
                check_vma=False,
            )(capacity, cap_accum, dev["seg_rows"], dev["seg_offsets"],
              dev["bag_ids"], pooled2)
        else:
            new_cap, new_acc = kernel_ops.fused_sparse_backward_segments(
                capacity, cap_accum, dev["seg_rows"], dev["seg_offsets"],
                dev["bag_ids"], pooled2, sparse_lr,
                seg_base=dev["seg_base"], eps=sparse_eps,
                use_kernel=mc.use_kernel, interpret=interpret)
        # 4) the return all-to-all: refresh working sets post-update so
        #    every cached copy a host will hit again is current
        caches = mc.fill_slabs(caches, new_cap, dev["ws_rows"],
                               dev["ws_slots"])
        lookups = jnp.sum(dev["local_idx"] >= 0).astype(jnp.float32)
        return (new_dense, new_dense_state, new_cap, new_acc, caches,
                {"loss": loss, "lookups": lookups})

    inner_jit = jax.jit(inner, donate_argnums=(2, 3, 4))

    def step(params, state, mstate, batch, step_idx, next_batch=None):
        splan = mc.plan_step(mstate, batch["idx"],
                             host_plans=host_plans_from_batch(batch),
                             global_plan=host_plan_from_batch(batch),
                             train=True)
        dev = {"dense": jnp.asarray(batch["dense"]),
               "label": jnp.asarray(batch["label"]),
               "local_idx": jnp.asarray(splan.local_idx),
               "miss_rows": jnp.asarray(splan.miss_rows),
               "miss_slots": jnp.asarray(splan.miss_slots),
               "ws_rows": jnp.asarray(splan.ws_rows),
               "ws_slots": jnp.asarray(splan.ws_slots),
               "seg_rows": jnp.asarray(splan.seg_rows),
               "seg_offsets": jnp.asarray(splan.seg_offsets),
               "seg_base": jnp.asarray(splan.seg_base),
               "bag_ids": jnp.asarray(splan.bag_ids)}
        (new_dense, new_dense_state, new_cap, new_acc, new_caches,
         metrics) = inner_jit(params, state["dense"], mstate.capacity,
                              mstate.cap_accum, mstate.caches, dev,
                              step_idx)
        mc.mark_updated(mstate, new_cap, new_acc, new_caches)
        # snapshot BEFORE the prefetch so step metrics cover run batches
        metrics = {**metrics, **mc.stats(mstate).snapshot(),
                   **mstate.route.snapshot()}
        if not strict_sync and next_batch is not None:
            # dispatched after the jitted step: the gather consumes the
            # POST-update capacity array, so prefetched copies are current
            mc.prefetch(mstate, next_batch["idx"],
                        host_plans=host_plans_from_batch(next_batch),
                        global_plan=host_plan_from_batch(next_batch))
        return new_dense, {"dense": new_dense_state}, metrics

    return step


# ---------------------------------------------------------------------------
# The one cached-step factory (EmbeddingTier dispatch)
# ---------------------------------------------------------------------------


def build_cached_train_step(cfg: DLRMConfig, tier, dense_opt: Optimizer,
                            sparse_lr: float = 0.05,
                            sparse_eps: float = 1e-8,
                            interpret: bool = False,
                            rules: LogicalRules = TRAIN_RULES,
                            strict_sync: bool = False,
                            mesh=None, host_axis: str = "data",
                            fetch_chunk: int | None = None) -> Callable:
    """ONE train-step factory for every cached embedding tier, dispatching
    on the tier's TYPE instead of a builder per schedule:

      `CachedEmbeddingBagCollection`        sync schedule (take admits the
      (incl. the bulk-backed subclass)      batch inline; next_batch rows
                                            prefetch behind the dispatch)
      `AsyncCachedTier(cc)`                 overlapped schedule (batch k+1
                                            stages while batch k computes;
                                            `strict_sync=True` falls back
                                            bit-identically)
      `MultiHostCachedEmbeddingBagCollection`
                                            row-sharded capacity + per-host
                                            caches (`mesh`/`host_axis`
                                            route the owner update)

    The returned step's signature matches the schedule (see the per-tier
    builders); all of them consume the tier through the `EmbeddingTier`
    protocol (core/tiers.py). `fetch_chunk` (> 1) switches capacity->cache
    transfers to contiguous row blocks on any tier (docs/cache.md
    "Chunk-granular transfers"); `strict_sync`/`mesh`/`host_axis` are
    ignored by tiers without the knob."""

    if isinstance(tier, AsyncCachedTier):
        cc = tier.cc
        if fetch_chunk is not None:
            cc = dataclasses.replace(cc, fetch_chunk=fetch_chunk)
        return _build_async_cached_step(cfg, AsyncCachedTier(cc), dense_opt,
                                        sparse_lr, sparse_eps, interpret,
                                        rules, strict_sync)
    if isinstance(tier, MultiHostCachedEmbeddingBagCollection):
        if fetch_chunk is not None:
            tier = dataclasses.replace(tier, fetch_chunk=fetch_chunk)
        return _build_multihost_cached_step(cfg, tier, dense_opt, sparse_lr,
                                            sparse_eps, interpret, rules,
                                            strict_sync, mesh, host_axis)
    if isinstance(tier, CachedEmbeddingBagCollection):
        if fetch_chunk is not None:
            tier = dataclasses.replace(tier, fetch_chunk=fetch_chunk)
        return _build_sync_cached_step(cfg, tier, dense_opt, sparse_lr,
                                       sparse_eps, interpret, rules)
    raise TypeError(
        f"build_cached_train_step: unsupported tier {type(tier).__name__}; "
        "expected an EmbeddingTier (CachedEmbeddingBagCollection, "
        "AsyncCachedTier, MultiHostCachedEmbeddingBagCollection or the "
        f"bulk-backed subclass); protocol conformance: "
        f"{isinstance(tier, EmbeddingTier)}")


def build_cached_dlrm_train_step(cfg: DLRMConfig, cc, dense_opt: Optimizer,
                                 sparse_lr: float = 0.05,
                                 sparse_eps: float = 1e-8,
                                 interpret: bool = False,
                                 rules: LogicalRules = TRAIN_RULES,
                                 fetch_chunk: int | None = None
                                 ) -> Callable:
    """Deprecated alias of `build_cached_train_step(cfg, cc, ...)` (one
    release); the factory dispatches the sync schedule from the tier type."""
    warnings.warn(
        "build_cached_dlrm_train_step is deprecated; use "
        "build_cached_train_step(cfg, tier, ...)", DeprecationWarning,
        stacklevel=2)
    return build_cached_train_step(cfg, cc, dense_opt, sparse_lr, sparse_eps,
                                   interpret, rules,
                                   fetch_chunk=fetch_chunk)


def build_async_cached_dlrm_train_step(cfg: DLRMConfig, cc,
                                       dense_opt: Optimizer,
                                       sparse_lr: float = 0.05,
                                       sparse_eps: float = 1e-8,
                                       interpret: bool = False,
                                       rules: LogicalRules = TRAIN_RULES,
                                       strict_sync: bool = False,
                                       fetch_chunk: int | None = None
                                       ) -> Callable:
    """Deprecated alias of `build_cached_train_step(cfg,
    AsyncCachedTier(cc), ...)` (one release)."""
    warnings.warn(
        "build_async_cached_dlrm_train_step is deprecated; use "
        "build_cached_train_step(cfg, AsyncCachedTier(cc), ...)",
        DeprecationWarning, stacklevel=2)
    return build_cached_train_step(cfg, AsyncCachedTier(cc), dense_opt,
                                   sparse_lr, sparse_eps, interpret, rules,
                                   strict_sync=strict_sync,
                                   fetch_chunk=fetch_chunk)


def build_multihost_cached_train_step(cfg: DLRMConfig, mc,
                                      dense_opt: Optimizer,
                                      sparse_lr: float = 0.05,
                                      sparse_eps: float = 1e-8,
                                      interpret: bool = False,
                                      rules: LogicalRules = TRAIN_RULES,
                                      strict_sync: bool = False,
                                      mesh=None,
                                      host_axis: str = "data",
                                      fetch_chunk: int | None = None
                                      ) -> Callable:
    """Deprecated alias of `build_cached_train_step(cfg, mc, ...)` (one
    release); the factory dispatches the multi-host schedule from the tier
    type."""
    warnings.warn(
        "build_multihost_cached_train_step is deprecated; use "
        "build_cached_train_step(cfg, tier, ...)", DeprecationWarning,
        stacklevel=2)
    return build_cached_train_step(cfg, mc, dense_opt, sparse_lr, sparse_eps,
                                   interpret, rules, strict_sync=strict_sync,
                                   mesh=mesh, host_axis=host_axis,
                                   fetch_chunk=fetch_chunk)


def build_tablewise_train_step(cfg: DLRMConfig, ebc: EmbeddingBagCollection,
                               dense_opt: Optimizer,
                               sparse_lr: float = 0.05,
                               sparse_eps: float = 1e-8,
                               interpret: bool = False,
                               rules: LogicalRules = TRAIN_RULES,
                               mesh=None, model_axis: str = "model",
                               overlap: bool = False) -> Callable:
    """Hybrid model/data-parallel train step for a `table_wise` placement:
    whole embedding tables live on owning shards (model-parallel) while
    every shard runs the full MLPs on its batch slice (data-parallel) —
    the production placement of "Deep Learning Training in Facebook Data
    Centers" (arxiv 2003.09518) and the source paper's Zion.

    Per step, with H = `ebc.plan.capacity_shards` owners:
      FWD   each owner gathers+pools its LOCAL tables once for the global
            batch; the all-to-all exchanges only the pooled (B, F, d)
            activations — `ebc.lookup_pooled_psum` under `mesh` (pool
            before the collective), the pure-jnp global lookup without.
            Cross-wire bytes per direction: (H-1)/H * B*F*d*itemsize, vs
            the row-sharded naive gather's un-pooled (B, F, L, d) rows.
      BWD   the dense backward yields pooled (B, F, d) bag grads; they
            route BACK through the same per-owner split — the global
            plan's live prefix cut at owner row boundaries
            (`split_plan_by_owner`; owners of a table_wise layout are the
            same contiguous blocks as the row-sharded capacity tier) —
            and each owner runs the fused AdaGrad apply on its segment
            (shard_map over `model_axis` under `mesh`, the segmented
            single-launch kernel without).

    Duplicate (row, bag) pairs reduce once, in flat-batch order, inside
    the fused segment apply, so the step is BIT-EXACT vs the dense
    single-host oracle (tests/test_tablewise.py, 8 fake devices).

    `overlap=True` stages batch k+1's pooled forward right after step k's
    update commits (a separately-jitted gather+pool on the post-update
    mega), so the pooled exchange hides under the NEXT step's host-side
    planning — the tablewise twin of the cached tier's prefetch stream.
    Consumption is keyed to (step k+1, that exact batch object); any
    mismatch falls back to the in-step forward, so results are
    bit-identical either way.

    Returns step(params, state, batch, step_idx, next_batch=None) ->
    (params, state, metrics); params follow the `build_dlrm_train_step`
    convention (params["emb"]["mega"], state = {"dense", "accum"}), batch
    carries OFFSET global indices (`ebc.offset_indices`) and optionally a
    hook-attached plan. Metrics include the host-computed pooled-exchange
    bytes (`launch.analysis.tablewise_exchange_traffic` is the matching
    analytic model)."""
    plan = ebc.plan
    if plan.strategy != "table_wise":
        raise ValueError(
            f"build_tablewise_train_step needs a table_wise placement, "
            f"got {plan.strategy!r}")
    if any(c != 1 for c in plan.column_shards):
        raise NotImplementedError(
            "column-sliced tables (column_shards > 1) need the column_wise "
            "executor; re-plan with a larger per-shard budget or fewer "
            "slices")
    n_owners = plan.capacity_shards
    shard_rows = plan.shard_rows
    d = cfg.embed_dim
    itemsize = 4                       # pooled activations cross in fp32
    owners = np.asarray(plan.table_offsets) // max(shard_rows, 1)
    f_per_owner = np.bincount(owners, minlength=n_owners)
    max_f_owned = int(f_per_owner.max()) if len(f_per_owner) else 0

    def pooled_fwd(mega, idx):
        """The pooled exchange: gather+pool locally, all-to-all (B,F,d)."""
        if mesh is not None:
            return ebc.lookup_pooled_psum({"mega": mega}, idx, mesh,
                                          model_axis)
        return ebc.lookup({"mega": mega}, idx, rules)

    def tail(dense_params, dense_state, mega, accum, pooled, dev, step_idx):
        """Dense fwd/bwd on the exchanged pooled activations, then the
        owner-routed fused sparse update."""

        def loss_fn(dp, pl_):
            logits = dlrm_forward_dense({**dp, "emb": None}, dev["dense"],
                                        pl_, cfg, interpret)
            return _bce(logits, dev["label"])

        loss, (g_dense, g_pooled) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(dense_params, pooled)
        new_dense, new_dense_state = dense_opt.apply(
            dense_params, g_dense, dense_state, step_idx)
        pooled2 = g_pooled.astype(jnp.float32).reshape(-1, d)
        if mesh is not None:
            from jax.sharding import PartitionSpec as SP

            from repro.compat import shard_map

            def owner_update(mega_sh, acc_sh, rows_sh, offs_sh, bags, g2):
                return kernel_ops.fused_sparse_backward_segments(
                    mega_sh, acc_sh, rows_sh, offs_sh, bags, g2, sparse_lr,
                    eps=sparse_eps, interpret=interpret)

            new_mega, new_accum = shard_map(
                owner_update, mesh=mesh,
                in_specs=(SP(model_axis, None), SP(model_axis),
                          SP(model_axis, None), SP(model_axis, None),
                          SP(None), SP(None, None)),
                out_specs=(SP(model_axis, None), SP(model_axis)),
                check_vma=False,
            )(mega, accum, dev["seg_rows"], dev["seg_offsets"],
              dev["bag_ids"], pooled2)
        else:
            new_mega, new_accum = kernel_ops.fused_sparse_backward_segments(
                mega, accum, dev["seg_rows"], dev["seg_offsets"],
                dev["bag_ids"], pooled2, sparse_lr,
                seg_base=dev["seg_base"], eps=sparse_eps,
                interpret=interpret)
        lookups = jnp.sum(dev["idx"] >= 0).astype(jnp.float32)
        return (new_dense, new_dense_state, new_mega, new_accum,
                {"loss": loss, "lookups": lookups})

    def inner(dense_params, dense_state, mega, accum, dev, step_idx):
        pooled = pooled_fwd(mega, dev["idx"])
        return tail(dense_params, dense_state, mega, accum, pooled, dev,
                    step_idx)

    def inner_staged(dense_params, dense_state, mega, accum, pooled, dev,
                     step_idx):
        return tail(dense_params, dense_state, mega, accum, pooled, dev,
                    step_idx)

    inner_jit = jax.jit(inner, donate_argnums=(2, 3))
    inner_staged_jit = jax.jit(inner_staged, donate_argnums=(2, 3))
    stage_jit = jax.jit(pooled_fwd)
    staged_cell: list[tuple | None] = [None]

    def step(params, state, batch, step_idx, next_batch=None):
        if mesh is not None:
            assert mesh.shape[model_axis] == n_owners, \
                (mesh.shape[model_axis], n_owners)
        idx_h = np.asarray(batch["idx"])
        plan_h = host_plan_from_batch(batch)
        if plan_h is None:
            plan_h = build_sparse_plan_host(idx_h)
        seg_rows, seg_offs, seg_base = split_plan_by_owner(
            plan_h, shard_rows, n_owners,
            seg_cap=len(plan_h.unique_rows))
        dev = {"dense": jnp.asarray(batch["dense"]),
               "label": jnp.asarray(batch["label"]),
               "idx": jnp.asarray(batch["idx"]),
               "seg_rows": jnp.asarray(seg_rows),
               "seg_offsets": jnp.asarray(seg_offs),
               "seg_base": jnp.asarray(seg_base),
               "bag_ids": jnp.asarray(plan_h.bag_ids)}
        staged, staged_cell[0] = staged_cell[0], None
        if (staged is not None and staged[0] == int(step_idx)
                and staged[1] == id(batch)):
            out = inner_staged_jit(
                {"bottom": params["bottom"], "top": params["top"]},
                state["dense"], params["emb"]["mega"], state["accum"],
                staged[2], dev, step_idx)
        else:
            out = inner_jit(
                {"bottom": params["bottom"], "top": params["top"]},
                state["dense"], params["emb"]["mega"], state["accum"],
                dev, step_idx)
        new_dense, new_dense_state, new_mega, new_accum, metrics = out
        b, f, _ = idx_h.shape
        wire = (n_owners - 1) / max(n_owners, 1) * b * f * d * itemsize
        metrics = {**metrics,
                   "exchange_pooled_fwd_bytes": wire,
                   "exchange_pooled_bwd_bytes": wire,
                   "exchange_pair_leg_bytes":
                       -(-b // max(n_owners, 1)) * max_f_owned * d * itemsize}
        if overlap and next_batch is not None:
            # dispatched after the update: the staged gather reads the
            # POST-update mega, so batch k+1's pooled activations are
            # current; PJRT orders it before the next step's donation
            staged_cell[0] = (int(step_idx) + 1, id(next_batch),
                              stage_jit(new_mega,
                                        jnp.asarray(next_batch["idx"])))
        new_params = {**new_dense, "emb": {"mega": new_mega}}
        return (new_params, {"dense": new_dense_state, "accum": new_accum},
                metrics)

    return step
