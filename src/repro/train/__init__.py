"""Training-side resilience stack: checkpointing, fault injection, steps."""
from repro.train.checkpoint import (  # noqa: F401
    CheckpointCorruptionError,
    CheckpointManager,
)
from repro.train.fault_tolerance import (  # noqa: F401
    ChaosReport,
    DegradationManager,
    FaultInjector,
    FaultSpec,
    InjectedFault,
    PreemptionHandler,
    RetryPolicy,
    StragglerDetector,
    TrainState,
    TransientFetchFault,
    elastic_tablewise_repack,
    restore_train_state,
    run_chaos_loop,
    run_resilient_loop,
    save_train_state,
)
from repro.train.steps import (  # noqa: F401
    build_async_cached_dlrm_train_step,
    build_cached_dlrm_train_step,
    build_cached_train_step,
    build_dlrm_train_step,
    build_lm_train_step,
    cached_dlrm_init_state,
)
