from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.fault_tolerance import (  # noqa: F401
    PreemptionHandler,
    StragglerDetector,
)
from repro.train.steps import (  # noqa: F401
    build_async_cached_dlrm_train_step,
    build_cached_dlrm_train_step,
    build_dlrm_train_step,
    build_lm_train_step,
    cached_dlrm_init_state,
)
