"""Sharded, manifest-based checkpointing with async writes and elastic
(re-mesh) restore.

Layout:  <dir>/step_000123/
            manifest.json     pytree structure + leaf shapes/dtypes
            leaf_00000.npy    one file per leaf (addressable-shard gather)
         <dir>/LATEST         atomic pointer file

Fault-tolerance contract (paper section VII cites CPR/DeepFreeze):
  * save() is atomic: a step directory only becomes visible in LATEST after
    every leaf + manifest hit disk and fsync returns.
  * every leaf carries a CRC-32 checksum in the manifest (computed over the
    stored bytes); restore() verifies it before handing state back, so a
    torn or bit-flipped leaf raises CheckpointCorruptionError instead of
    silently loading garbage.
  * async=True runs the serialization in a background thread (training
    continues; the paper's throughput argument) — `wait()` joins before the
    next save or shutdown and RE-RAISES any failure the writer thread hit
    (a swallowed write error would let the job truncate its own history).
  * restore(step=None) walks BACKWARD through saved steps until one passes
    verification — a corrupt newest checkpoint falls back to the previous
    intact step (`last_restored_step` reports which one loaded).
  * restore(shardings=...) re-device_puts every leaf under NEW shardings, so
    a job restarted on a different mesh shape (elastic downscale after a
    node failure) resumes from the same global state.

Fault injection (docs/fault_tolerance.md): pass a
`train.fault_tolerance.FaultInjector` and `_write` fires the
"checkpoint.write" site once per save — kind "error" makes the write fail
(exercising the async re-raise path), kind "torn" corrupts one byte of a
chosen leaf AFTER the atomic publish (a storage-level tear the atomicity
protocol cannot see, which only the CRC verification catches).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any

import jax
import ml_dtypes
import numpy as np


class CheckpointCorruptionError(RuntimeError):
    """A saved leaf failed CRC verification (torn write / bit rot)."""


def _crc32(arr: np.ndarray) -> int:
    """CRC-32 (zlib, IEEE polynomial — stdlib, no extra dependency) over
    the array's stored bytes."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF

#: numpy can't serialize bf16 (np.save round-trips it as void16); store the
#: raw bits as uint16 and record the logical dtype in the manifest.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    """Atomic, CRC-verified pytree checkpoints with bounded retention.

    Each save writes leaves + a manifest into a tmp dir, fsyncs, then
    publishes with os.replace — a crash leaves either the old or the new
    checkpoint, never a torn one. Restore verifies per-leaf CRCs and falls
    back past corrupt steps to the newest intact one."""

    def __init__(self, directory: str, keep: int = 3, injector=None):
        self.directory = directory
        self.keep = keep
        self.injector = injector           # FaultInjector ("checkpoint.write")
        self.last_restored_step: int | None = None
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, async_: bool = False):
        """Checkpoint `tree` at `step`; async_=True hands the write to a
        background thread (gathered to host first, so donation is safe)."""
        self.wait()                 # re-raises a failed previous async save
        # gather to host BEFORE handing off (device buffers may be donated)
        paths, leaves, treedef = _flatten_with_paths(tree)
        host_leaves = [np.asarray(x) for x in leaves]

        if async_:
            self._thread = threading.Thread(
                target=self._write_captured, args=(step, paths, host_leaves),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, paths, host_leaves)

    def _write_captured(self, step: int, paths, host_leaves):
        """Async-writer entry point: park any failure for wait() to
        re-raise (a daemon thread's traceback otherwise just vanishes)."""
        try:
            self._write(step, paths, host_leaves)
        except BaseException as e:  # noqa: BLE001 — surfaced by wait()
            self._error = e

    def _write(self, step: int, paths, host_leaves):
        spec = None
        if self.injector is not None:       # "error" kind raises right here
            spec = self.injector.fire("checkpoint.write", step=step)
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npy"
            logical = str(arr.dtype)
            if logical in _BITCAST:
                arr = arr.view(_BITCAST[logical])
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "path": path, "file": fname,
                "shape": list(arr.shape), "dtype": logical,
                "crc32": _crc32(arr)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.isdir(final):
            # re-saving a step that already exists on disk (a replay after
            # restore() fell back past a corrupt copy of it): os.replace
            # cannot overwrite a non-empty directory, so drop the stale
            # copy first. A crash in the window leaves no directory at
            # this step — restore() falls back one step further, which is
            # still crash-consistent (LATEST never points at the window).
            shutil.rmtree(final)
        os.replace(tmp, final)
        latest_tmp = os.path.join(self.directory, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))
        if spec is not None and getattr(spec, "kind", None) == "torn":
            # storage-level tear: the atomic publish SUCCEEDED but a leaf
            # lost bits afterwards — only the CRC check can catch this
            leaf = int(spec.arg or 0) % max(len(manifest["leaves"]), 1)
            self._flip_byte(os.path.join(
                final, manifest["leaves"][leaf]["file"]))
        self._gc()

    @staticmethod
    def _flip_byte(path: str):
        """Corrupt the last byte of `path` in place (deterministic tear)."""
        with open(path, "r+b") as f:
            f.seek(-1, os.SEEK_END)
            b = f.read(1)
            f.seek(-1, os.SEEK_END)
            f.write(bytes([b[0] ^ 0xFF]))

    def wait(self):
        """Join any in-flight async save, re-raising its failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint save failed: {err!r}") from err

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        # keep=0 means "keep none": steps[:-0] is the EMPTY slice, not the
        # whole list, so the negative slice only applies for keep > 0
        drop = steps[:-self.keep] if self.keep > 0 else steps
        for d in drop:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        """Newest published step on disk (None when nothing is saved)."""
        latest = os.path.join(self.directory, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            # LATEST can point at a directory _gc already removed (e.g. a
            # keep window smaller than the save cadence): fall back to
            # scanning rather than handing restore() a dangling step
            if os.path.isdir(os.path.join(self.directory, name)):
                return int(name.split("_")[1])
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        if not steps:
            return None
        return int(steps[-1].split("_")[1])

    def saved_steps(self) -> list[int]:
        """All fully-published step numbers on disk, ascending."""
        return sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))

    def restore(self, example_tree: Any, step: int | None = None,
                shardings: Any | None = None) -> Any:
        """example_tree fixes the pytree structure; shardings (optional,
        matching pytree of jax.sharding.Sharding) re-places leaves — pass the
        NEW mesh's shardings for elastic restore.

        With `step=None`, candidate steps are tried NEWEST-FIRST and a
        checkpoint whose leaves fail CRC verification (or whose files are
        unreadable) is skipped — the fall-back-to-previous-intact-step
        half of the recovery contract. `last_restored_step` records which
        step actually loaded. An explicit `step` is strict: corruption
        raises CheckpointCorruptionError. A structure mismatch between
        example_tree and the manifest always raises (it is a caller bug,
        not corruption — falling back would mask it)."""
        if step is not None:
            tree = self._restore_step(step, example_tree, shardings)
            self.last_restored_step = step
            return tree
        candidates = self.saved_steps()[::-1]
        if not candidates:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        errors: list[tuple[int, Exception]] = []
        for cand in candidates:
            try:
                tree = self._restore_step(cand, example_tree, shardings)
            except (CheckpointCorruptionError, OSError,
                    json.JSONDecodeError) as e:
                errors.append((cand, e))
                continue
            self.last_restored_step = cand
            return tree
        raise CheckpointCorruptionError(
            f"no intact checkpoint in {self.directory}: " +
            "; ".join(f"step {s}: {e}" for s, e in errors))

    def _restore_step(self, step: int, example_tree: Any,
                      shardings: Any | None) -> Any:
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        paths, leaves, treedef = _flatten_with_paths(example_tree)
        missing = [p for p in paths if p not in by_path]
        extra = [p for p in by_path if p not in set(paths)]
        if missing or extra:
            raise ValueError(
                f"checkpoint structure mismatch at step {step}: example "
                f"tree leaves absent from the manifest: {missing or 'none'};"
                f" manifest leaves absent from the example tree: "
                f"{extra or 'none'} (did the model/optimizer/cache layout "
                "change between save and restore?)")
        shard_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
            if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, shard_leaves):
            entry = by_path[path]
            arr = np.load(os.path.join(d, entry["file"]))
            if "crc32" in entry and _crc32(arr) != entry["crc32"]:
                raise CheckpointCorruptionError(
                    f"step {step} leaf {path!r} ({entry['file']}) failed "
                    "CRC verification — torn write or bit rot")
            logical = entry["dtype"]
            if logical in _BITCAST:
                arr = arr.view(ml_dtypes.bfloat16 if logical == "bfloat16"
                               else getattr(ml_dtypes, logical))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
