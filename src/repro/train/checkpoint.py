"""Sharded, manifest-based checkpointing with async writes and elastic
(re-mesh) restore.

Layout:  <dir>/step_000123/
            manifest.json     pytree structure + leaf shapes/dtypes
            leaf_00000.npy    one file per leaf (addressable-shard gather)
         <dir>/LATEST         atomic pointer file

Fault-tolerance contract (paper section VII cites CPR/DeepFreeze):
  * save() is atomic: a step directory only becomes visible in LATEST after
    every leaf + manifest hit disk and fsync returns.
  * async=True runs the serialization in a background thread (training
    continues; the paper's throughput argument) — `wait()` joins before the
    next save or shutdown.
  * restore(shardings=...) re-device_puts every leaf under NEW shardings, so
    a job restarted on a different mesh shape (elastic downscale after a
    node failure) resumes from the same global state.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

#: numpy can't serialize bf16 (np.save round-trips it as void16); store the
#: raw bits as uint16 and record the logical dtype in the manifest.
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, async_: bool = False):
        self.wait()
        # gather to host BEFORE handing off (device buffers may be donated)
        paths, leaves, treedef = _flatten_with_paths(tree)
        host_leaves = [np.asarray(x) for x in leaves]

        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, paths, host_leaves),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, paths, host_leaves)

    def _write(self, step: int, paths, host_leaves):
        final = os.path.join(self.directory, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npy"
            logical = str(arr.dtype)
            if logical in _BITCAST:
                arr = arr.view(_BITCAST[logical])
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "path": path, "file": fname,
                "shape": list(arr.shape), "dtype": logical})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
        latest_tmp = os.path.join(self.directory, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.directory, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        # keep=0 means "keep none": steps[:-0] is the EMPTY slice, not the
        # whole list, so the negative slice only applies for keep > 0
        drop = steps[:-self.keep] if self.keep > 0 else steps
        for d in drop:
            shutil.rmtree(os.path.join(self.directory, d),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        latest = os.path.join(self.directory, "LATEST")
        if os.path.exists(latest):
            with open(latest) as f:
                name = f.read().strip()
            # LATEST can point at a directory _gc already removed (e.g. a
            # keep window smaller than the save cadence): fall back to
            # scanning rather than handing restore() a dangling step
            if os.path.isdir(os.path.join(self.directory, name)):
                return int(name.split("_")[1])
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        if not steps:
            return None
        return int(steps[-1].split("_")[1])

    def restore(self, example_tree: Any, step: int | None = None,
                shardings: Any | None = None) -> Any:
        """example_tree fixes the pytree structure; shardings (optional,
        matching pytree of jax.sharding.Sharding) re-places leaves — pass the
        NEW mesh's shardings for elastic restore."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        paths, leaves, treedef = _flatten_with_paths(example_tree)
        shard_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
            if shardings is not None else [None] * len(leaves))
        out = []
        for path, leaf, sh in zip(paths, leaves, shard_leaves):
            entry = by_path[path]
            arr = np.load(os.path.join(d, entry["file"]))
            logical = entry["dtype"]
            if logical in _BITCAST:
                arr = arr.view(ml_dtypes.bfloat16 if logical == "bfloat16"
                               else getattr(ml_dtypes, logical))
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
