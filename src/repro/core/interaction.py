"""Feature interaction (paper section III-A.3): concat or pairwise dot.

`dot`: project the bottom-MLP output to the embedding dim, stack it with the
pooled sparse embeddings into Z (B, F+1, d), take all strictly-lower-triangle
pairwise dot products (sparse-sparse and sparse-dense interactions), and
concatenate them with the bottom output — exactly DLRM's interaction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops


def interact(bottom_out: jax.Array, pooled: jax.Array, kind: str,
             use_kernel=None, interpret: bool = False) -> jax.Array:
    """bottom_out: (B, d); pooled: (B, F, d). Returns top-MLP input."""
    if kind == "cat":
        b = pooled.shape[0]
        return jnp.concatenate([bottom_out, pooled.reshape(b, -1)], axis=-1)
    if kind == "dot":
        z = jnp.concatenate([bottom_out[:, None, :], pooled], axis=1)
        tri = ops.dot_interaction(z, 8, use_kernel, interpret)
        return jnp.concatenate([bottom_out, tri.astype(bottom_out.dtype)],
                               axis=-1)
    raise ValueError(f"unknown interaction {kind!r}")


def interaction_dim(n_sparse: int, embed_dim: int, kind: str) -> int:
    """Width of the top-MLP input."""
    f = n_sparse + 1
    if kind == "cat":
        return embed_dim + n_sparse * embed_dim
    return embed_dim + f * (f - 1) // 2
