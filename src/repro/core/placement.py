"""PlacementPlanner: decides WHERE each embedding table lives.

This is the TPU realization of the paper's Fig. 8 placement options
(section IV-B.1) and of its observation that access frequency does NOT
correlate with table size (Fig. 6/7) — so balanced placement must bin-pack
on *load* (lookups/step) under *capacity* (bytes/shard) constraints.

All tables are laid out in one row-concatenated MEGA TABLE (rows, d). The
plan fixes each table's row offset and the mega table's PartitionSpec:

  replicated   fits in one chip's budget -> paper's "EMB on (one) GPU"
  table_wise   whole tables bin-packed onto `model`-axis shards; offsets
               padded so no table straddles a shard boundary -> paper's
               "table-wise partitioning on GPUs"
  row_wise     rows striped across shards regardless of table boundaries ->
               paper's "row-wise partitioning" (large tables straddle)
  column_wise  embedding dim sharded -> balances tiny-but-hot tables
               (follow-up work to the paper; included as a beyond-paper
               option)

  cached_host  the paper's "system memory" tier, realized: the mega table
               lives replicated in a slow capacity tier (host-resident /
               pooled-HBM array) and a fixed-size device cache holds hot
               rows (core/cache.py). `cache_rows` is sized from the HBM
               budget; Fig. 6/7's skewed, size-uncorrelated access makes a
               small cache capture most traffic. The legacy `host_offload`
               strategy string maps here, keeping configs portable.

               Under data parallelism (`capacity_shards > 1`, the MTrainS
               heterogeneous-memory regime) the capacity tier is ROW-SHARDED
               across hosts — host h owns the contiguous range
               [h*shard_rows, (h+1)*shard_rows) — while every host still
               runs its own `cache_rows`-sized hot cache over the WHOLE row
               space (core/cache.py MultiHostCachedEmbeddingBagCollection).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np
from jax.sharding import PartitionSpec as P


#: device-side HBM overhead per CACHED row beyond the row payload:
#: row-wise AdaGrad accumulator (fp32) + LFU frequency score (fp32)
CACHED_ROW_META_BYTES = 8


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """Where every table's rows live: the fused mega-table layout, its
    sharding spec, and (cached_host) the device-cache sizing."""

    strategy: str   # replicated|table_wise|row_wise|column_wise|cached_host
    table_offsets: tuple[int, ...]   # row offset of each table in the mega table
    total_rows: int                  # padded row count of the mega table
    pspec: P                         # sharding of the (rows, d) mega table
    shard_of_table: tuple[int, ...] | None  # table_wise only
    n_shards: int
    # diagnostics
    bytes_per_shard: tuple[int, ...] = ()
    load_per_shard: tuple[float, ...] = ()
    # cached_host only: device-cache slots backing the host-resident table
    cache_rows: int = 0
    # cached_host under data parallelism (capacity row-sharded over hosts)
    # AND table_wise (owner s holds rows [s*shard_rows, (s+1)*shard_rows)):
    # hosts the rows are sharded across (1 = unsharded) and rows per shard
    capacity_shards: int = 1
    shard_rows: int = 0
    # table_wise only: per-table count of embedding-dim (column) slices the
    # executor should use — 1 for tables that fit their owner's budget, k>1
    # for tables whose bytes exceed one shard (the column_wise escape hatch
    # for huge tables; docs/parallelism.md). The mega layout itself stays
    # full-width — realizing the slice is the execution layer's job.
    column_shards: tuple[int, ...] = ()

    @property
    def load_imbalance(self) -> float:
        """max/mean expected lookup load across shards (1.0 = balanced)."""
        if not self.load_per_shard or max(self.load_per_shard) == 0:
            return 1.0
        mean = float(np.mean(self.load_per_shard))
        return float(max(self.load_per_shard)) / max(mean, 1e-9)


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def plan_placement(hash_sizes: Sequence[int],
                   mean_lookups: Sequence[float],
                   embed_dim: int,
                   n_shards: int,
                   hbm_budget_bytes: float,
                   itemsize: int = 4,
                   strategy: str = "auto",
                   model_axis: str = "model",
                   second_axis: str = "data",
                   second_axis_size: int = 1,
                   capacity_shards: int = 1,
                   table_costs: Sequence[float] | None = None
                   ) -> PlacementPlan:
    """Build a placement plan for one EmbeddingBagCollection.

    hbm_budget_bytes is the per-shard capacity available for embeddings
    (chip HBM minus activations/MLP budget — the caller decides).

    `table_costs` (table_wise only) prices each table for the greedy
    bin-pack — e.g. `launch.analysis.recommend_placement`'s per-table
    exchange+update byte estimate, or measured per-table step times.
    Default is `mean_lookups` (load-balanced packing, the paper's Fig. 6/7
    insight that hot != big).
    """
    hash_sizes = [int(h) for h in hash_sizes]
    loads = [float(ld) for ld in mean_lookups]
    total_bytes = sum(h * embed_dim * itemsize for h in hash_sizes)
    if strategy == "host_offload":  # legacy alias for the realized tier
        strategy = "cached_host"
    if strategy == "auto":
        if total_bytes <= hbm_budget_bytes:
            strategy = "replicated"
        elif (total_bytes <= hbm_budget_bytes * n_shards
              and max(hash_sizes) * embed_dim * itemsize
              <= hbm_budget_bytes):
            strategy = "table_wise"
        else:
            strategy = "row_wise"

    if strategy == "replicated":
        offsets, rows = _contiguous(hash_sizes, pad_mult=8)
        return PlacementPlan(strategy, offsets, rows, P(None, None), None,
                             n_shards,
                             bytes_per_shard=(total_bytes,) * 1,
                             load_per_shard=(sum(loads),))

    if strategy == "row_wise":
        offsets, rows = _contiguous(hash_sizes, pad_mult=8)
        rows = _round_up(rows, n_shards * 8)
        per = rows // n_shards * embed_dim * itemsize
        pspec = P(model_axis, None)
        shards = n_shards
        if per > hbm_budget_bytes and second_axis_size > 1:
            # one axis of shards is not enough (the paper's M3 regime, where
            # a single Big Basin cannot hold the tables): spread rows over
            # the full pod — pooled HBM is the Zion 2 TB tier (DESIGN 2)
            shards = n_shards * second_axis_size
            rows = _round_up(rows, shards * 8)
            per = rows // shards * embed_dim * itemsize
            pspec = P((model_axis, second_axis), None)
        return PlacementPlan(strategy, offsets, rows, pspec,
                             None, shards,
                             bytes_per_shard=(per,) * shards,
                             load_per_shard=_rowwise_load(
                                 hash_sizes, loads, offsets, rows, shards))

    if strategy == "column_wise":
        # every table's embedding dim sliced across all shards: each shard
        # holds the full row space at width d/n_shards, so per-shard bytes
        # shrink by n_shards with NO per-table balance problem — the heavy
        # hammer for tables too big for any single owner (table_wise marks
        # those via column_shards; docs/parallelism.md).
        if embed_dim % n_shards:
            raise ValueError(
                f"column_wise needs embed_dim divisible by n_shards, got "
                f"{embed_dim} % {n_shards}; pad the dim or drop shards")
        offsets, rows = _contiguous(hash_sizes, pad_mult=8)
        per = rows * embed_dim // n_shards * itemsize
        return PlacementPlan(strategy, offsets, rows, P(None, model_axis),
                             None, n_shards,
                             bytes_per_shard=(per,) * n_shards,
                             load_per_shard=(sum(loads) / n_shards,)
                             * n_shards,
                             column_shards=(n_shards,) * len(hash_sizes))

    if strategy == "table_wise":
        return _table_wise(hash_sizes, loads, embed_dim, n_shards,
                           hbm_budget_bytes, itemsize, model_axis,
                           costs=table_costs)

    if strategy == "cached_host":
        # capacity tier: the whole mega table in slow memory (host DRAM /
        # pooled HBM). Single-host (capacity_shards=1): replicated, no
        # sharding to plan. Data-parallel (capacity_shards=H): ROW-SHARDED
        # over the hosts' second (data) axis — each host owns a contiguous
        # shard_rows range and serves other hosts' misses for it. The
        # device tier either way is a per-host hot-row cache sized so
        # payload + per-row AdaGrad accumulator + LFU score fit the
        # per-chip budget.
        offsets, rows = _contiguous(hash_sizes, pad_mult=8)
        rows = _round_up(rows, max(8, capacity_shards * 8))
        shard_rows = rows // capacity_shards
        row_bytes = embed_dim * itemsize + CACHED_ROW_META_BYTES
        cache_rows = int(hbm_budget_bytes // row_bytes)
        cache_rows = max(8, min(cache_rows // 8 * 8, rows))
        pspec = P(None, None) if capacity_shards == 1 \
            else P(second_axis, None)
        per_host = cache_rows * row_bytes + (
            0 if capacity_shards == 1
            else shard_rows * embed_dim * itemsize)
        return PlacementPlan("cached_host", offsets, rows, pspec,
                             None, n_shards,
                             bytes_per_shard=(per_host,) * n_shards,
                             load_per_shard=(sum(loads),) * n_shards,
                             cache_rows=cache_rows,
                             capacity_shards=capacity_shards,
                             shard_rows=shard_rows)

    raise ValueError(f"unknown placement strategy {strategy!r}")


def frequency_reorder(table_offsets: Sequence[int],
                      hash_sizes: Sequence[int],
                      freq: np.ndarray,
                      total_rows: int) -> tuple[np.ndarray, np.ndarray]:
    """Build a per-table ids-by-frequency row permutation of the mega table.

    The CacheEmbedding trick (`ChunkParamMgr.reorder`): renumber each
    table's rows so the most-frequent ids come first. Afterward the Zipf
    head occupies a CONTIGUOUS prefix of every table's row span, which is
    what makes chunk-granular capacity<->cache transfers (fetch_chunk > 1)
    pull in mostly-hot neighbours instead of random cold rows.

    Args:
      table_offsets: row offset of each table in the mega table.
      hash_sizes: logical (unpadded) row count of each table.
      freq: (total_rows,) observed access count / EMA per GLOBAL row.
      total_rows: padded row count of the mega table.

    Returns:
      (remap, inverse): int64 arrays of shape (total_rows,).
      ``remap[old_global_row] = new_global_row`` — apply to incoming ids.
      ``inverse[new_global_row] = old_global_row`` — recover the original
      layout (e.g. to permute pretrained weights to match). Rows outside
      every table span (padding) map to themselves; the permutation never
      crosses a table boundary, so the placement plan is unchanged.
    """
    freq = np.asarray(freq)
    if freq.shape != (total_rows,):
        raise ValueError(
            f"freq must have shape ({total_rows},), got {freq.shape}")
    remap = np.arange(total_rows, dtype=np.int64)
    for o, h in zip(table_offsets, hash_sizes):
        # stable sort: equal-frequency rows keep their original order,
        # making the reorder deterministic for a given counter state
        order = np.argsort(-freq[o:o + h], kind="stable")
        remap[o + order] = o + np.arange(h, dtype=np.int64)
    inverse = np.empty_like(remap)
    inverse[remap] = np.arange(total_rows, dtype=np.int64)
    return remap, inverse


def elastic_table_remap(old_plan: PlacementPlan, new_plan: PlacementPlan,
                        hash_sizes: Sequence[int]
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Row worklist moving a mega table between two placements of the SAME
    tables (elastic restore after host loss: the table_wise bin-pack was
    re-run for the surviving owner count, so every table's row block moved
    to a new global offset).

    Args:
      old_plan / new_plan: placements sharing `hash_sizes` (any strategy —
        only `table_offsets` is consulted).
      hash_sizes: logical (unpadded) row count of each table.

    Returns:
      (src_rows, dst_rows): int64 arrays; copying
      ``new_mega[dst_rows] = old_mega[src_rows]`` (and likewise for the
      AdaGrad accumulator) re-scatters every logical row under the new
      placement. Padding rows are never moved — they are zero in both
      layouts and unreachable by construction.
    """
    if len(old_plan.table_offsets) != len(hash_sizes) or \
            len(new_plan.table_offsets) != len(hash_sizes):
        raise ValueError(
            "elastic_table_remap needs plans over the same tables: "
            f"{len(old_plan.table_offsets)} vs {len(new_plan.table_offsets)}"
            f" vs {len(hash_sizes)} tables")
    src, dst = [], []
    for t, h in enumerate(hash_sizes):
        rows = np.arange(h, dtype=np.int64)
        src.append(old_plan.table_offsets[t] + rows)
        dst.append(new_plan.table_offsets[t] + rows)
    return np.concatenate(src), np.concatenate(dst)


def _contiguous(hash_sizes, pad_mult: int):
    offsets, off = [], 0
    for h in hash_sizes:
        offsets.append(off)
        off += _round_up(h, pad_mult)
    return tuple(offsets), off


def _rowwise_load(hash_sizes, loads, offsets, rows, n_shards):
    """Expected lookups hitting each shard under uniform row access."""
    shard_rows = rows // n_shards
    per = np.zeros(n_shards)
    for h, ld, o in zip(hash_sizes, loads, offsets):
        lo, hi = o, o + h
        for s in range(n_shards):
            a, b = s * shard_rows, (s + 1) * shard_rows
            overlap = max(0, min(hi, b) - max(lo, a))
            if h:
                per[s] += ld * overlap / h
    return tuple(float(x) for x in per)


def _table_wise(hash_sizes, loads, embed_dim, n_shards, budget, itemsize,
                model_axis, costs=None):
    """Greedy LPT bin-packing on PRICED COST with BYTES capacity constraint.

    The paper's insight (Fig. 6/7): hot tables are often small, so packing by
    bytes alone strands bandwidth — we balance a per-table COST instead
    (default: lookups/step; callers may pass analytically priced costs, e.g.
    `launch.analysis.recommend_placement`'s exchange+update bytes) and treat
    bytes as the hard constraint.

    Every table lands whole on its owner: owner s holds the contiguous mega
    rows [s*shard_rows, (s+1)*shard_rows), which is what lets
    `kernels.split_plan_by_owner` slice a batch plan into per-owner routed
    segments with two searchsorted calls. A table whose bytes exceed one
    shard's budget still gets a row-contiguous home (least-byte shard) but
    is flagged in `column_shards` with the D-slice count the execution
    layer should use (the column_wise fallback for huge tables).
    """
    n = len(hash_sizes)
    costs = list(loads) if costs is None else [float(c) for c in costs]
    assert len(costs) == n, (len(costs), n)
    order = np.argsort([-c for c in costs])        # priciest table first
    shard_bytes = np.zeros(n_shards)
    shard_cost = np.zeros(n_shards)
    shard_load = np.zeros(n_shards)
    shard_tables = [[] for _ in range(n_shards)]
    shard_of = np.zeros(n, np.int32)
    col_shards = np.ones(n, np.int64)
    for t in order:
        tb = hash_sizes[t] * embed_dim * itemsize
        if budget > 0 and tb > budget:
            # no owner can hold this table whole: recommend a D-slice over
            # enough shards that each slice fits (clamped to the mesh)
            col_shards[t] = min(n_shards, -(-tb // int(budget)))
        # cheapest shard with room; fall back to least-byte shard
        cand = sorted(range(n_shards), key=lambda s: (shard_cost[s],
                                                      shard_bytes[s]))
        pick = next((s for s in cand if shard_bytes[s] + tb <= budget),
                    int(np.argmin(shard_bytes)))
        shard_of[t] = pick
        shard_bytes[pick] += tb
        shard_cost[pick] += costs[t]
        shard_load[pick] += loads[t]
        shard_tables[pick].append(t)

    # rows per shard = max shard allocation, padded so shards align
    rows_of = [_round_up(h, 8) for h in hash_sizes]
    shard_rows = max(sum(rows_of[t] for t in ts) for ts in shard_tables)
    shard_rows = _round_up(max(shard_rows, 8), 8)
    offsets = [0] * n
    for s, ts in enumerate(shard_tables):
        off = s * shard_rows
        for t in ts:
            offsets[t] = off
            off += rows_of[t]
    total = shard_rows * n_shards
    return PlacementPlan("table_wise", tuple(offsets), total,
                         P(model_axis, None), tuple(int(x) for x in shard_of),
                         n_shards,
                         bytes_per_shard=tuple(int(x) for x in shard_bytes),
                         load_per_shard=tuple(float(x) for x in shard_load),
                         capacity_shards=n_shards,
                         shard_rows=shard_rows,
                         column_shards=tuple(int(x) for x in col_shards))
