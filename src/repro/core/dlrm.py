"""The DLRM model (paper Fig. 3) and its split dense/sparse training step.

Architecture: bottom MLP over dense features -> EmbeddingBagCollection over
sparse features -> feature interaction -> top MLP -> sigmoid CTR logit.

The train step mirrors the paper's production split (Fig. 4): dense params
(MLPs) are data-parallel and optimized with (dense) AdaGrad; the embedding
mega table is model-parallel per the PlacementPlan and optimized with
row-wise AdaGrad applied to DEDUPLICATED per-lookup gradients. Gradients for
the mega table are never materialized densely: autodiff runs with the pooled
embeddings as an explicit leaf, and `per_lookup_grads` + the rowwise-adagrad
path consume (indices, pooled-grad) directly — the PS "gradient aggregation"
of section VII.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import DLRMConfig
from repro.core.embedding import EmbeddingBagCollection
from repro.core.interaction import interact, interaction_dim
from repro.nn.layers import linear, linear_specs

# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def _mlp_specs(dims, in_dim: int, in_ax: str, out_ax: str):
    specs, d = [], in_dim
    for i, width in enumerate(dims):
        # alternate logical axes so consecutive layers shard on
        # opposite sides (megatron-style f/g pairing)
        a_in = in_ax if i % 2 == 0 else out_ax
        a_out = out_ax if i % 2 == 0 else in_ax
        specs.append(linear_specs(d, width, a_in, a_out, bias=True))
        d = width
    return specs, d


def dlrm_param_specs(cfg: DLRMConfig, ebc: EmbeddingBagCollection) -> dict:
    """ParamSpec tree for the full DLRM: bottom/top MLPs + the embedding
    collection's mega table."""
    bottom, bot_out = _mlp_specs(cfg.bottom_mlp, cfg.n_dense_features,
                                 None, "dense_ff")
    assert bot_out == cfg.embed_dim, (
        f"bottom MLP must end at embed_dim: {bot_out} != {cfg.embed_dim}")
    top_in = interaction_dim(cfg.n_sparse_features, cfg.embed_dim,
                             cfg.interaction)
    top, top_out = _mlp_specs(cfg.top_mlp, top_in, None, "dense_ff")
    assert top_out == 1
    return {
        "bottom": bottom,
        "top": top,
        "emb": ebc.param_specs(),
    }

# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _mlp_apply(layers, x, dtype):
    for i, p in enumerate(layers):
        x = linear(p, x, dtype)
        if i < len(layers) - 1:
            x = jax.nn.relu(x)
    return x


def dlrm_forward_dense(params: dict, dense_x: jax.Array, pooled: jax.Array,
                       cfg: DLRMConfig, interpret: bool = False) -> jax.Array:
    """Everything downstream of the embedding lookup (autodiff runs here).

    dense_x: (B, n_dense); pooled: (B, F, d). Returns (B,) logits.
    """
    dtype = jnp.float32 if cfg.compute_dtype == "float32" else jnp.bfloat16
    bot = _mlp_apply(params["bottom"], dense_x.astype(dtype), dtype)
    top_in = interact(bot, pooled.astype(dtype), cfg.interaction,
                      interpret=interpret)
    logit = _mlp_apply(params["top"], top_in, dtype)
    return logit[..., 0].astype(jnp.float32)


def _lookup(params, batch, cfg, ebc, rules):
    if cfg.lookup_impl == "psum":
        from repro.nn.sharding import _live_mesh
        mesh = _live_mesh()
        if mesh is not None:
            return ebc.lookup_pooled_psum(params["emb"], batch["idx"], mesh)
    # a batch-attached bucketing plan (data.sparse_plan_hook, or the cached
    # steps' slot-relabelled copy) dedups the forward gather — the plan is
    # built once per batch and shared with the fused backward and the
    # cached tiers' miss planning (docs/embedding_forward.md)
    from repro.kernels.sparse_plan import plan_from_batch
    return ebc.lookup(params["emb"], batch["idx"], rules,
                      plan=plan_from_batch(batch))


def dlrm_forward(params: dict, batch: dict, cfg: DLRMConfig,
                 ebc: EmbeddingBagCollection,
                 interpret: bool = False, rules=None) -> jax.Array:
    """Full forward pass: embedding lookup + dense tower -> logits."""
    pooled = _lookup(params, batch, cfg, ebc, rules)
    return dlrm_forward_dense(params, batch["dense"], pooled, cfg, interpret)


def dlrm_loss(params: dict, batch: dict, cfg: DLRMConfig,
              ebc: EmbeddingBagCollection,
              interpret: bool = False, rules=None) -> jax.Array:
    """Binary cross-entropy (CTR) — the paper's NE metric is normalized BCE."""
    logits = dlrm_forward(params, batch, cfg, ebc, interpret, rules)
    return _bce(logits, batch["label"])


def _bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def normalized_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """The paper's model-quality metric (section VI-C): BCE normalized by the
    entropy of the base CTR."""
    bce = _bce(logits, labels)
    p = jnp.clip(jnp.mean(labels), 1e-6, 1 - 1e-6)
    base = -(p * jnp.log(p) + (1 - p) * jnp.log(1 - p))
    return bce / base

# ---------------------------------------------------------------------------
# split dense/sparse gradient computation
# ---------------------------------------------------------------------------


def dlrm_grads(params: dict, batch: dict, cfg: DLRMConfig,
               ebc: EmbeddingBagCollection, interpret: bool = False,
               rules=None
               ) -> tuple[jax.Array, dict, tuple[jax.Array, jax.Array]]:
    """Returns (loss, dense_grads, (idx (B,F,L), pooled_grads (B,F,d))).

    The mega table only ever sees sparse gradients: autodiff treats the
    pooled embeddings as a leaf input, and sum-pooling lets every valid
    lookup slot inherit its bag's gradient.
    """
    pooled = _lookup(params, batch, cfg, ebc, rules)
    dense_params = {"bottom": params["bottom"], "top": params["top"]}

    def loss_fn(dp, pl_):
        """BCE loss over the dense tower, pooled embeddings as a leaf."""
        logits = dlrm_forward_dense({**dp, "emb": None}, batch["dense"],
                                    pl_, cfg, interpret)
        return _bce(logits, batch["label"])

    loss, (g_dense, g_pooled) = jax.value_and_grad(
        loss_fn, argnums=(0, 1))(dense_params, pooled)
    return loss, g_dense, (batch["idx"], g_pooled.astype(jnp.float32))
