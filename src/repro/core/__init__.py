"""The paper's primary contribution as a composable JAX module set:

  placement.py     PlacementPlanner — capacity/frequency-driven embedding
                   placement (table-wise / row-wise / column-wise /
                   replicated), the TPU mapping of the paper's Fig. 8
  embedding.py     EmbeddingBagCollection — mega-table layout + multi-hot
                   pooled lookup under any placement plan
  interaction.py   concat / pairwise-dot feature interaction (section III-A.3)
  dlrm.py          the full DLRM (Fig. 3): bottom MLP -> EMBs -> interaction
                   -> top MLP, loss, and the split dense/sparse train step
  design_space.py  the section-V parameterized test suite (feature counts,
                   batch size, hash size, MLP dims sweeps)
  cache.py         CachedEmbeddingBagCollection — the "system memory" tier
                   realized: host-resident capacity array + LFU-managed
                   device hot-row cache (Figs. 6-8 access skew)
"""
from repro.core.cache import (  # noqa: F401
    AsyncCacheState,
    CachedEmbeddingBagCollection,
    CacheState,
    CacheStats,
)
from repro.core.dlrm import (  # noqa: F401
    dlrm_forward,
    dlrm_loss,
    dlrm_param_specs,
)
from repro.core.embedding import EmbeddingBagCollection  # noqa: F401
from repro.core.placement import PlacementPlan, plan_placement  # noqa: F401
