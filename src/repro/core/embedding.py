"""EmbeddingBagCollection: all sparse-feature tables of one model as a single
row-concatenated mega table + a PlacementPlan.

Lookup semantics (paper section III-A.2): each sparse feature is a multi-hot
index list of up to `truncation` entries; each entry fetches one d-vector;
vectors are sum-pooled per (example, feature). Index preprocessing (hashing
into [0, hash_size) and adding the table's row offset) happens in the data
pipeline; the collection consumes offset global indices with -1 padding.

Two lookup paths:
  * `lookup` — pure-jnp gather+pool with GLOBAL semantics: under pjit the
    XLA SPMD partitioner turns the gather-from-sharded-table into partial
    local gathers + an all-reduce over the `model` axis (the embedding
    "all-to-all" of the paper's PS architecture). Used for training and the
    dry-run (collectives must be visible to the roofline pass).
  * `lookup_local` — the Pallas embedding_bag kernel on one shard's rows;
    used inside shard_map on real TPUs and by serving.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.placement import PlacementPlan, plan_placement
from repro.kernels import ops
from repro.nn.params import ParamSpec


@dataclasses.dataclass(frozen=True)
class EmbeddingBagCollection:
    """All embedding tables fused into one (total_rows, d) mega table,
    looked up bag-pooled per feature under a placement plan."""

    cfg: DLRMConfig
    plan: PlacementPlan

    @classmethod
    def build(cls, cfg: DLRMConfig, n_shards: int,
              strategy: str | None = None,
              second_axis_size: int = 1,
              capacity_shards: int = 1) -> EmbeddingBagCollection:
        """Plan placement for cfg's tables and wrap it."""
        plan = plan_placement(
            cfg.hash_sizes, cfg.mean_lookups, cfg.embed_dim, n_shards,
            hbm_budget_bytes=cfg.hbm_budget_gb * 1e9,
            itemsize=4 if cfg.param_dtype == "float32" else 2,
            strategy=strategy or cfg.placement,
            second_axis_size=second_axis_size,
            capacity_shards=capacity_shards)
        return cls(cfg, plan)

    # -- params ------------------------------------------------------------

    def param_specs(self) -> dict:
        """The fused mega-table ParamSpec."""
        dt = jnp.float32 if self.cfg.param_dtype == "float32" else jnp.bfloat16
        return {"mega": ParamSpec(
            (self.plan.total_rows, self.cfg.embed_dim),
            ("hash", "table_dim"), dtype=dt, init="normal",
            scale=1.0 / np.sqrt(self.cfg.embed_dim))}

    def optimizer_specs(self) -> dict:
        """Row-wise AdaGrad second-moment accumulator."""
        return {"accum": ParamSpec((self.plan.total_rows,), ("hash",),
                                   dtype=jnp.float32, init="zeros")}

    def pspecs(self) -> dict:
        """Partition specs for the params, from the plan."""
        return {"mega": self.plan.pspec}

    def optimizer_pspecs(self) -> dict:
        """Partition specs for the optimizer state (row dim only)."""
        return {"accum": jax.sharding.PartitionSpec(*self.plan.pspec[:1])}

    # -- index preprocessing -----------------------------------------------

    def offset_indices(self, raw: jax.Array) -> jax.Array:
        """raw: (B, F, L) per-table indices in [0, hash_size_f) or -1 pad.
        Returns global mega-table rows (still -1 padded)."""
        off = jnp.asarray(self.plan.table_offsets, jnp.int32)
        out = raw + off[None, :, None]
        return jnp.where(raw >= 0, out, -1)

    # -- lookup ------------------------------------------------------------

    def lookup(self, params: dict, idx: jax.Array, rules=None,
               plan=None) -> jax.Array:
        """idx: (B, F, L) offset global rows, -1 pads. Returns (B, F, d)
        sum-pooled embeddings. Pure-jnp global-semantics path: under pjit the
        gather from the model-sharded mega table lowers to local gathers +
        the cross-shard reduce — the paper's PS pull.

        `plan` (a kernels.SparsePlan over idx's flat stream, e.g. the one
        `data.sparse_plan_hook` attaches and `kernels.plan_from_batch`
        rehydrates) DEDUPLICATES the mega-table gather: the table is
        touched once per plan entry (its unique capacity U, not B*F*L) into
        a compact hot buffer, and every lookup slot then reads that buffer
        through an index-only searchsorted remap. The pooling that follows
        is the SAME code either way, so the planned path is BIT-EXACT vs
        the plan-less one (asserted in tests/test_dedup_forward.py) — the
        forward half of the plan-once-used-thrice contract
        (docs/embedding_forward.md)."""
        from repro.nn.sharding import shard_activation
        mega = params["mega"]
        b, f, lk = idx.shape

        if plan is None:
            def take(flat):                  # flat: (n,) clipped global rows
                """Direct mega-table gather."""
                return jnp.take(mega, flat, axis=0)
        else:
            compact = jnp.take(mega, jnp.maximum(plan.unique_rows, 0),
                               axis=0)       # the ONLY mega-table gather
            sent = jnp.where(plan.unique_rows >= 0, plan.unique_rows,
                             jnp.iinfo(jnp.int32).max)

            def take(flat):
                """Gather via the plan's deduplicated compact slab."""
                return jnp.take(compact, jnp.searchsorted(sent, flat),
                                axis=0)

        def pool_one(_, idx_f):
            """Pool one feature's bags; scanned over the feature axis."""
            # idx_f: (b, lk) one feature's bags
            valid = idx_f >= 0
            rows = take(jnp.maximum(idx_f, 0).reshape(-1))
            rows = rows.reshape(b, lk, -1)
            rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
            return None, rows.sum(axis=1).astype(mega.dtype)

        if f > 8:
            # scan over features: bounds the (b, lk, d) gather transient to
            # one feature at a time (m3 has 127 tables x 32 lookups)
            _, pooled = jax.lax.scan(pool_one, None,
                                     jnp.swapaxes(idx, 0, 1))
            pooled = jnp.swapaxes(pooled, 0, 1)              # (b, f, d)
        else:
            valid = idx >= 0
            rows = take(jnp.maximum(idx, 0).reshape(-1))
            rows = rows.reshape(b, f, lk, -1)
            rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
            pooled = rows.sum(axis=2).astype(mega.dtype)
        return shard_activation(pooled, ("act_batch", None, None),
                                rules or {})

    def lookup_pooled_psum(self, params: dict, idx: jax.Array,
                           mesh, model_axis: str = "model") -> jax.Array:
        """shard_map lookup with PS-SIDE POOLING: each model shard pools its
        local rows per bag, then a psum of the (B, F, d) POOLED tensor
        crosses shards — instead of the naive gather whose cross-shard
        payload is the (B, F, L, d) un-pooled rows (truncation x more
        bytes; the paper's PS architecture pools at the PS for exactly this
        reason). Requires plan.pspec == P(model_axis, None) and the batch
        sharded over the remaining axes."""
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map
        assert self.plan.pspec == P(model_axis, None), self.plan.pspec
        batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)
        rows_local = self.plan.total_rows // mesh.shape[model_axis]
        d = self.cfg.embed_dim

        def local_fn(mega_shard, idx_local):
            """Per-shard masked lookup; psum recombines across shards."""
            shard = jax.lax.axis_index(model_axis)
            lo = shard * rows_local
            loc = jnp.where((idx_local >= lo)
                            & (idx_local < lo + rows_local),
                            idx_local - lo, -1)
            b, f, lk = loc.shape
            valid = loc >= 0
            rows = jnp.take(mega_shard, jnp.maximum(loc, 0).reshape(-1),
                            axis=0).reshape(b, f, lk, d)
            rows = jnp.where(valid[..., None], rows.astype(jnp.float32),
                             0.0)
            pooled = rows.sum(axis=2)          # POOL BEFORE the collective
            return jax.lax.psum(pooled, model_axis)

        return shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(model_axis, None), P(batch_axes, None, None)),
            out_specs=P(batch_axes, None, None),
        )(params["mega"], idx).astype(params["mega"].dtype)

    def lookup_local(self, mega_shard: jax.Array, idx: jax.Array,
                     row_lo: int, row_hi: int,
                     interpret: bool = False,
                     dedup: bool = False) -> jax.Array:
        """Per-shard lookup for shard_map/serving: gather only rows owned by
        this shard ([row_lo, row_hi)); callers all-reduce partial pools.

        `dedup=True` routes through the plan-driven dedup'd kernel
        (ops.dedup_embedding_bag, plan built on device over the shard-local
        stream): each locally-owned unique row leaves HBM once per batch
        instead of once per referencing slot."""
        b, f, lk = idx.shape
        local = jnp.where((idx >= row_lo) & (idx < row_hi),
                          idx - row_lo, -1).reshape(b * f, lk)
        if dedup:
            out = ops.dedup_embedding_bag(mega_shard, local, None, "sum",
                                          None, interpret)
        else:
            out = ops.embedding_bag(mega_shard, local, "sum", None,
                                    interpret)
        return out.reshape(b, f, -1)

    # -- gradient layout for the sparse optimizer ---------------------------

    def per_lookup_grads(self, idx: jax.Array, pooled_grad: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
        """LEGACY layout: sum pooling => each valid lookup slot inherits its
        bag's grad, materializing the (B*F*L, d) broadcast the fused path
        exists to avoid. Kept as the reference input for
        rowwise_adagrad_update and the equivalence tests.

        idx: (B, F, L); pooled_grad: (B, F, d).
        Returns (flat_idx (B*F*L,), flat_grads (B*F*L, d)).
        """
        b, f, lk = idx.shape
        g = jnp.broadcast_to(pooled_grad[:, :, None, :],
                             (b, f, lk, pooled_grad.shape[-1]))
        return idx.reshape(-1), g.reshape(b * f * lk, -1)

    # -- stats ---------------------------------------------------------------

    def table_bytes(self) -> int:
        """Total mega-table bytes at the param dtype."""
        item = 4 if self.cfg.param_dtype == "float32" else 2
        return self.plan.total_rows * self.cfg.embed_dim * item

    def lookups_per_example(self) -> float:
        """Mean pooled lookups per example across features."""
        return float(sum(self.cfg.mean_lookups))
