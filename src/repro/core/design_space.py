"""The paper's section-V design-space test suite, as code.

"To explore the design space of training model configurations, we created a
model containing basic components of recommendation models" — this module
builds that parameterized model: dense features 64..4096, sparse features
4..128, FIXED hash size for all tables (default 100000, as in Figs. 10-13),
lookups truncated to 32, MLP dims width^layers.

Each sweep_* function returns the configs for one paper figure; the matching
benchmarks/fig*.py files run them.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import DLRMConfig


def test_suite_config(n_dense: int = 512, n_sparse: int = 32,
                      hash_size: int = 100_000, mlp_width: int = 512,
                      mlp_layers: int = 3, lookups: int = 32,
                      embed_dim: int = 64,
                      interaction: str = "dot") -> DLRMConfig:
    """One point of the section-V suite: constant hash size (removes indexing
    noise), truncation 32, MLP dims width^layers."""
    return DLRMConfig(
        name=f"suite-d{n_dense}-s{n_sparse}-h{hash_size}"
             f"-m{mlp_width}x{mlp_layers}",
        n_dense_features=n_dense,
        n_sparse_features=n_sparse,
        embed_dim=embed_dim,
        hash_sizes=(hash_size,) * n_sparse,
        mean_lookups=(lookups,) * n_sparse,
        truncation=32,
        bottom_mlp=(mlp_width,) * mlp_layers + (embed_dim,),
        top_mlp=(mlp_width,) * mlp_layers + (1,),
        interaction=interaction,
        notes="section V test suite")


def sweep_fig10() -> list[tuple[str, DLRMConfig]]:
    """Fig. 10: dense x sparse feature grid (MLP 512^3, hash 100k)."""
    out = []
    for n_dense in (64, 256, 1024, 4096):
        for n_sparse in (4, 16, 64, 128):
            cfg = test_suite_config(n_dense=n_dense, n_sparse=n_sparse)
            out.append((f"dense{n_dense}_sparse{n_sparse}", cfg))
    return out


def sweep_fig11_batch() -> list[int]:
    """Fig. 11: batch-size scaling (model fixed; batch is the x-axis)."""
    return [128, 256, 512, 1024, 2048, 4096, 8192]


def sweep_fig12_hash() -> list[tuple[str, DLRMConfig]]:
    """Fig. 12: hash-size scaling (table capacity grows, lookups constant)."""
    out = []
    for h in (10_000, 100_000, 1_000_000, 5_000_000, 10_000_000):
        out.append((f"hash{h}", test_suite_config(hash_size=h)))
    return out


def sweep_fig13_mlp() -> list[tuple[str, DLRMConfig]]:
    """Fig. 13: MLP dimension sweep width^layers."""
    out = []
    for width, layers in ((64, 2), (128, 2), (256, 3), (512, 3),
                          (1024, 3), (2048, 4)):
        out.append((f"mlp{width}x{layers}",
                    test_suite_config(mlp_width=width, mlp_layers=layers)))
    return out


def reduced(cfg: DLRMConfig, factor: int = 16) -> DLRMConfig:
    """Shrink a suite config for CPU benchmarking while keeping ratios."""
    return dataclasses.replace(
        cfg,
        n_dense_features=max(8, cfg.n_dense_features // factor),
        n_sparse_features=max(2, cfg.n_sparse_features // factor),
        hash_sizes=tuple(max(64, h // factor)
                         for h in cfg.hash_sizes)[
                             :max(2, cfg.n_sparse_features // factor)],
        mean_lookups=cfg.mean_lookups[:max(2, cfg.n_sparse_features
                                           // factor)],
        bottom_mlp=tuple(max(8, w // factor) for w in cfg.bottom_mlp[:-1])
        + (cfg.embed_dim // 4,),
        top_mlp=tuple(max(8, w // factor) for w in cfg.top_mlp[:-1]) + (1,),
        embed_dim=cfg.embed_dim // 4,
    )
