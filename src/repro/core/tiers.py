"""N-tier heterogeneous embedding memory behind one `EmbeddingTier` protocol.

MTrainS (arxiv 2305.01515) shows production DLRM tables tiered across
HBM / DRAM / NVM by bandwidth need, not just the two levels core/cache.py
grew for the paper's capacity problem. This module adds the third level
and the formal surface that keeps a fourth from forking the codebase again:

  `EmbeddingTier`   the runtime-checkable protocol every cached collection
                    implements — `take` (make a batch current), `stage`
                    (overlap the next batch's fetch), `prefetch_rows`,
                    `commit`, `flush`, `materialize`, `state_dict` /
                    `load_state_dict`, `stats`, `placement`. Call sites in
                    train/steps.py, serve/dlrm_engine.py, and
                    train/fault_tolerance.py consume tiers through this
                    surface only.
  `AsyncCachedTier` the async exchange stream as a first-class tier: a thin
                    wrapper mapping the protocol onto
                    `CachedEmbeddingBagCollection`'s *_async methods, so
                    `build_cached_train_step` dispatches on tier TYPE
                    instead of a builder-per-schedule.
  `BulkCachedEmbeddingBagCollection`
                    HBM cache -> DRAM capacity -> bulk store. The capacity
                    array keeps full height (it stays the one authoritative
                    value store, so every oracle stays bit-exact); a
                    `dram_resident` mask splits the non-device rows between
                    DRAM and the `BulkStore` (mmap-backed or RAM, with
                    injected multi-microsecond block latency). Admissions
                    whose rows live in bulk PROMOTE them first (chunked
                    reads through `coalesce_rows`, behind the "bulk.fetch"
                    fault site); evictions land in DRAM, and DRAM overflow
                    DEMOTES the coldest rows (by the same EMA score that
                    drives admission) back to bulk. Bulk latency is a
                    deadline, not an inline sleep: the async stream's
                    commit pays only what batch k's compute did not already
                    hide (docs/memory_tiers.md).

Residency is EXCLUSIVE by construction — device (row_slot >= 0), DRAM
(dram_resident, not device), bulk (neither) partition the row space; the
hypothesis property test in tests/test_tiers.py fuzzes promotion/demotion
interleavings against it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, ClassVar, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.cache import (AsyncCacheState, CachedEmbeddingBagCollection,
                              CacheState, CacheStats, _ema_score,
                              _fetch_guard)
from repro.core.embedding import EmbeddingBagCollection
from repro.kernels.sparse_plan import coalesce_rows


# ---------------------------------------------------------------------------
# Per-tier counters
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TierCacheStats(CacheStats):
    """CacheStats plus the third tier's hit/traffic counters.

    The device-tier figures keep their FBGEMM conventions (`hits`,
    `misses`, `hit_rate`); the new counters split the MISS stream by the
    level that served it — every admitted row came from DRAM
    (`dram_hits`) or had to be promoted from bulk (`bulk_hits`) — and
    price the promotion/demotion pipelines in rows, bytes, chunks, and
    injected latency. All integers so the checkpoint path's int64 cast
    round-trips (`state_dict`)."""

    dram_hits: int = 0         # admitted rows whose staging copy was in DRAM
    bulk_hits: int = 0         # admitted rows promoted from the bulk store
    demotions: int = 0         # rows demoted DRAM -> bulk on budget overflow
    promotion_bytes: int = 0   # bulk -> DRAM payload bytes (row + accum)
    demotion_bytes: int = 0    # DRAM -> bulk payload bytes
    bulk_read_chunks: int = 0  # block descriptors issued by promotions
    bulk_write_chunks: int = 0  # block descriptors issued by demotions
    bulk_sched_us: int = 0     # injected bulk latency scheduled (deadlines)
    bulk_wait_us: int = 0      # scheduled latency actually paid at a sync
                               # point (commit/take) — the un-hidden part

    @property
    def hit_hbm(self) -> int:
        """Accesses served by the device tier (alias of `hits`)."""
        return self.hits

    @property
    def dram_hit_rate(self) -> float:
        """dram_hits / fetched rows: the fraction of the miss stream DRAM
        absorbed before it could reach the bulk tier; 0.0 untouched."""
        fetched = self.dram_hits + self.bulk_hits
        return self.dram_hits / fetched if fetched else 0.0

    @property
    def hidden_fraction(self) -> float:
        """1 - bulk_wait/bulk_sched: how much of the injected bulk latency
        the async stream hid under compute; 1.0 when nothing was scheduled."""
        if self.bulk_sched_us <= 0:
            return 1.0
        return max(0.0, 1.0 - self.bulk_wait_us / self.bulk_sched_us)

    def snapshot(self) -> dict[str, float]:
        """Flat metrics dict: the two-tier payload plus `tier_*` keys."""
        out = super().snapshot()
        out.update({
            "tier_hit_hbm": float(self.hits),
            "tier_hit_dram": float(self.dram_hits),
            "tier_hit_bulk": float(self.bulk_hits),
            "tier_dram_hit_rate": self.dram_hit_rate,
            "tier_demotions": float(self.demotions),
            "tier_promotion_bytes": float(self.promotion_bytes),
            "tier_demotion_bytes": float(self.demotion_bytes),
            "tier_bulk_read_chunks": float(self.bulk_read_chunks),
            "tier_bulk_write_chunks": float(self.bulk_write_chunks),
            "tier_bulk_sched_us": float(self.bulk_sched_us),
            "tier_bulk_wait_us": float(self.bulk_wait_us)})
        return out


# ---------------------------------------------------------------------------
# The bulk store (SSD/NVM stand-in)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BulkStore:
    """The slowest level: an mmap-backed (or plain RAM) row store standing
    in for SSD/NVM below host-DRAM capacity.

    Access is BLOCK-granular like a real block device: reads and writes
    coalesce their sorted row lists into contiguous `chunk`-row blocks
    (`coalesce_rows`, min_fill=1 — every access pays whole blocks) and
    each block schedules `latency_us` of device latency. The latency is a
    DEADLINE (`_ready_at`), not an inline sleep: `wait()` — called at the
    consumption point (sync admission, or the async stream's commit) —
    sleeps only the part that real work has not already hidden, and books
    scheduled vs paid microseconds separately so the bench can measure the
    hidden fraction exactly."""

    values: np.ndarray         # (R, d) demoted-row payload (np or memmap)
    accum: np.ndarray          # (R,) fp32 AdaGrad accumulators
    chunk: int                 # block height in rows (>= 1)
    latency_us: float          # injected device latency per block access
    path: str | None = None    # backing .npy file when mmap-backed
    _ready_at: float = 0.0     # monotonic deadline of the in-flight access

    @classmethod
    def build(cls, rows: int, dim: int, chunk: int, latency_us: float,
              path: str | None = None,
              dtype=np.float32) -> BulkStore:
        """Allocate an (rows, dim) store; `path` switches the payload to
        np.memmap-backed .npy files (`path` + a sibling accumulator file)
        so the tier genuinely pages through the filesystem."""
        if path and rows:
            values = np.lib.format.open_memmap(
                path, mode="w+", dtype=dtype, shape=(rows, dim))
            accum = np.lib.format.open_memmap(
                str(path) + ".accum.npy", mode="w+", dtype=np.float32,
                shape=(rows,))
        else:
            values = np.zeros((rows, dim), dtype)
            accum = np.zeros((rows,), np.float32)
        return cls(values, accum, max(1, int(chunk)), float(latency_us),
                   path if rows else None)

    @property
    def row_bytes(self) -> int:
        """Payload bytes per row (embedding row + its accumulator)."""
        return int(self.values.shape[1]) * self.values.itemsize \
            + self.accum.itemsize

    def _schedule(self, n_blocks: int, stats: TierCacheStats) -> None:
        """Push the readiness deadline out by `n_blocks` block latencies
        (accesses queue behind each other, like one device channel)."""
        lat_us = n_blocks * self.latency_us
        base = max(self._ready_at, time.monotonic())
        self._ready_at = base + lat_us * 1e-6
        stats.bulk_sched_us += int(round(lat_us))

    def read(self, rows: np.ndarray,
             stats: TierCacheStats) -> tuple[np.ndarray, np.ndarray]:
        """Block-granular read of sorted unique `rows` (the promotion leg).
        Schedules latency and books chunks/bytes; returns (values, accum)
        copies."""
        starts, _ = coalesce_rows(rows, self.chunk, len(self.values),
                                  min_fill=1)
        stats.bulk_read_chunks += len(starts)
        stats.promotion_bytes += len(rows) * self.row_bytes
        self._schedule(len(starts), stats)
        return self.values[rows].copy(), self.accum[rows].copy()

    def write(self, rows: np.ndarray, values: np.ndarray,
              accum: np.ndarray, stats: TierCacheStats) -> None:
        """Block-granular write of sorted unique `rows` (the demotion
        leg). Schedules latency and books chunks/bytes/demotions."""
        starts, _ = coalesce_rows(rows, self.chunk, len(self.values),
                                  min_fill=1)
        stats.bulk_write_chunks += len(starts)
        stats.demotions += len(rows)
        stats.demotion_bytes += len(rows) * self.row_bytes
        self._schedule(len(starts), stats)
        self.seed(rows, values, accum)

    def seed(self, rows: np.ndarray, values: np.ndarray,
             accum: np.ndarray) -> None:
        """Raw install without latency or counters (initial population and
        checkpoint restore)."""
        self.values[rows] = np.asarray(values, self.values.dtype)
        self.accum[rows] = np.asarray(accum, np.float32)

    def wait(self, stats: TierCacheStats) -> float:
        """Sleep until the outstanding access deadline — the consumption
        point of the latency model. Books the microseconds actually paid
        (the part compute did not hide) and returns them."""
        now = time.monotonic()
        paid = 0.0
        if self._ready_at > now:
            paid = self._ready_at - now
            time.sleep(paid)
            stats.bulk_wait_us += int(round(paid * 1e6))
        self._ready_at = 0.0
        return paid * 1e6


# ---------------------------------------------------------------------------
# The protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class EmbeddingTier(Protocol):
    """The one surface every cached embedding tier implements.

    Implementations: `CachedEmbeddingBagCollection` (sync two-tier),
    `AsyncCachedTier` (its overlapped stream), `BulkCachedEmbeddingBag-
    Collection` (three-tier, sync or wrapped async), and
    `MultiHostCachedEmbeddingBagCollection`. Call sites outside core/
    (train/steps.py, serve/dlrm_engine.py, train/fault_tolerance.py)
    consume tiers through these methods only — conformance is asserted in
    tests/test_tiers.py."""

    def init_state(self, mega: jax.Array, accum: jax.Array | None = None):
        """Fresh mutable tier state over the (rows, d) capacity table."""
        ...

    def take(self, state, idx, train: bool = True, plan=None):
        """Make `idx`'s batch current; return its device-space remap."""
        ...

    def stage(self, state, idx, train: bool = True, plan=None):
        """Overlap the NEXT batch's fetch (None when the tier can't)."""
        ...

    def prefetch_rows(self, state, rows, gate: bool = False) -> int:
        """Best-effort admission of unique rows ahead of use."""
        ...

    def commit(self, state) -> int:
        """Drain pending installs at a step boundary."""
        ...

    def flush(self, state) -> int:
        """Write dirty device rows back to the capacity tier."""
        ...

    def materialize(self, state):
        """The up-to-date (mega, accum) capacity arrays."""
        ...

    def state_dict(self, state) -> dict:
        """Checkpoint-ready pytree covering the whole tier."""
        ...

    def load_state_dict(self, d: dict):
        """Rebuild tier state from a `state_dict` pytree."""
        ...

    def stats(self, state) -> CacheStats:
        """The tier's counters."""
        ...

    def placement(self) -> dict:
        """Static memory-level layout, fastest first."""
        ...


# ---------------------------------------------------------------------------
# The async stream as a tier
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsyncCachedTier:
    """The async exchange stream as a first-class `EmbeddingTier`.

    Wraps any `CachedEmbeddingBagCollection` (including the bulk-backed
    subclass) and maps the protocol onto its *_async methods, so the
    schedule is a TIER CHOICE — `build_cached_train_step` dispatches on
    `AsyncCachedTier` vs the bare collection instead of keeping one
    builder per schedule. State is the wrapped collection's
    AsyncCacheState; semantics (bit-exactness vs the sync schedule, the
    slot_epoch invariant) are unchanged (docs/cache.md)."""

    cc: CachedEmbeddingBagCollection

    @property
    def ebc(self) -> EmbeddingBagCollection:
        """The wrapped embedding collection (step-builder accessor)."""
        return self.cc.ebc

    @property
    def cache_rows(self) -> int:
        """Device-tier height of the wrapped collection."""
        return self.cc.cache_rows

    def init_state(self, mega: jax.Array,
                   accum: jax.Array | None = None) -> AsyncCacheState:
        """Protocol `init_state` -> the wrapped `init_async_state`."""
        return self.cc.init_async_state(mega, accum)

    def take(self, state: AsyncCacheState, idx, train: bool = True,
             plan=None) -> np.ndarray:
        """Protocol `take` -> `take_async`: pop the staged plan (or plan
        now), mark in-flight, commit pending fetches."""
        return self.cc.take_async(state, idx, train=train, plan=plan)

    def stage(self, state: AsyncCacheState, idx, train: bool = True,
              plan=None) -> np.ndarray:
        """Protocol `stage` -> `stage_async`: dispatch the next batch's
        shadow fetch so it overlaps the in-flight compute."""
        return self.cc.stage_async(state, idx, train=train, plan=plan)

    def prefetch_rows(self, state: AsyncCacheState, rows,
                      gate: bool = False) -> int:
        """Protocol `prefetch_rows` -> `stage_rows` (queued lookahead)."""
        return self.cc.stage_rows(state, rows, gate=gate)

    def commit(self, state: AsyncCacheState) -> int:
        """Protocol `commit` -> `commit_async` (drain the pending queue)."""
        return self.cc.commit_async(state)

    def flush(self, state: AsyncCacheState) -> int:
        """Protocol `flush` -> `flush_async`."""
        return self.cc.flush_async(state)

    def materialize(self, state: AsyncCacheState
                    ) -> tuple[jax.Array, jax.Array]:
        """Protocol `materialize` -> `materialize_async`."""
        return self.cc.materialize_async(state)

    def state_dict(self, state: AsyncCacheState) -> dict:
        """Protocol `state_dict` (drains + unwinds, see the collection)."""
        return self.cc.state_dict(state)

    def load_state_dict(self, d: dict) -> AsyncCacheState:
        """Protocol `load_state_dict` (the async flavour restores itself
        off the checkpoint's `epoch` key)."""
        return self.cc.load_state_dict(d)

    def stats(self, state: AsyncCacheState) -> CacheStats:
        """Protocol accessor for the tier's CacheStats."""
        return state.stats

    def placement(self) -> dict:
        """The wrapped layout, restamped as the async stream."""
        return {**self.cc.placement(), "stream": "async"}

    # step-builder delegations (beyond the protocol)

    def plan_to_slots(self, state: AsyncCacheState, batch: dict) -> dict:
        """Relabel a host sparse plan onto the cache slab (see the
        collection's `plan_to_slots`)."""
        return self.cc.plan_to_slots(state, batch)

    def mark_updated(self, state: AsyncCacheState, new_cache: jax.Array,
                     new_cache_accum: jax.Array) -> None:
        """Install post-update cache arrays (see `mark_updated`)."""
        self.cc.mark_updated(state, new_cache, new_cache_accum)

    def lookup(self, state: AsyncCacheState, idx, train: bool = False,
               rules=None) -> jax.Array:
        """Pooled lookup through the async stream (`lookup_async`)."""
        return self.cc.lookup_async(state, idx, train=train, rules=rules)


# ---------------------------------------------------------------------------
# The three-tier collection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BulkCacheState(CacheState):
    """CacheState plus the third tier: the bulk store and the exclusive
    DRAM-residency mask (row in DRAM iff dram_resident and not cached)."""

    bulk: BulkStore | None = None
    dram_resident: np.ndarray | None = None  # (R,) bool

    @property
    def dram_occupancy(self) -> int:
        """Rows whose current home is the DRAM level (not device, marked
        resident) — the figure the DRAM budget bounds."""
        return int((self.dram_resident & (self.row_slot < 0)).sum())


@dataclasses.dataclass
class BulkAsyncCacheState(AsyncCacheState):
    """AsyncCacheState plus the third tier (see BulkCacheState)."""

    bulk: BulkStore | None = None
    dram_resident: np.ndarray | None = None  # (R,) bool

    @property
    def dram_occupancy(self) -> int:
        """Rows whose current home is the DRAM level."""
        return int((self.dram_resident & (self.row_slot < 0)).sum())


@dataclasses.dataclass(frozen=True)
class BulkCachedEmbeddingBagCollection(CachedEmbeddingBagCollection):
    """Three-tier cached collection: HBM cache -> DRAM capacity -> bulk.

    The capacity array keeps FULL height and stays the single
    authoritative value store — promotion copies a row's (identical) bits
    from the bulk store into capacity, demotion copies capacity bits out —
    so every two-tier oracle (dense single-host, sync-vs-async, chaos
    replay) stays bit-exact by construction, and `dram_rows >= total_rows`
    (or <= 0) degenerates EXACTLY to the parent's two-tier behaviour with
    zero bulk traffic. What the third tier adds is the residency
    accounting, the chunked promotion/demotion pipelines with injected
    block latency, and the per-tier counters (`TierCacheStats`):

      admit       missing rows not DRAM-resident promote from bulk first
                  (`_stage_capacity` hook, "bulk.fetch" fault site,
                  chunked `BulkStore.read`), then fetch to device as usual;
      evict       displaced rows land in DRAM (`_absorb_evictions` hook);
                  when DRAM occupancy exceeds `dram_rows`, the coldest
                  DRAM rows (lazily-decayed EMA score — the admission
                  policy run backwards) demote via chunked writes;
      async       bulk latency is a deadline paid at `commit_async` — the
                  stream that stages batch k+1 behind batch k's compute
                  hides it the same way it hides the capacity fetch.
    """

    dram_rows: int = 0         # DRAM budget in rows; <= 0 or >= total rows
                               # disables the bulk tier (pure two-tier)
    bulk_chunk: int = 32       # bulk block height in rows (device blocks)
    bulk_latency_us: float = 50.0  # injected latency per block access
    bulk_path: str | None = None   # mmap the bulk payload at this .npy path

    _stats_cls: ClassVar[type] = TierCacheStats

    @classmethod
    def build(cls, cfg: DLRMConfig, cache_rows: int | None = None,
              strategy: str = "cached_host", decay: float = 0.98,
              use_kernel: bool | None = None, interpret: bool = False,
              ema_admission: bool = True, fetch_chunk: int = 1,
              dram_rows: int = 0, bulk_chunk: int = 32,
              bulk_latency_us: float = 50.0, bulk_path: str | None = None
              ) -> BulkCachedEmbeddingBagCollection:
        """Build over a fresh single-shard EmbeddingBagCollection; see the
        class fields for the tier knobs."""
        ebc = EmbeddingBagCollection.build(cfg, n_shards=1, strategy=strategy)
        rows = cache_rows if cache_rows is not None else ebc.plan.cache_rows
        assert rows > 0, "cached_host plan produced an empty cache"
        return cls(ebc, int(rows), decay, use_kernel, interpret,
                   ema_admission, int(fetch_chunk),
                   dram_rows=int(dram_rows), bulk_chunk=int(bulk_chunk),
                   bulk_latency_us=float(bulk_latency_us),
                   bulk_path=bulk_path)

    def _dram_cap(self) -> int:
        """Effective DRAM budget in rows (total height when disabled)."""
        r = self.ebc.plan.total_rows
        if self.dram_rows <= 0 or self.dram_rows >= r:
            return r
        return int(self.dram_rows)

    # -- state ---------------------------------------------------------------

    def _bulk_wrap(self, base, cls, mega: jax.Array,
                   accum: jax.Array | None):
        """Extend a freshly-initialised two-tier state with the bulk store
        and residency mask. Cold start: with a real budget every row
        begins in BULK (the table height >> DRAM scenario) and the working
        set promotes on first touch; with the tier disabled every row is
        DRAM-resident and the store is empty."""
        r, d = mega.shape
        if self._dram_cap() >= r:
            dram = np.ones((r,), bool)
            bulk = BulkStore.build(0, int(d), self.bulk_chunk,
                                   self.bulk_latency_us)
        else:
            dram = np.zeros((r,), bool)
            bulk = BulkStore.build(r, int(d), self.bulk_chunk,
                                   self.bulk_latency_us, self.bulk_path,
                                   dtype=np.asarray(mega).dtype)
            acc = np.zeros((r,), np.float32) if accum is None \
                else np.asarray(accum, np.float32)
            bulk.seed(np.arange(r), np.asarray(mega), acc)
        fields = dataclasses.fields(type(base))
        return cls(**{f.name: getattr(base, f.name) for f in fields},
                   bulk=bulk, dram_resident=dram)

    def init_state(self, mega: jax.Array,
                   accum: jax.Array | None = None) -> BulkCacheState:
        """Three-tier `init_state` (see the parent for the buffer
        contract)."""
        base = super().init_state(mega, accum)
        return self._bulk_wrap(base, BulkCacheState, mega, accum)

    def init_async_state(self, mega: jax.Array,
                         accum: jax.Array | None = None
                         ) -> BulkAsyncCacheState:
        """Three-tier async `init_state` twin."""
        base = super().init_async_state(mega, accum)
        return self._bulk_wrap(base, BulkAsyncCacheState, mega, accum)

    # -- tier hooks ----------------------------------------------------------

    def _stage_capacity(self, state, missing: np.ndarray) -> None:
        """Promote `missing` rows that live in bulk into the DRAM capacity
        array before the device fetch reads it. The "bulk.fetch" guard
        fires BEFORE any mutation (stats included) so a propagated fault
        leaves the whole admission cleanly replayable; the chunked
        `BulkStore.read` schedules its latency deadline, paid inline on
        the sync path and at commit on the async one."""
        if len(missing) == 0:
            return
        promote = missing[~state.dram_resident[missing]]
        if len(promote):
            _fetch_guard(self.injector, self.retry, site="bulk.fetch")
        s = state.stats
        s.dram_hits += len(missing) - len(promote)
        if not len(promote):
            return
        vals, acc = state.bulk.read(promote, s)
        rows_j = jnp.asarray(promote, jnp.int32)
        state.capacity = state.capacity.at[rows_j].set(
            jnp.asarray(vals, state.capacity.dtype))
        state.cap_accum = state.cap_accum.at[rows_j].set(
            jnp.asarray(acc, jnp.float32))
        state.dram_resident[promote] = True
        s.bulk_hits += len(promote)
        if not isinstance(state, AsyncCacheState):
            state.bulk.wait(s)     # sync path consumes immediately

    def _absorb_evictions(self, state, evicted_rows: np.ndarray) -> None:
        """Rows displaced from the device tier fall back to DRAM; demote
        the coldest DRAM rows when that overflows the budget."""
        ev = np.asarray(evicted_rows, np.int64).ravel()
        ev = ev[ev >= 0]
        if len(ev):
            state.dram_resident[ev] = True
        self._demote_overflow(state, ev)

    def _demote_overflow(self, state, exclude: np.ndarray) -> None:
        """Demote the coldest DRAM-resident rows (lazily-decayed EMA
        score, the admission policy run backwards) until occupancy fits
        `dram_rows`. `exclude` (this call's fresh evictions) never demote
        in the same breath — in the async stream their dirty writeback may
        still be queued. Older queued writebacks that intersect the victim
        set drain first (commit_async), so a demotion always reads
        post-writeback capacity values."""
        r = len(state.dram_resident)
        cap = self._dram_cap()
        if cap >= r:
            return
        cand_mask = state.dram_resident & (state.row_slot < 0)
        over = int(cand_mask.sum()) - cap
        if over <= 0:
            return
        if len(exclude):
            cand_mask[exclude] = False
        cand = np.flatnonzero(cand_mask)
        over = min(over, len(cand))
        if over <= 0:
            return
        scores = _ema_score(state.ema, state.ema_tick, cand, state.tick,
                            self.decay)
        order = np.argsort(scores, kind="stable")
        victims = np.sort(cand[order[:over]])
        if isinstance(state, AsyncCacheState) and state.pending:
            queued = [p.evict_rows[p.evict_rows >= 0]
                      for p in state.pending]
            qwb = np.concatenate(queued) if queued \
                else np.empty((0,), np.int64)
            if len(qwb) and np.intersect1d(victims, qwb).size:
                self.commit_async(state)
        vidx = jnp.asarray(victims, jnp.int32)
        vals = np.asarray(jnp.take(state.capacity, vidx, axis=0))
        acc = np.asarray(jnp.take(state.cap_accum, vidx))
        state.bulk.write(victims, vals, acc, state.stats)
        state.dram_resident[victims] = False
        if not isinstance(state, AsyncCacheState):
            state.bulk.wait(state.stats)

    # -- async consumption point ---------------------------------------------

    def commit_async(self, astate) -> int:
        """Commit pending fetches, paying whatever part of the scheduled
        bulk latency batch k's compute did not hide (the deadline model —
        see BulkStore.wait)."""
        bulk = getattr(astate, "bulk", None)
        if bulk is not None:
            bulk.wait(astate.stats)
        return super().commit_async(astate)

    # -- introspection -------------------------------------------------------

    def tier_residency(self, state) -> dict[str, np.ndarray]:
        """Exclusive per-row membership masks {hbm, dram, bulk} — they
        partition the row space by construction; tests/test_tiers.py
        fuzzes promotion/demotion interleavings against exactly this."""
        hbm = state.row_slot >= 0
        dram = ~hbm & state.dram_resident
        bulk = ~hbm & ~state.dram_resident
        return {"hbm": hbm, "dram": dram, "bulk": bulk}

    def placement(self) -> dict:
        """Static three-level layout, fastest first."""
        r = self.ebc.plan.total_rows
        return {"strategy": "cached_bulk", "stream": "sync",
                "levels": [
                    {"tier": "hbm", "rows": self.cache_rows},
                    {"tier": "dram", "rows": self._dram_cap()},
                    {"tier": "bulk", "rows": r,
                     "chunk": self.bulk_chunk,
                     "latency_us": self.bulk_latency_us,
                     "mmap": bool(self.bulk_path)}]}

    # -- checkpointing -------------------------------------------------------

    def state_dict(self, state) -> dict:
        """Parent snapshot (drained/unwound) + the residency mask. The
        bulk payload itself is NOT saved: bulk rows are bit-identical to
        their capacity values by construction, so restore rebuilds the
        store from capacity."""
        d = super().state_dict(state)
        d["dram_resident"] = np.asarray(state.dram_resident).copy()
        return d

    def load_state_dict(self, d: dict):
        """Rebuild the three-tier state: the parent restores the two-tier
        half (stats come back as TierCacheStats via `_stats_cls`), then
        the bulk store is re-seeded from capacity for every
        non-DRAM-resident row."""
        dram = np.array(d["dram_resident"], bool)
        base = super().load_state_dict(
            {k: v for k, v in d.items() if k != "dram_resident"})
        cls = BulkAsyncCacheState if isinstance(base, AsyncCacheState) \
            else BulkCacheState
        fields = dataclasses.fields(type(base))
        st = cls(**{f.name: getattr(base, f.name) for f in fields},
                 bulk=None, dram_resident=dram)
        r, dim = st.capacity.shape
        if self._dram_cap() >= r:
            st.bulk = BulkStore.build(0, int(dim), self.bulk_chunk,
                                      self.bulk_latency_us)
            return st
        st.bulk = BulkStore.build(r, int(dim), self.bulk_chunk,
                                  self.bulk_latency_us, self.bulk_path)
        rows = np.flatnonzero(~dram)
        if len(rows):
            ridx = jnp.asarray(rows, jnp.int32)
            st.bulk.seed(rows, np.asarray(jnp.take(st.capacity, ridx,
                                                   axis=0)),
                         np.asarray(jnp.take(st.cap_accum, ridx)))
        return st


def tier_conformance(obj: Any) -> bool:
    """True iff `obj` structurally satisfies `EmbeddingTier` — the assert
    tests and call sites use instead of hand-rolled hasattr chains."""
    return isinstance(obj, EmbeddingTier)
