"""Software-managed cached embedding tier (paper section IV-B, Figs. 6-8).

The paper's central capacity problem: production embedding tables exceed
device memory, and its Fig. 6/7 show per-row access frequency is highly
skewed AND uncorrelated with table size — exactly the regime where a
software-managed hot-row cache beats static sharding. This module realizes
the "system memory" placement tier as two arrays:

  capacity tier  (total_rows, d)  the full mega table + row-wise AdaGrad
                 accumulator, host-resident / pooled-HBM, slow to touch;
  device cache   (cache_rows, d)  hot rows + their accumulators + an LFU
                 score per slot, sized by plan_placement("cached_host")
                 from the per-chip HBM budget.

`CachedEmbeddingBagCollection` wraps an EmbeddingBagCollection: each step the
host manager extracts the batch's unique global rows, remaps them to cache
slots (fetch-on-miss through the kernels/cache_ops.py exchange, which moves
row + accumulator together), and the device-side lookup/update then runs
entirely against the small cache array — so per-step cost scales with the
cache, not the table. Eviction is frequency-aware (LFU with decay): victims
are the coldest slots outside the current working set; dirty victims write
back to the capacity tier on the way out. Hit/miss/eviction/writeback
counters are first-class metrics (CacheStats).

State handling is split the only way JAX allows: payload arrays (capacity,
cache, accumulators, LFU scores) are jax Arrays updated functionally;
the slot maps (row<->slot, dirty bits) are host numpy, mutated in place —
eviction choice is data-dependent and lives on the host anyway (the same
split as CacheEmbedding's ChunkParamMgr and MTrainS's tier manager).
"""
from __future__ import annotations

import dataclasses
from typing import Any, ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.embedding import EmbeddingBagCollection
from repro.kernels import cache_ops
from repro.kernels.sparse_plan import coalesce_rows


@dataclasses.dataclass
class CacheStats:
    """First-class cache metrics. A miss is a CAPACITY-TIER FETCH: one per
    unique missing row per batch — that row's further accesses in the same
    batch are served from the just-filled slot and count as hits, like every
    other access (the FBGEMM/UVM-cache convention: hit_rate = 1 -
    unique_misses / accesses). fetches/evictions/writebacks count rows."""
    hits: int = 0
    misses: int = 0
    fetches: int = 0           # unique rows pulled from the capacity tier
    evictions: int = 0         # slots whose resident row was displaced
    writebacks: int = 0        # dirty evictions flushed to capacity
    prefetched: int = 0        # rows admitted ahead of use (pipeline hook)
    fetch_chunks: int = 0      # DMA descriptors issued by chunked fetches
    overfetch_rows: int = 0    # padding rows chunked fetches over-read
    steps: int = 0

    @property
    def hit_rate(self) -> float:
        """hits / (hits + misses); 0.0 before any traffic."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict[str, float]:
        """Flat metrics dict (the train-loop logging payload)."""
        return {"cache_hits": float(self.hits),
                "cache_misses": float(self.misses),
                "cache_hit_rate": self.hit_rate,
                "cache_fetches": float(self.fetches),
                "cache_evictions": float(self.evictions),
                "cache_writebacks": float(self.writebacks),
                "cache_prefetched": float(self.prefetched),
                "cache_fetch_chunks": float(self.fetch_chunks),
                "cache_overfetch_rows": float(self.overfetch_rows)}

    def reset(self) -> None:
        """Zero every counter in place. Benchmark sweeps call this between
        candidates sharing one process (benchmarks/cache_bench.py) so
        per-candidate figures can never silently accumulate across runs;
        works for subclasses too (iterates the dataclass fields)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


@dataclasses.dataclass
class CacheState:
    """Mutable two-tier state: device hot-row cache over a host capacity
    tier, plus the host-side slot maps and frequency counters."""

    capacity: jax.Array        # (R, d) slow tier — the full mega table
    cap_accum: jax.Array       # (R,) fp32 AdaGrad accumulator, slow tier
    cache: jax.Array           # (C, d) device tier — hot rows
    cache_accum: jax.Array     # (C,) fp32 accumulators of cached rows
    freq: jax.Array            # (C,) fp32 LFU-with-decay score per slot
    slot_row: np.ndarray       # (C,) int64: global row held by slot, -1 free
    row_slot: np.ndarray       # (R,) int32: slot holding row, -1 uncached
    dirty: np.ndarray          # (C,) bool: slot updated since fetch
    ema: np.ndarray            # (R,) fp32 EMA-decayed per-row access counts
    ema_tick: np.ndarray       # (R,) int64 tick of each row's last EMA touch
    tick: int                  # EMA clock: one tick per planned batch
    stats: CacheStats

    @property
    def cache_rows(self) -> int:
        """Device-tier height C (slots)."""
        return int(self.cache.shape[0])

    @property
    def resident(self) -> int:
        """Number of occupied cache slots."""
        return int((self.slot_row >= 0).sum())


@dataclasses.dataclass
class PendingCommit:
    """One staged admission waiting for its step-boundary commit.

    The shadow slab holds the fetched capacity rows (dispatched while the
    in-flight batch computes); slots/evict_rows are the commit worklist
    (evict_rows[i] >= 0 means slot i's dirty victim writes back first).
    This is the pending-eviction writeback queue entry of the async design
    (docs/cache.md)."""
    epoch: int
    slots: np.ndarray          # (n,) cache slots to fill at commit
    evict_rows: np.ndarray     # (n,) capacity row for dirty writeback, -1 none
    rows: np.ndarray           # (n,) global rows being admitted
    victim_slots: np.ndarray   # (v,) slots whose resident was displaced
    ws_mask: np.ndarray        # (C,) bool: staged batch's full working set
    shadow: jax.Array | None        # (m, d) fetched rows, m >= n if chunked
    shadow_accum: jax.Array | None  # (m,) fetched accumulators
    src_pos: np.ndarray | None = None  # (n,) shadow row per entry (chunked
                                       # fetch); None = one row per entry


@dataclasses.dataclass
class StagedBatch:
    """A batch whose admission has been staged ahead of use: the remapped
    slot indices + the idx fingerprint `take` uses to match it. hits/misses
    record the plan's stat contribution so a discarded (mismatched) plan
    can be re-booked as a prefetch instead of a phantom step."""
    epoch: int
    idx_key: np.ndarray        # (B, F, L) global idx the plan was made for
    local: np.ndarray          # (B, F, L) slot-space remap
    ws_mask: np.ndarray        # (C,) bool working-set slots
    hits: int                  # stat delta booked at plan time
    misses: int


@dataclasses.dataclass
class AsyncCacheState:
    """Double-buffered cache state for the async exchange stream.

    Differences vs CacheState:
      * `freq` lives on the HOST (np.float32): victim selection must never
        block the planner on device work — the whole point of the stream is
        that planning + fetch overlap the in-flight batch's compute.
      * `slot_epoch` tags each slot with the epoch at which its resident
        row was admitted. Together with working-set protection it enforces
        the pipeline invariant: a slot admitted at epoch k+1 (pending) is
        never read or written by the in-flight epoch-k batch, so in-flight
        gradients always land in the slab their remap was planned against.
      * `pending` is the ordered commit queue (fetches in flight); host
        maps are flipped EAGERLY at plan time (the cheap slot-map swap), so
        later plans see the post-commit view while the device catches up.
    """
    capacity: jax.Array        # (R, d) slow tier — the full mega table
    cap_accum: jax.Array       # (R,) fp32 AdaGrad accumulator, slow tier
    cache: jax.Array           # (C, d) device tier — hot rows
    cache_accum: jax.Array     # (C,) fp32 accumulators of cached rows
    freq: np.ndarray           # (C,) HOST fp32 LFU-with-decay scores
    slot_row: np.ndarray       # (C,) int64: global row held by slot, -1 free
    row_slot: np.ndarray       # (R,) int32: slot holding row, -1 uncached
    dirty: np.ndarray          # (C,) bool: slot updated since fetch
    slot_epoch: np.ndarray     # (C,) int64: admission epoch per slot
    epoch: int                 # last epoch issued
    pending: list[PendingCommit]
    inflight_mask: np.ndarray | None   # (C,) bool: in-flight working set
    staged: StagedBatch | None
    ema: np.ndarray            # (R,) fp32 EMA-decayed per-row access counts
    ema_tick: np.ndarray       # (R,) int64 tick of each row's last EMA touch
    tick: int                  # EMA clock: one tick per planned batch
    stats: CacheStats

    @property
    def cache_rows(self) -> int:
        """Device-tier height C (slots)."""
        return int(self.cache.shape[0])

    @property
    def resident(self) -> int:
        """Number of occupied cache slots."""
        return int((self.slot_row >= 0).sum())


def _pick_slots(slot_row: np.ndarray, freq: np.ndarray, n: int,
                protect: np.ndarray, thrash_detail: str
                ) -> tuple[np.ndarray, np.ndarray]:
    """The ONE slot-selection policy of every admission path (sync, async,
    and per-host multi-host): free slots first, then the coldest
    unprotected residents (stable argsort of the LFU scores), with the
    cache-thrash guard raised when the protected working set leaves too
    few victims. Returns (slots (n,), victims) — victims occupy the TAIL
    of `slots`, the layout the exchange worklists rely on."""
    free = np.flatnonzero(slot_row < 0)
    need = n - len(free)
    victims = np.empty((0,), np.int64)
    if need > 0:
        evictable = np.flatnonzero((slot_row >= 0) & ~protect)
        if len(evictable) < need:
            raise ValueError(
                f"cache thrash: need {need} evictions but only "
                f"{len(evictable)} unprotected slots — {thrash_detail}")
        order = np.argsort(np.asarray(freq)[evictable], kind="stable")
        victims = evictable[order[:need]]
    return np.concatenate([free[:min(n, len(free))], victims])[:n], victims


def _ema_score(ema: np.ndarray, ema_tick: np.ndarray, rows: np.ndarray,
               now: int, decay: float) -> np.ndarray:
    """Lazily-decayed EMA read: each row's counter decays by `decay` per
    tick, but only the touched rows are ever written — the decay owed since
    a row's last touch is applied on read (score = ema * decay**age), so
    the (R,)-sized state needs no per-step dense pass."""
    age = (now - ema_tick[rows]).astype(np.float32)
    return ema[rows] * np.power(np.float32(decay), age)


def _ema_touch(ema: np.ndarray, ema_tick: np.ndarray, rows: np.ndarray,
               counts: np.ndarray, now: int, decay: float) -> None:
    """Fold one batch's access counts into the per-row EMA (in place):
    settle each touched row's owed decay, add its counts, stamp the tick.
    After the call `ema[rows]` holds the post-touch scores — the admission
    seeds of the EMA policy (a re-admitted row re-enters at its historical
    frequency instead of this batch's count, so one cold burst cannot
    churn it out of the cache before the burst rows themselves decay)."""
    ema[rows] = _ema_score(ema, ema_tick, rows, now, decay) \
        + counts.astype(np.float32)
    ema_tick[rows] = now


def _gate_admission(slot_row: np.ndarray, freq: np.ndarray,
                    protect: np.ndarray, missing: np.ndarray,
                    scores: np.ndarray) -> np.ndarray:
    """The adaptive admission threshold of the EMA policy, for best-effort
    paths (prefetch / stage_rows with `gate=True`): rows that fit free
    slots always admit; beyond that, candidates (EMA scores descending)
    admit only while they STRICTLY beat the coldest unprotected residents
    (slot freq ascending) — so admission is monotone in a row's access
    frequency and a one-off cold burst (score ~1) cannot displace the hot
    head (asserted in tests/test_cache_admission.py). Returns a (len
    (missing),) bool keep-mask; strict planned batches never gate (every
    planned row MUST become resident for bit-exactness)."""
    n = len(missing)
    free = int((slot_row < 0).sum())
    if n <= free:
        return np.ones((n,), bool)
    evictable = np.flatnonzero((slot_row >= 0) & ~protect)
    vic_scores = np.sort(np.asarray(freq)[evictable])
    order = np.argsort(-scores, kind="stable")
    admit = np.zeros((n,), bool)
    admit[order[:free]] = True
    rest = order[free:]
    k = min(len(rest), len(vic_scores))
    if k:
        beats = scores[rest[:k]] > vic_scores[:k]
        # descending candidates vs ascending victims: the first failure
        # ends the admitted prefix
        n_admit = k if beats.all() else int(np.argmin(beats))
        admit[rest[:n_admit]] = True
    return admit


def _chunk_min_fill(chunk: int) -> int:
    """Minimum member rows for a coalesced block to beat per-row DMAs:
    blocks at least ~3/4 full keep the over-fetch payload below the
    descriptor savings (launch/analysis.cache_admission_traffic prices the
    trade); sparser blocks fall back to the per-row fetch path."""
    return max(2, (3 * chunk + 3) // 4)


def _chunked_shadow_fetch(capacity: jax.Array, cap_accum: jax.Array,
                          missing: np.ndarray, chunk: int, stats: CacheStats,
                          use_kernel: bool | None, interpret: bool
                          ) -> tuple[jax.Array, jax.Array, np.ndarray]:
    """Chunk-granular shadow fetch with density-adaptive fallback, shared
    by the sync and async admission paths: coalesce the sorted miss list
    into contiguous blocks, fetch dense blocks block-wise
    (cache_ops.cache_fetch_chunked — one DMA descriptor per block) and the
    isolated remainder row-wise, concatenated into one shadow slab. Books
    `fetch_chunks` (descriptors) and `overfetch_rows` (block padding) on
    `stats`. Returns (shadow, shadow_accum, src_pos) — src_pos[i] is miss
    i's row inside the slab, the `cache_ops.cache_commit` install remap."""
    total = int(capacity.shape[0])
    chunk = min(chunk, total)
    starts, pos = coalesce_rows(missing, chunk, total,
                                min_fill=_chunk_min_fill(chunk))
    single = np.flatnonzero(pos < 0)
    src_pos = pos.copy()
    src_pos[single] = len(starts) * chunk + np.arange(len(single),
                                                      dtype=np.int32)
    parts = []
    if len(starts):
        parts.append(cache_ops.cache_fetch_chunked(
            capacity, cap_accum, jnp.asarray(starts), chunk,
            use_kernel=use_kernel, interpret=interpret))
    if len(single):
        parts.append(cache_ops.cache_fetch(
            capacity, cap_accum, jnp.asarray(missing[single], jnp.int32),
            use_kernel=use_kernel, interpret=interpret))
    if len(parts) == 2:
        shadow = jnp.concatenate([parts[0][0], parts[1][0]])
        shadow_accum = jnp.concatenate([parts[0][1], parts[1][1]])
    else:
        shadow, shadow_accum = parts[0]
    stats.fetch_chunks += len(starts) + len(single)
    stats.overfetch_rows += len(starts) * chunk - (len(missing) - len(single))
    return shadow, shadow_accum, src_pos


def _fetch_guard(injector, retry, site: str = "cache.fetch") -> int:
    """Fire a fault-injection `site` with bounded retry-with-backoff
    (docs/fault_tolerance.md). Default site: "cache.fetch" (training);
    the serving tier reuses the same guard with "serve.fetch" /
    "serve.admit" (serve/dlrm_engine.py).

    Stands in front of every capacity-tier fetch dispatch: a scheduled
    transient fault (any exception with a truthy `transient` attribute —
    duck-typed so core/ never imports train/fault_tolerance) is retried up
    to `retry.max_retries` times with `retry.sleep(attempt)` backoff;
    exhaustion or a non-transient fault propagates to the driver, whose
    DegradationManager decides whether to fall back to the strict_sync
    schedule. Crucially the guard sits BEFORE any host-map mutation of the
    admission path it protects, so a propagated fault leaves the tier
    consistent and the step can simply be replayed. Returns the number of
    retries burned (0 when no injector is armed or nothing fired)."""
    if injector is None:
        return 0
    attempt = 0
    while True:
        try:
            injector.fire(site)
        except Exception as e:
            if not getattr(e, "transient", False) or retry is None \
                    or attempt >= retry.max_retries:
                raise
            attempt += 1
            retry.sleep(attempt)
            continue
        return attempt


@dataclasses.dataclass
class StaleRowSnapshot:
    """Read-only last-known-good row values for degrade-don't-die serving.

    The serving tier records every row it successfully fetches from the
    capacity tier; when a later fetch faults (or the circuit breaker is in
    stale_only), misses resolve from this snapshot instead — zeros for rows
    never seen. The tier is READ-ONLY in serving, so a recorded value can
    never go stale relative to the capacity tier: "stale" responses differ
    from the oracle only on never-seen (zero-filled) rows, which is exactly
    the `degraded=True` contract (docs/serving.md).

    Host-side numpy on purpose: the degraded path must not depend on the
    device tier being reachable."""

    values: np.ndarray         # (R, d) last-known-good rows, host copy
    seen: np.ndarray           # (R,) bool: row has been recorded at least once

    @classmethod
    def empty(cls, total_rows: int, dim: int,
              dtype=np.float32) -> StaleRowSnapshot:
        """Zero-filled snapshot covering `total_rows` rows of width `dim`."""
        return cls(values=np.zeros((total_rows, dim), dtype),
                   seen=np.zeros((total_rows,), bool))

    def record(self, rows: np.ndarray, values: np.ndarray) -> None:
        """Remember `values` ((n, d), host or device) for global `rows`."""
        rows = np.asarray(rows)
        if len(rows) == 0:
            return
        self.values[rows] = np.asarray(values, self.values.dtype)
        self.seen[rows] = True

    def gather(self, rows: np.ndarray) -> np.ndarray:
        """(n, d) last-known-good values for `rows`; zeros where unseen."""
        rows = np.asarray(rows)
        out = self.values[rows].copy()
        out[~self.seen[rows]] = 0
        return out

    @property
    def coverage(self) -> float:
        """Fraction of the row space with a recorded value."""
        return float(self.seen.mean()) if len(self.seen) else 0.0


@dataclasses.dataclass(frozen=True)
class CachedEmbeddingBagCollection:
    """EmbeddingBagCollection whose device working set is a hot-row cache.

    The wrapped collection's `mega` param IS the capacity tier; `lookup`
    results are numerically identical to the uncached collection (rows are
    moved bit-exactly and pooled by the same code path).
    """
    ebc: EmbeddingBagCollection
    cache_rows: int
    decay: float = 0.98        # LFU decay per step (1.0 = pure LFU; lower
                               # adapts faster but churns the tail more)
    use_kernel: bool | None = None
    interpret: bool = False
    ema_admission: bool = True  # seed admitted slots with the row's EMA
                                # score (historical frequency) instead of
                                # this batch's count — False restores
                                # first-touch count seeding
    fetch_chunk: int = 1       # capacity->cache transfer granularity in
                               # rows: >1 coalesces the sorted miss list
                               # into contiguous blocks (one DMA descriptor
                               # per block); 1 = per-row transfers
    injector: Any = None       # train.fault_tolerance.FaultInjector firing
                               # the "cache.fetch" site ahead of every
                               # capacity-tier fetch dispatch (tests/chaos)
    retry: Any = None          # RetryPolicy (duck-typed: max_retries +
                               # sleep) bounding transient-fault retries in
                               # `_fetch_guard`; None = fail fast

    # stats flavour hook: the bulk-backed tier (core/tiers.py) swaps in
    # TierCacheStats so per-tier counters ride every state/checkpoint path
    _stats_cls: ClassVar[type] = CacheStats

    @classmethod
    def build(cls, cfg: DLRMConfig, cache_rows: int | None = None,
              strategy: str = "cached_host", decay: float = 0.98,
              use_kernel: bool | None = None,
              interpret: bool = False, ema_admission: bool = True,
              fetch_chunk: int = 1) -> CachedEmbeddingBagCollection:
        """Build over a fresh single-shard EmbeddingBagCollection; see the
        class fields for the knobs."""
        ebc = EmbeddingBagCollection.build(cfg, n_shards=1, strategy=strategy)
        rows = cache_rows if cache_rows is not None else ebc.plan.cache_rows
        assert rows > 0, "cached_host plan produced an empty cache"
        return cls(ebc, int(rows), decay, use_kernel, interpret,
                   ema_admission, int(fetch_chunk))

    # -- state ---------------------------------------------------------------

    def init_state(self, mega: jax.Array,
                   accum: jax.Array | None = None) -> CacheState:
        """mega: (total_rows, d) capacity-tier table (e.g. params["emb"]
        ["mega"]); accum: optional (total_rows,) AdaGrad accumulator.

        The state COPIES mega/accum once and owns its buffers from then on:
        every subsequent exchange donates them to XLA so the swap updates
        rows in place instead of moving the whole tier (the caller's arrays
        stay valid; arrays handed out by `materialize` may be donated again
        by later flushes)."""
        r, d = mega.shape
        assert r == self.ebc.plan.total_rows, (r, self.ebc.plan.total_rows)
        c = self.cache_rows
        if accum is None:
            accum = jnp.zeros((r,), jnp.float32)
        return CacheState(
            capacity=jnp.array(mega, copy=True),
            cap_accum=jnp.array(accum, jnp.float32, copy=True),
            cache=jnp.zeros((c, d), mega.dtype),
            cache_accum=jnp.zeros((c,), jnp.float32),
            freq=jnp.zeros((c,), jnp.float32),
            slot_row=np.full((c,), -1, np.int64),
            row_slot=np.full((r,), -1, np.int32),
            dirty=np.zeros((c,), bool),
            ema=np.zeros((r,), np.float32),
            ema_tick=np.zeros((r,), np.int64),
            tick=0,
            stats=self._stats_cls())

    # -- admission -----------------------------------------------------------

    @staticmethod
    def _split_batch(idx, row_slot: np.ndarray, cache_rows: int, plan=None):
        """Shared batch parsing for the sync and async planners (their
        behavioural equality is the bit-exactness contract): pad mask,
        unique rows with counts, thrash guard, resident/missing split.

        `plan` (a host SparsePlan over idx in GLOBAL row space, e.g.
        `kernels.host_plan_from_batch`'s) short-circuits the np.unique sort:
        the plan's live prefix IS the sorted unique row set and its offset
        diffs are the counts — the batch was already bucketed once in the
        reader thread, so the miss planning rides that same artifact
        (identical outputs, asserted in tests/test_dedup_forward.py).
        Returns (idx, valid, rows, counts, hit_slots, hit_counts, missing,
        miss_counts)."""
        idx = np.asarray(idx)
        valid = idx >= 0
        if plan is not None:
            prows = np.asarray(plan.unique_rows)
            n_live = int((prows >= 0).sum())
            rows = prows[:n_live].astype(np.int64)
            counts = np.diff(np.asarray(plan.bag_offsets)[:n_live + 1]
                             .astype(np.int64))
        else:
            rows, counts = np.unique(idx[valid], return_counts=True)
        if len(rows) > cache_rows:
            raise ValueError(
                f"batch touches {len(rows)} unique rows > cache_rows="
                f"{cache_rows}; raise the HBM budget or shrink the "
                "batch")
        resident = row_slot[rows] >= 0
        return (idx, valid, rows, counts, row_slot[rows[resident]],
                counts[resident], rows[~resident], counts[~resident])

    @staticmethod
    def _remap(row_slot: np.ndarray, idx: np.ndarray,
               valid: np.ndarray) -> np.ndarray:
        """Global rows -> cache slots (-1 pads preserved)."""
        local = row_slot[np.where(valid, idx, 0)]
        return np.where(valid, local, -1).astype(np.int32)

    # -- tier hooks (overridden by the bulk-backed tier, core/tiers.py) ------

    def _stage_capacity(self, state, missing: np.ndarray) -> None:
        """Pre-fetch tier hook: every admission path calls this with the
        sorted unique `missing` rows right before the capacity tier is
        read. The two-tier collection stages nothing — capacity IS its
        slowest tier. The bulk-backed tier overrides this to promote
        bulk-resident rows into the DRAM capacity array (behind the
        "bulk.fetch" fault site, guard fired before any mutation) so the
        device fetch that follows reads current values."""

    def _absorb_evictions(self, state, evicted_rows: np.ndarray) -> None:
        """Post-eviction tier hook: every admission path calls this after
        the host maps are updated, with the global rows displaced from the
        device tier. The two-tier collection needs nothing — evicted rows
        already live in capacity. The bulk-backed tier overrides this to
        account the rows DRAM-resident and demote the coldest DRAM rows to
        the bulk store when the DRAM budget overflows."""

    def _admit(self, state: CacheState, missing: np.ndarray,
               seeds: np.ndarray, protect: np.ndarray) -> int:
        """Bring `missing` global rows (SORTED ascending) into cache slots,
        evicting the coldest unprotected slots. `seeds` holds the slots'
        initial LFU scores (batch counts, or EMA scores under the EMA
        admission policy); `protect` is a (C,) bool mask of slots that must
        survive (the current working set). Returns rows written back."""
        n = len(missing)
        if n == 0:
            return 0
        # fault-injection gate BEFORE any host-map mutation: a propagated
        # transient fault leaves the tier consistent for a step replay
        _fetch_guard(self.injector, self.retry)
        # tier hook: promote bulk-resident rows into capacity before the
        # fetch below reads it (no-op on the two-tier collection)
        self._stage_capacity(state, missing)
        slots, victims = _pick_slots(
            state.slot_row, state.freq, n, protect,
            f"the batch working set exceeds cache_rows={state.cache_rows};"
            " raise the HBM budget or shrink the batch")
        evicted_rows = state.slot_row[victims]
        wb_mask = state.dirty[victims]
        # worklist: dirty victims write back; every admitted slot fetches
        evict_rows = np.full((n,), -1, np.int64)
        evict_rows[len(slots) - len(victims):] = np.where(
            wb_mask, evicted_rows, -1)
        if self.fetch_chunk > 1:
            # chunk-granular transfer: coalesce the sorted miss list into
            # contiguous blocks, fetch dense blocks block-wise (isolated
            # misses fall back row-wise), install row-wise through the
            # commit's src_pos remap — bit-identical to the fused exchange
            # (values are copies either way)
            shadow, shadow_accum, pos = _chunked_shadow_fetch(
                state.capacity, state.cap_accum, missing, self.fetch_chunk,
                state.stats, self.use_kernel, self.interpret)
            (state.capacity, state.cache, state.cap_accum,
             state.cache_accum) = cache_ops.cache_commit(
                state.capacity, state.cache, state.cap_accum,
                state.cache_accum, shadow, shadow_accum,
                jnp.asarray(slots, jnp.int32),
                jnp.asarray(evict_rows, jnp.int32),
                jnp.asarray(missing, jnp.int32),
                use_kernel=self.use_kernel, interpret=self.interpret,
                src_pos=jnp.asarray(pos))
            state.freq = state.freq.at[jnp.asarray(slots, jnp.int32)].set(
                jnp.asarray(seeds, jnp.float32))
        else:
            (state.capacity, state.cache, state.cap_accum, state.cache_accum,
             state.freq) = cache_ops.cache_exchange(
                state.capacity, state.cache, state.cap_accum,
                state.cache_accum, state.freq, jnp.asarray(slots, jnp.int32),
                jnp.asarray(evict_rows, jnp.int32),
                jnp.asarray(missing, jnp.int32),
                jnp.asarray(seeds, jnp.float32),
                use_kernel=self.use_kernel, interpret=self.interpret)
        # host maps
        state.row_slot[evicted_rows] = -1
        state.slot_row[slots] = missing
        state.row_slot[missing] = slots.astype(np.int32)
        state.dirty[slots] = False
        # tier hook: evicted rows fall back to the next tier down
        self._absorb_evictions(state, evicted_rows)
        state.stats.fetches += n
        state.stats.evictions += len(victims)
        state.stats.writebacks += int(wb_mask.sum())
        return int(wb_mask.sum())

    def prepare(self, state: CacheState, idx, train: bool = True,
                plan=None) -> np.ndarray:
        """Make every row of `idx` cache-resident and remap to slot space.

        idx: (B, F, L) OFFSET global rows (-1 pads), host or device array.
        Returns (B, F, L) int32 cache-slot indices (-1 pads preserved) —
        feed these to `lookup_cached` / the cached train step. When `train`,
        the working set's slots are marked dirty (they will receive sparse
        updates) so eviction writes them back. `plan` (host SparsePlan in
        global row space) replaces the miss planner's np.unique sort with
        the reader thread's bucketing — see `_split_batch`.
        """
        (idx, valid, rows, counts, hit_slots, hit_counts, missing,
         miss_counts) = self._split_batch(idx, state.row_slot,
                                          state.cache_rows, plan)
        # LFU accounting: decay everything, bump hit slots; admitted slots
        # are seeded by _admit below.
        state.freq = cache_ops.lfu_touch(
            state.freq, jnp.asarray(hit_slots, jnp.int32),
            jnp.asarray(hit_counts, jnp.float32), decay=self.decay)
        # per-ROW EMA (capacity row space, survives eviction): one tick per
        # planned batch, decay settled lazily on touch
        state.tick += 1
        _ema_touch(state.ema, state.ema_tick, rows, counts, state.tick,
                   self.decay)
        protect = np.zeros((state.cache_rows,), bool)
        protect[hit_slots] = True
        # EMA admission: a re-admitted row re-enters at its historical
        # frequency (post-touch EMA score) instead of this batch's count
        seeds = state.ema[missing] if self.ema_admission \
            else miss_counts.astype(np.float32)
        self._admit(state, missing, seeds, protect)
        state.stats.hits += int(counts.sum()) - len(missing)
        state.stats.misses += len(missing)
        state.stats.steps += 1
        if train:
            state.dirty[state.row_slot[rows]] = True
        return self._remap(state.row_slot, idx, valid)

    def prefetch(self, state: CacheState, rows, gate: bool = False) -> int:
        """Best-effort admission of `rows` (unique global rows, e.g. the
        NEXT batch's deduplicated indices from the pipeline hook) so the
        capacity-tier fetch overlaps the current step's compute. Does not
        touch hit/miss accounting and never evicts the rows it brings in;
        overflow beyond free+evictable space is dropped. `gate=True` adds
        the EMA admission threshold (`_gate_admission`): beyond the free
        slots, a row is admitted only if its EMA score strictly beats the
        coldest unprotected resident's — speculative admissions cannot
        churn the hot head. Returns the number of rows admitted."""
        rows = np.unique(np.asarray(rows))
        rows = rows[rows >= 0]
        missing = rows[state.row_slot[rows] < 0]
        protect = np.zeros((state.cache_rows,), bool)
        keep = state.row_slot[rows[state.row_slot[rows] >= 0]]
        protect[keep] = True
        # seed = EMA score + 1 (this request counts as one access; EMA
        # itself is only touched by planned batches), or 1.0 first-touch
        if self.ema_admission:
            seeds = _ema_score(state.ema, state.ema_tick, missing,
                               state.tick, self.decay) + np.float32(1.0)
        else:
            seeds = np.ones((len(missing),), np.float32)
        if gate and len(missing):
            keep_mask = _gate_admission(state.slot_row,
                                        np.asarray(state.freq), protect,
                                        missing, seeds)
            missing, seeds = missing[keep_mask], seeds[keep_mask]
        evictable = int(((state.slot_row >= 0) & ~protect).sum())
        free = int((state.slot_row < 0).sum())
        missing, seeds = missing[:free + evictable], seeds[:free + evictable]
        self._admit(state, missing, seeds, protect)
        state.stats.prefetched += len(missing)
        return len(missing)

    # -- lookup --------------------------------------------------------------

    def lookup_cached(self, state: CacheState, local_idx,
                      rules=None) -> jax.Array:
        """Pooled lookup against the device cache. local_idx: (B, F, L)
        slot indices from `prepare`. Pure device function — jit-friendly."""
        return self.ebc.lookup({"mega": state.cache},
                               jnp.asarray(local_idx), rules)

    def lookup(self, state: CacheState, idx, train: bool = False,
               rules=None) -> jax.Array:
        """prepare + lookup_cached: numerically identical to
        `EmbeddingBagCollection.lookup` on the same (global) indices."""
        return self.lookup_cached(state, self.prepare(state, idx, train),
                                  rules)

    # -- training ------------------------------------------------------------

    def plan_to_slots(self, state, batch: dict) -> dict:
        """Relabel a host-built sparse bucketing plan (data.sparse_plan_hook,
        GLOBAL row space) onto the cache slab: unique rows map through
        row_slot (a bijection over the batch's — by now resident — working
        set), then the runs are RE-SORTED by slot so the plan invariant
        (live prefix strictly ascending) survives the relabel — the dedup'd
        forward's compact-buffer remap searches the row list and requires
        it sorted. Permuting whole runs is free for the fused backward:
        each unique row's update is independent and its within-run order is
        untouched, so the result stays bit-identical (asserted in
        tests/test_sparse_fused.py / test_dedup_forward.py). Call AFTER
        prepare/take_async. Accepts CacheState or AsyncCacheState; returns
        the three plan keys for the device batch.
        """
        rows = np.asarray(batch["plan_rows"])
        offs = np.asarray(batch["plan_offsets"]).astype(np.int64)
        bags = np.asarray(batch["plan_bags"], np.int32)
        n_live = int((rows >= 0).sum())        # pads trail (planner sorts)
        slots = state.row_slot[rows[:n_live]].astype(np.int64)
        order = np.argsort(slots, kind="stable")
        lengths = np.diff(offs[:n_live + 1])[order]
        new_rows = np.full(rows.shape, -1, np.int32)
        new_rows[:n_live] = slots[order]
        new_offs = offs.copy()                 # tail already == n_valid
        new_offs[:n_live + 1] = np.concatenate(
            [[0], np.cumsum(lengths)])
        # permute the bag list segment-wise to follow its runs
        n_valid = int(offs[n_live])
        starts = offs[:n_live][order]
        ends = np.cumsum(lengths)
        gather = (np.repeat(starts - np.concatenate([[0], ends[:-1]]),
                            lengths) + np.arange(n_valid)) \
            if n_live else np.empty((0,), np.int64)
        new_bags = bags.copy()
        new_bags[:n_valid] = bags[gather]
        return {"plan_rows": new_rows,
                "plan_offsets": new_offs.astype(np.int32),
                "plan_bags": new_bags}

    def mark_updated(self, state, new_cache: jax.Array,
                     new_cache_accum: jax.Array) -> None:
        """Install post-update cache arrays (dirty bits were already set by
        `prepare(train=True)` / the async plan). Accepts CacheState or
        AsyncCacheState."""
        state.cache = new_cache
        state.cache_accum = new_cache_accum

    # -- writeback -----------------------------------------------------------

    def flush(self, state: CacheState) -> int:
        """Write every dirty slot back to the capacity tier (rows stay
        cached, now clean). Returns rows written back."""
        slots = np.flatnonzero(state.dirty)
        if len(slots) == 0:
            return 0
        (state.capacity, state.cache, state.cap_accum, state.cache_accum,
         state.freq) = cache_ops.cache_exchange(
            state.capacity, state.cache, state.cap_accum, state.cache_accum,
            state.freq, jnp.asarray(slots, jnp.int32),
            jnp.asarray(state.slot_row[slots], jnp.int32),
            jnp.full((len(slots),), -1, jnp.int32),
            jnp.zeros((len(slots),), jnp.float32),
            use_kernel=self.use_kernel, interpret=self.interpret)
        state.dirty[slots] = False
        state.stats.writebacks += len(slots)
        return len(slots)

    def materialize(self, state: CacheState
                    ) -> tuple[jax.Array, jax.Array]:
        """Flush and return the up-to-date (mega, accum) capacity arrays —
        what a checkpoint or an uncached evaluator should read."""
        self.flush(state)
        return state.capacity, state.cap_accum

    # -- EmbeddingTier protocol surface (core/tiers.py) ----------------------

    def take(self, state: CacheState, idx, train: bool = True,
             plan=None) -> np.ndarray:
        """Protocol `take` (core/tiers.py EmbeddingTier): make the batch
        current and return its device-tier index remap. The sync tier
        plans, fetches, and installs inside this one call — `prepare` by
        its protocol name."""
        return self.prepare(state, idx, train=train, plan=plan)

    def stage(self, state: CacheState, idx, train: bool = True,
              plan=None) -> np.ndarray | None:
        """Protocol `stage` (overlap the NEXT batch's fetch): the sync
        tier performs every fetch inside its own `take`, so there is
        nothing to stage ahead — returns None."""
        return None

    def prefetch_rows(self, state: CacheState, rows,
                      gate: bool = False) -> int:
        """Protocol alias of `prefetch`: best-effort admission of unique
        global `rows` ahead of use. Returns rows admitted."""
        return self.prefetch(state, rows, gate=gate)

    def commit(self, state: CacheState) -> int:
        """Protocol `commit`: the sync tier installs fetched rows inside
        `take`, so nothing is ever pending — returns 0."""
        return 0

    def stats(self, state: CacheState) -> CacheStats:
        """Protocol accessor for the tier's CacheStats."""
        return state.stats

    def placement(self) -> dict:
        """Static tier layout, fastest level first (protocol accessor;
        the bulk-backed tier appends its third level)."""
        return {"strategy": "cached_host", "stream": "sync",
                "levels": [{"tier": "hbm", "rows": self.cache_rows},
                           {"tier": "dram",
                            "rows": self.ebc.plan.total_rows}]}

    # -- async exchange stream (docs/cache.md "Async fetch stream") ----------
    #
    # Per-step protocol (k = in-flight batch):
    #
    #   take_async(k)      pop the staged plan for batch k (or plan now on a
    #                      cold start / strict-sync fallback), mark its
    #                      working set in-flight, then COMMIT every pending
    #                      fetch — dispatched after batch k-1's update, so
    #                      dirty-victim writebacks read post-update rows.
    #   <device step k dispatched against the committed cache slab>
    #   stage_async(k+1)   plan batch k+1's admission on the host, dispatch
    #                      the capacity-tier fetch into a fresh shadow slab
    #                      (reads tiers only — overlaps step k's compute),
    #                      flip the host slot maps eagerly, queue the commit.
    #
    # Victim selection protects the union of the in-flight working set and
    # every queued plan's working set, so a slot admitted at epoch k+1 is
    # never one batch k still reads/writes (the slot_epoch invariant).

    def init_async_state(self, mega: jax.Array,
                         accum: jax.Array | None = None) -> AsyncCacheState:
        """Async twin of init_state: same owned-buffer contract (exchange
        kernels donate the tiers), host-resident LFU scores, empty commit
        queue at epoch 0."""
        r, d = mega.shape
        assert r == self.ebc.plan.total_rows, (r, self.ebc.plan.total_rows)
        c = self.cache_rows
        if accum is None:
            accum = jnp.zeros((r,), jnp.float32)
        return AsyncCacheState(
            capacity=jnp.array(mega, copy=True),
            cap_accum=jnp.array(accum, jnp.float32, copy=True),
            cache=jnp.zeros((c, d), mega.dtype),
            cache_accum=jnp.zeros((c,), jnp.float32),
            freq=np.zeros((c,), np.float32),
            slot_row=np.full((c,), -1, np.int64),
            row_slot=np.full((r,), -1, np.int32),
            dirty=np.zeros((c,), bool),
            slot_epoch=np.zeros((c,), np.int64),
            epoch=0,
            pending=[],
            inflight_mask=None,
            staged=None,
            ema=np.zeros((r,), np.float32),
            ema_tick=np.zeros((r,), np.int64),
            tick=0,
            stats=self._stats_cls())

    def _protected_mask(self, astate: AsyncCacheState) -> np.ndarray:
        """Slots no plan may evict: the in-flight batch's working set,
        every queued (uncommitted) plan's working set, AND the staged
        batch's working set. The staged mask must be carried independently
        of the queue: a drain (below) commits and clears the staged plan's
        pending entry while its remap is still outstanding — evicting its
        slots then would silently invalidate `StagedBatch.local`."""
        protect = np.zeros((astate.cache_rows,), bool)
        if astate.inflight_mask is not None:
            protect |= astate.inflight_mask
        if astate.staged is not None:
            protect |= astate.staged.ws_mask
        for p in astate.pending:
            protect |= p.ws_mask
        return protect

    def _drain_if_fetching_queued_victims(self, astate: AsyncCacheState,
                                          missing: np.ndarray) -> None:
        """A row being fetched whose DIRTY eviction is still queued would
        read a stale capacity value (its latest value lives in the victim
        slot until that writeback commits). Drain the queue first in that
        case — committing early is always safe: the queued writebacks
        consume `astate.cache`, which already carries every dispatched
        update, so ordering is preserved by data dependency. Only the
        fetch-ahead overlap of the drained entries is lost."""
        if not len(missing) or not astate.pending:
            return
        queued = [p.evict_rows[p.evict_rows >= 0] for p in astate.pending]
        queued_wb = np.concatenate(queued) if queued else queued
        if len(queued_wb) and np.intersect1d(missing, queued_wb).size:
            self.commit_async(astate)

    def _admit_async(self, astate: AsyncCacheState, missing: np.ndarray,
                     extra_protect: np.ndarray, seed: np.ndarray,
                     strict: bool, gate: bool = False) -> PendingCommit:
        """Shared admission core of `_plan_async` and `stage_rows`: drain
        the queue if a missing row's dirty eviction is still pending,
        choose free slots then coldest unprotected victims, dispatch the
        shadow fetch, flip the host maps eagerly, and queue the commit.

        `seed` holds per-missing-row LFU seeds (EMA scores under the EMA
        admission policy, else batch counts for plans / 1.0 for prefetch).
        `strict=True` raises on overflow (a planned batch MUST become
        resident); `strict=False` truncates `missing` (best-effort
        prefetch), and with `gate=True` also applies the EMA admission
        threshold (`_gate_admission`) first. Returns the queued
        PendingCommit, whose ws_mask covers the admitted slots (callers
        widen it for full batch working sets)."""
        self._drain_if_fetching_queued_victims(astate, missing)
        protect = self._protected_mask(astate) | extra_protect
        if not strict:
            if gate and len(missing):
                keep = _gate_admission(astate.slot_row, astate.freq,
                                       protect, missing, seed)
                missing, seed = missing[keep], seed[keep]
            free = int((astate.slot_row < 0).sum())
            evictable = int(((astate.slot_row >= 0) & ~protect).sum())
            missing = missing[:free + evictable]
            seed = seed[:len(missing)]
        n = len(missing)
        slots, victims = _pick_slots(
            astate.slot_row, astate.freq, n, protect,
            "the staged + in-flight working sets exceed cache_rows="
            f"{astate.cache_rows}; raise the HBM budget, shrink the "
            "batch, or reduce the lookahead depth")
        evicted_rows = astate.slot_row[victims]
        wb_mask = astate.dirty[victims]
        evict_rows = np.full((n,), -1, np.int64)
        evict_rows[len(slots) - len(victims):] = np.where(
            wb_mask, evicted_rows, -1)
        src_pos = None
        if n:
            # fault gate first: staged plans that die here leave the maps
            # unflipped and the queue intact (the batch re-plans at take)
            _fetch_guard(self.injector, self.retry)
            # tier hook: promote bulk-resident rows into capacity before
            # the shadow fetch below reads it (no-op on the two-tier
            # collection); its own "bulk.fetch" guard also fires pre-mutation
            self._stage_capacity(astate, missing)
            # fetch into a fresh shadow slab — reads the tiers only, so it
            # overlaps the in-flight batch's device compute
            if self.fetch_chunk > 1:
                shadow, shadow_accum, src_pos = _chunked_shadow_fetch(
                    astate.capacity, astate.cap_accum, missing,
                    self.fetch_chunk, astate.stats, self.use_kernel,
                    self.interpret)
            else:
                shadow, shadow_accum = cache_ops.cache_fetch(
                    astate.capacity, astate.cap_accum,
                    jnp.asarray(missing, jnp.int32),
                    use_kernel=self.use_kernel, interpret=self.interpret)
        else:
            shadow = shadow_accum = None
        epoch = astate.epoch + 1
        astate.epoch = epoch
        # eagerly flip the host maps to the post-commit view (the cheap
        # slot-map swap): later plans see these admissions as resident
        astate.row_slot[evicted_rows] = -1
        astate.slot_row[slots] = missing
        astate.row_slot[missing] = slots.astype(np.int32)
        astate.dirty[slots] = False
        astate.freq[slots] = seed.astype(np.float32)
        astate.slot_epoch[slots] = epoch
        ws_mask = np.zeros((astate.cache_rows,), bool)
        ws_mask[slots] = True
        astate.stats.fetches += n
        astate.stats.evictions += len(victims)
        astate.stats.writebacks += int(wb_mask.sum())
        pending = PendingCommit(epoch, slots.astype(np.int64), evict_rows,
                                missing, victims, ws_mask, shadow,
                                shadow_accum, src_pos)
        if n:                                  # nothing to commit for all-hit
            astate.pending.append(pending)
        # tier hook AFTER the queue append: an overflow demotion that must
        # drain pending dirty writebacks then sees this entry too
        self._absorb_evictions(astate, evicted_rows)
        return pending

    def _plan_async(self, astate: AsyncCacheState, idx: np.ndarray,
                    train: bool, plan=None) -> StagedBatch:
        """Plan one batch's admission: host-side LFU accounting + victim
        choice, dispatch the shadow fetch, flip the maps, queue the commit.
        Never blocks on device work. `plan` replaces the np.unique sort
        with the reader thread's bucketing — see `_split_batch`."""
        (idx, valid, rows, counts, hit_slots, hit_counts, missing,
         miss_counts) = self._split_batch(idx, astate.row_slot,
                                          astate.cache_rows, plan)
        # host LFU (same math as kernels/ref.lfu_touch_ref, in np.float32):
        # decay everything, bump hit slots; admitted slots seeded by admit
        astate.freq *= np.float32(self.decay)
        astate.freq[hit_slots] += hit_counts.astype(np.float32)
        # per-ROW EMA, same clock discipline as the sync `prepare`
        astate.tick += 1
        _ema_touch(astate.ema, astate.ema_tick, rows, counts, astate.tick,
                   self.decay)
        extra = np.zeros((astate.cache_rows,), bool)
        extra[hit_slots] = True
        n = len(missing)
        seeds = astate.ema[missing] if self.ema_admission \
            else miss_counts.astype(np.float32)
        pending = self._admit_async(astate, missing, extra, seeds,
                                    strict=True)
        ws_slots = astate.row_slot[rows]
        pending.ws_mask[ws_slots] = True       # widen: full batch working set
        if train:
            astate.dirty[ws_slots] = True
        hits = int(counts.sum()) - n
        astate.stats.hits += hits
        astate.stats.misses += n
        astate.stats.steps += 1
        return StagedBatch(pending.epoch, idx.copy(),
                           self._remap(astate.row_slot, idx, valid),
                           pending.ws_mask, hits, n)

    def stage_async(self, astate: AsyncCacheState, idx,
                    train: bool = True, plan=None) -> np.ndarray:
        """Stage the NEXT batch: plan + dispatch its shadow fetch while the
        in-flight batch computes. Returns the slot-space remap, which
        `take_async` hands back when the batch becomes current."""
        staged = self._plan_async(astate, idx, train, plan)
        astate.staged = staged
        return staged.local

    def stage_rows(self, astate: AsyncCacheState, rows,
                   gate: bool = False) -> int:
        """Best-effort k-step-lookahead admission (the async twin of
        `prefetch`): queue a fetch for `rows` without hit/miss accounting
        and without evicting any protected slot; overflow beyond
        free+evictable space is dropped. `gate=True` adds the EMA admission
        threshold (see `prefetch`). Returns rows admitted."""
        rows = np.unique(np.asarray(rows))
        rows = rows[rows >= 0]
        missing = rows[astate.row_slot[rows] < 0]
        if len(missing) == 0:
            return 0
        extra = np.zeros((astate.cache_rows,), bool)
        keep = astate.row_slot[rows[astate.row_slot[rows] >= 0]]
        extra[keep] = True                     # requested residents survive
        if self.ema_admission:
            seeds = _ema_score(astate.ema, astate.ema_tick, missing,
                               astate.tick, self.decay) + np.float32(1.0)
        else:
            seeds = np.ones((len(missing),), np.float32)
        pending = self._admit_async(astate, missing, extra, seeds,
                                    strict=False, gate=gate)
        n = len(pending.rows)
        astate.stats.prefetched += n
        return n

    def take_async(self, astate: AsyncCacheState, idx,
                   train: bool = True, plan=None) -> np.ndarray:
        """Make `idx`'s batch current: reuse its staged plan when one
        matches (the overlapped path), else plan it now (cold start /
        strict-sync fallback). Marks the working set in-flight and commits
        every pending fetch — the commit is dispatched after the previous
        batch's update, so dirty-victim writebacks read post-update rows.
        Returns the (B, F, L) slot-space indices."""
        idx = np.asarray(idx)
        st = astate.staged
        astate.staged = None
        if st is None or st.idx_key.shape != idx.shape or \
                not np.array_equal(st.idx_key, idx):
            if st is not None:
                # the discarded plan degrades to a prefetch: its rows were
                # admitted, but its batch never runs — re-book its stat
                # contribution so steps/hit-rate reflect real batches only
                astate.stats.hits -= st.hits
                astate.stats.misses -= st.misses
                astate.stats.steps -= 1
                astate.stats.prefetched += st.misses
            st = self._plan_async(astate, idx, train, plan)
        astate.inflight_mask = st.ws_mask
        self.commit_async(astate)
        return st.local

    def commit_async(self, astate: AsyncCacheState) -> int:
        """Drain the pending queue in order: each entry's dirty victims
        write back (post-update values) and its shadow rows install into
        their slots. Cheap device-side row copies — the slow capacity fetch
        already happened off the critical path. Returns entries committed."""
        done = 0
        for p in astate.pending:
            if len(p.slots) == 0:
                continue
            (astate.capacity, astate.cache, astate.cap_accum,
             astate.cache_accum) = cache_ops.cache_commit(
                astate.capacity, astate.cache, astate.cap_accum,
                astate.cache_accum, p.shadow, p.shadow_accum,
                jnp.asarray(p.slots, jnp.int32),
                jnp.asarray(p.evict_rows, jnp.int32),
                jnp.asarray(p.rows, jnp.int32),
                use_kernel=self.use_kernel, interpret=self.interpret,
                src_pos=None if p.src_pos is None
                else jnp.asarray(p.src_pos, jnp.int32))
            done += 1
        astate.pending.clear()
        return done

    def lookup_async(self, astate: AsyncCacheState, idx,
                     train: bool = False, rules=None) -> jax.Array:
        """take_async + cache lookup: numerically identical to the sync
        `lookup` and to the uncached collection on the same indices."""
        local = self.take_async(astate, idx, train)
        return self.ebc.lookup({"mega": astate.cache},
                               jnp.asarray(local), rules)

    def flush_async(self, astate: AsyncCacheState) -> int:
        """Commit all pending fetches, then write every dirty slot back to
        the capacity tier (rows stay cached, now clean). Returns rows
        written back."""
        self.commit_async(astate)
        slots = np.flatnonzero(astate.dirty)
        if len(slots) == 0:
            return 0
        (astate.capacity, astate.cache, astate.cap_accum, astate.cache_accum,
         _) = cache_ops.cache_exchange(
            astate.capacity, astate.cache, astate.cap_accum,
            astate.cache_accum, jnp.asarray(astate.freq),
            jnp.asarray(slots, jnp.int32),
            jnp.asarray(astate.slot_row[slots], jnp.int32),
            jnp.full((len(slots),), -1, jnp.int32),
            jnp.zeros((len(slots),), jnp.float32),
            use_kernel=self.use_kernel, interpret=self.interpret)
        astate.dirty[slots] = False
        astate.stats.writebacks += len(slots)
        return len(slots)

    def materialize_async(self, astate: AsyncCacheState
                          ) -> tuple[jax.Array, jax.Array]:
        """flush_async and return the up-to-date (mega, accum) capacity
        arrays — bit-identical to the sync path's `materialize` after the
        same batch sequence (asserted in tests/test_cache_async.py)."""
        self.flush_async(astate)
        return astate.capacity, astate.cap_accum

    # -- checkpointing -------------------------------------------------------

    def state_dict(self, state: CacheState | AsyncCacheState) -> dict:
        """Checkpoint-ready pytree of numpy leaves covering the WHOLE tier —
        both device arrays (capacity/cache/accumulators) and the host-side
        maps (slot_row/row_slot/dirty/EMA) that a params-only checkpoint
        would lose, leaving the restored job re-warming a cold cache and
        diverging from the uninterrupted run (accumulators live per-slot
        while a row is cached).

        For AsyncCacheState the pending queue is drained to a sync point
        first (commit_async) and a staged-but-unconsumed plan is unwound to
        a prefetch exactly as take_async does on an idx mismatch — its rows
        stay admitted, and the restored run re-plans the batch against the
        now-resident rows, so the model math is unchanged. Mutates `state`
        (drain + unwind) before snapshotting it."""
        is_async = isinstance(state, AsyncCacheState)
        if is_async:
            self.commit_async(state)
            st = state.staged
            state.staged = None
            if st is not None:
                state.stats.hits -= st.hits
                state.stats.misses -= st.misses
                state.stats.steps -= 1
                state.stats.prefetched += st.misses
            state.inflight_mask = None
        d = {k: np.asarray(getattr(state, k)) for k in
             ("capacity", "cap_accum", "cache", "cache_accum", "freq",
              "slot_row", "row_slot", "dirty", "ema", "ema_tick")}
        d["tick"] = np.int64(state.tick)
        d["stats"] = {k: np.int64(v)
                      for k, v in dataclasses.asdict(state.stats).items()}
        if is_async:
            d["slot_epoch"] = np.asarray(state.slot_epoch)
            d["epoch"] = np.int64(state.epoch)
        return d

    def load_state_dict(self, d: dict) -> CacheState | AsyncCacheState:
        """Rebuild the tier from a `state_dict` pytree (leaves may come back
        as jax arrays from CheckpointManager.restore — each is coerced to
        the side init_state/init_async_state put it on). The presence of
        the async-only `epoch` key selects the state flavour."""
        stats = self._stats_cls(**{k: int(v) for k, v in d["stats"].items()})
        dev = {k: jnp.asarray(d[k]) for k in
               ("capacity", "cap_accum", "cache", "cache_accum")}
        # restored leaves may alias read-only device buffers; the host-side
        # maps are mutated in place by the planner, so force owned copies
        host = dict(
            slot_row=np.array(d["slot_row"], np.int64),
            row_slot=np.array(d["row_slot"], np.int32),
            dirty=np.array(d["dirty"], bool),
            ema=np.array(d["ema"], np.float32),
            ema_tick=np.array(d["ema_tick"], np.int64))
        if "epoch" in d:
            return AsyncCacheState(
                **dev, freq=np.array(d["freq"], np.float32), **host,
                slot_epoch=np.array(d["slot_epoch"], np.int64),
                epoch=int(d["epoch"]), pending=[], inflight_mask=None,
                staged=None, tick=int(d["tick"]), stats=stats)
        return CacheState(**dev, freq=jnp.asarray(d["freq"]), **host,
                          tick=int(d["tick"]), stats=stats)


# ---------------------------------------------------------------------------
# Multi-host cache coherence (docs/cache.md "Multi-host coherence")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RouteStats:
    """Per-row traffic counters of the multi-host tier: which shard served
    each capacity-tier touch. `local` means the touching host owns the row
    (owner == host); `remote` rows crossed the host interconnect — the
    all-to-all legs the exchange-traffic model prices
    (launch/analysis.py multihost_exchange_traffic)."""
    fetch_local: int = 0       # miss rows served by the host's own shard
    fetch_remote: int = 0      # miss rows pulled from a remote owner
    refresh_local: int = 0     # post-update working-set rows, own shard
    refresh_remote: int = 0    # ... returned by a remote owner
    grad_pairs_local: int = 0  # (row, bag) grads aggregated at a local owner
    grad_pairs_remote: int = 0  # pairs routed to a remote owner
    dup_rows: int = 0          # rows in >1 host's working set (reduced ONCE
                               # at the owner instead of updated twice)
    invalidations: int = 0     # cached copies dropped after a remote update
    fetch_chunks: int = 0      # per-(host, owner) DMA descriptors after
                               # run-coalescing the miss messages
    steps: int = 0

    @property
    def remote_fetch_fraction(self) -> float:
        """Fraction of fetched rows served by a REMOTE owner shard."""
        total = self.fetch_local + self.fetch_remote
        return self.fetch_remote / total if total else 0.0

    def snapshot(self) -> dict[str, float]:
        """Flat metrics dict (the train-loop logging payload)."""
        return {"route_fetch_local": float(self.fetch_local),
                "route_fetch_remote": float(self.fetch_remote),
                "route_refresh_remote": float(self.refresh_remote),
                "route_grad_pairs_remote": float(self.grad_pairs_remote),
                "route_dup_rows": float(self.dup_rows),
                "route_invalidations": float(self.invalidations),
                "route_fetch_chunks": float(self.fetch_chunks),
                "route_remote_fetch_fraction": self.remote_fetch_fraction}

    def reset(self) -> None:
        """Zero every counter in place (the RouteStats side of the sweep
        isolation contract — see `CacheStats.reset`)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


@dataclasses.dataclass
class MultiHostCacheState:
    """State of the data-parallel cached tier: ONE row-sharded capacity
    tier (owner h holds rows [h*shard_rows, (h+1)*shard_rows)) under H
    independent per-host hot caches over the WHOLE row space.

    Cached copies are CLEAN BY CONSTRUCTION — the coherence invariant that
    replaces the single-host dirty-bit machinery: sparse updates are routed
    to the owning shard and applied there ONCE (duplicate rows reduced in
    host order), each host's working set is refreshed from the post-update
    capacity inside the same step, and copies a REMOTE update left stale
    are invalidated before the next batch plans. Eviction therefore never
    writes back, and the AdaGrad accumulator never leaves the owner."""
    capacity: jax.Array        # (R, d) row-sharded capacity tier
    cap_accum: jax.Array       # (R,) fp32 AdaGrad accumulator, owner-only
    caches: jax.Array          # (H, C, d) per-host clean hot caches
    freq: np.ndarray           # (H, C) host fp32 LFU-with-decay scores
    slot_row: np.ndarray       # (H, C) int64: row held by slot, -1 free
    row_slot: np.ndarray       # (H, R) int32: slot holding row, -1 uncached
    ema: np.ndarray            # (R,) fp32 EMA-decayed GLOBAL per-row counts
    ema_tick: np.ndarray       # (R,) int64 tick of each row's last EMA touch
    tick: int                  # EMA clock: one tick per planned batch
    stats: CacheStats          # aggregate over hosts
    route: RouteStats

    @property
    def n_hosts(self) -> int:
        """Host count H (one hot cache each)."""
        return int(self.caches.shape[0])

    @property
    def cache_rows(self) -> int:
        """Per-host device-tier height C (slots)."""
        return int(self.caches.shape[1])


@dataclasses.dataclass
class MultiHostStepPlan:
    """One batch's host-planned device worklist: every array the jitted
    multi-host step consumes (train/steps.py). All index arrays are
    -1-padded to static shapes so the step compiles once."""
    local_idx: np.ndarray      # (H, B/H, F, L) slot-space remap
    miss_rows: np.ndarray      # (H, M) capacity rows to install pre-forward
    miss_slots: np.ndarray     # (H, M) destination cache slots
    ws_rows: np.ndarray        # (H, M) working-set rows to refresh post-update
    ws_slots: np.ndarray       # (H, M) their cache slots
    seg_rows: np.ndarray       # (H, U) OWNER-LOCAL unique rows per segment
    seg_offsets: np.ndarray    # (H, U+1) absolute positions into bag_ids
    seg_base: np.ndarray       # (H,) owner row bases
    bag_ids: np.ndarray        # (N,) shared flat-bag list of the global plan


@dataclasses.dataclass(frozen=True)
class MultiHostCachedEmbeddingBagCollection:
    """The cached embedding tier under data parallelism (ROADMAP multi-host
    coherence item; MTrainS's heterogeneous-memory tier): H hosts each run
    a `cache_rows` hot cache over a capacity tier row-sharded across the
    SAME H hosts. Misses resolve through a plan-driven all-to-all against
    the owning shard — the per-batch SparsePlan's sorted live prefix IS the
    miss set grouped by owner (searchsorted on shard boundaries, no sort) —
    and gradients for rows cached on several hosts are routed to the owner
    and reduced once before the fused AdaGrad update (per-owner segments,
    kernels/sparse_update.py).

    Numerics contract: with the data-parallel batch split h -> examples
    [h*B/H, (h+1)*B/H), owner-side reduction concatenates host runs in
    host order == flat-batch order, so the whole tier is BIT-EXACT vs the
    dense single-host oracle (asserted in tests/test_cache_multihost.py).
    """
    ebc: EmbeddingBagCollection
    n_hosts: int
    cache_rows: int
    decay: float = 0.98
    use_kernel: bool | None = None
    interpret: bool = False
    ema_admission: bool = True  # same policy as the single-host tier; the
                                # EMA is GLOBAL (row space), shared by all
                                # hosts' admission decisions
    fetch_chunk: int = 1       # all-to-all miss-message granularity in
                               # rows: >1 coalesces each (host, owner)
                               # message's sorted rows into contiguous
                               # blocks (booked in RouteStats.fetch_chunks)
    injector: Any = None       # FaultInjector firing "cache.fetch" once
                               # per planned global batch (before any host
                               # map mutates — a fault leaves plan_step
                               # cleanly replayable)
    retry: Any = None          # RetryPolicy for transient faults, as in
                               # the single-host tier

    @classmethod
    def build(cls, cfg: DLRMConfig, n_hosts: int,
              cache_rows: int | None = None, decay: float = 0.98,
              use_kernel: bool | None = None, interpret: bool = False,
              ema_admission: bool = True, fetch_chunk: int = 1
              ) -> MultiHostCachedEmbeddingBagCollection:
        """Build over a fresh `n_hosts`-sharded EmbeddingBagCollection; see
        the class fields for the knobs."""
        ebc = EmbeddingBagCollection.build(cfg, n_shards=n_hosts,
                                           strategy="cached_host",
                                           capacity_shards=n_hosts)
        rows = cache_rows if cache_rows is not None else ebc.plan.cache_rows
        assert rows > 0, "cached_host plan produced an empty cache"
        return cls(ebc, int(n_hosts), int(rows), decay, use_kernel,
                   interpret, ema_admission, int(fetch_chunk))

    @property
    def shard_rows(self) -> int:
        """Capacity rows owned by each host shard."""
        return self.ebc.plan.shard_rows

    # -- state ---------------------------------------------------------------

    def init_state(self, mega: jax.Array, accum: jax.Array | None = None,
                   capacity_sharding=None) -> MultiHostCacheState:
        """mega: (total_rows, d) capacity tier; accum: optional (rows,)
        fp32. `capacity_sharding` (e.g. NamedSharding(mesh, plan.pspec))
        places the copied capacity arrays on the host mesh — the train
        step's shard_map update then runs against real shards.

        A mega SHORTER than total_rows (a single-host layout, whose tail
        padding is 8-aligned rather than H*8-aligned) is zero-padded into
        the sharded layout; pad rows are unreachable by construction
        (indices stay below the logical row count)."""
        r, d = mega.shape
        total = self.ebc.plan.total_rows
        assert r <= total, (r, total)
        h, c = self.n_hosts, self.cache_rows
        if accum is None:
            accum = jnp.zeros((r,), jnp.float32)
        capacity = jnp.zeros((total, d), mega.dtype).at[:r].set(mega)
        cap_accum = jnp.zeros((total,), jnp.float32).at[:r].set(
            jnp.asarray(accum, jnp.float32))
        if capacity_sharding is not None:
            capacity = jax.device_put(capacity, capacity_sharding)
            from jax.sharding import NamedSharding, PartitionSpec as P
            cap_sh = NamedSharding(capacity_sharding.mesh,
                                   P(*capacity_sharding.spec[:1]))
            cap_accum = jax.device_put(cap_accum, cap_sh)
        return MultiHostCacheState(
            capacity=capacity,
            cap_accum=cap_accum,
            caches=jnp.zeros((h, c, d), mega.dtype),
            freq=np.zeros((h, c), np.float32),
            slot_row=np.full((h, c), -1, np.int64),
            row_slot=np.full((h, total), -1, np.int32),
            ema=np.zeros((total,), np.float32),
            ema_tick=np.zeros((total,), np.int64),
            tick=0,
            stats=CacheStats(),
            route=RouteStats())

    # -- per-host admission --------------------------------------------------

    def _admit_host(self, state: MultiHostCacheState, h: int,
                    missing: np.ndarray, seeds: np.ndarray,
                    protect: np.ndarray) -> np.ndarray:
        """Assign cache slots on host h for `missing` rows: free slots
        first, then the coldest unprotected residents. `seeds` holds the
        slots' initial LFU scores (EMA scores under the EMA admission
        policy, else batch counts). Clean caches make eviction
        writeback-free — the displaced copy is dropped (its authoritative
        value lives at the owner). Returns the slots."""
        n = len(missing)
        if n == 0:
            return np.empty((0,), np.int64)
        slots, victims = _pick_slots(
            state.slot_row[h], state.freq[h], n, protect,
            f"host {h}'s batch working set exceeds cache_rows="
            f"{state.cache_rows}; raise the HBM budget or shrink the "
            "per-host batch")
        evicted = state.slot_row[h, victims]
        state.row_slot[h, evicted] = -1
        state.slot_row[h, slots] = missing
        state.row_slot[h, missing] = slots.astype(np.int32)
        state.freq[h, slots] = seeds.astype(np.float32)
        state.stats.fetches += n
        state.stats.evictions += len(victims)
        return slots

    # -- step planning -------------------------------------------------------

    def plan_step(self, state: MultiHostCacheState, idx,
                  host_plans=None, global_plan=None,
                  train: bool = True) -> MultiHostStepPlan:
        """Plan one global batch: per host, split its contiguous sub-batch
        into hits/misses off its sub-plan (`kernels.split_plan_by_host` —
        the live prefix IS the host's sorted unique row set, so miss dedup
        stays sort-free), admit misses (LFU eviction, clean drop), and
        remap to slot space. Cross-host legs are booked in RouteStats by
        grouping each host's rows by owning shard (a row // shard_rows,
        order-preserving on the sorted prefix). When `train`, also slices
        the global plan into per-owner update segments
        (`split_plan_by_owner`) and invalidates cached copies that this
        step's REMOTE updates will leave stale (working-set copies are
        exempt — the step refreshes them from the post-update capacity).

        idx: (B, F, L) OFFSET global rows, B divisible by n_hosts;
        host_plans/global_plan: hook-attached artifacts
        (`kernels.host_plans_from_batch` / `host_plan_from_batch`), built
        here when absent. Mutates the host maps; returns the device
        worklist for the jitted step half."""
        from repro.kernels.sparse_plan import (build_sparse_plan_host,
                                               split_plan_by_host,
                                               split_plan_by_owner)
        # fault gate before ANY mutation (tick/EMA/maps): a propagated
        # transient fault makes this call a clean no-op to replay
        _fetch_guard(self.injector, self.retry)
        idx = np.asarray(idx)
        b, f, lk = idx.shape
        hn = self.n_hosts
        assert b % hn == 0, (b, hn)
        bh = b // hn
        if global_plan is None:
            global_plan = build_sparse_plan_host(idx)
        if host_plans is None:
            host_plans = split_plan_by_host(global_plan, hn, bh * f)
        m = bh * f * lk                       # per-host worklist capacity
        local_idx = np.empty((hn, bh, f, lk), np.int32)
        miss_rows = np.full((hn, m), -1, np.int32)
        miss_slots = np.full((hn, m), -1, np.int32)
        ws_rows = np.full((hn, m), -1, np.int32)
        ws_slots = np.full((hn, m), -1, np.int32)
        g_rows = np.asarray(global_plan.unique_rows)
        n_live = int((g_rows >= 0).sum())
        dup = -n_live
        state.tick += 1          # one EMA tick per planned global batch
        for h in range(hn):
            sub = idx[h * bh:(h + 1) * bh]
            (sub, valid, rows, counts, hit_slots, hit_counts, missing,
             miss_counts) = CachedEmbeddingBagCollection._split_batch(
                sub, state.row_slot[h], self.cache_rows, host_plans[h])
            dup += len(rows)
            # host LFU: decay everything, bump hits; admissions seed below
            state.freq[h] *= np.float32(self.decay)
            state.freq[h, hit_slots] += hit_counts.astype(np.float32)
            # GLOBAL per-row EMA: hosts touch sequentially, so shared rows
            # accumulate every host's counts at this tick
            _ema_touch(state.ema, state.ema_tick, rows, counts, state.tick,
                       self.decay)
            protect = np.zeros((self.cache_rows,), bool)
            protect[hit_slots] = True
            seeds = state.ema[missing] if self.ema_admission \
                else miss_counts.astype(np.float32)
            slots = self._admit_host(state, h, missing, seeds, protect)
            miss_rows[h, :len(missing)] = missing
            miss_slots[h, :len(missing)] = slots
            ws_rows[h, :len(rows)] = rows
            ws_slots[h, :len(rows)] = state.row_slot[h, rows]
            local_idx[h] = CachedEmbeddingBagCollection._remap(
                state.row_slot[h], sub, valid)
            state.stats.hits += int(counts.sum()) - len(missing)
            state.stats.misses += len(missing)
            owner_m = missing // self.shard_rows
            state.route.fetch_remote += int((owner_m != h).sum())
            state.route.fetch_local += int((owner_m == h).sum())
            if self.fetch_chunk > 1 and len(missing):
                # chunk the per-(host, owner) all-to-all messages: each
                # owner's slice of the sorted miss list coalesces on its
                # own (blocks never straddle shard boundaries)
                chunk = min(self.fetch_chunk, self.shard_rows)
                cuts = np.searchsorted(
                    missing, np.arange(hn + 1) * self.shard_rows)
                for s in range(hn):
                    a, b_ = int(cuts[s]), int(cuts[s + 1])
                    if b_ > a:
                        starts, pos = coalesce_rows(
                            missing[a:b_] - s * self.shard_rows, chunk,
                            self.shard_rows,
                            min_fill=_chunk_min_fill(chunk))
                        n_single = int((pos < 0).sum())
                        descs = len(starts) + n_single
                        state.route.fetch_chunks += descs
                        state.stats.fetch_chunks += descs
                        state.stats.overfetch_rows += \
                            len(starts) * chunk - (b_ - a - n_single)
            if train:
                owner_w = rows // self.shard_rows
                remote = owner_w != h
                state.route.refresh_remote += int(remote.sum())
                state.route.refresh_local += int((~remote).sum())
                state.route.grad_pairs_remote += int(counts[remote].sum())
                state.route.grad_pairs_local += int(counts[~remote].sum())
        state.stats.steps += 1
        state.route.steps += 1
        state.route.dup_rows += max(dup, 0)
        if train:
            touched = g_rows[:n_live].astype(np.int64)
            for h in range(hn):
                slots_t = state.row_slot[h, touched]
                resident = slots_t >= 0
                in_ws = np.zeros((self.cache_rows,), bool)
                wss = ws_slots[h]
                in_ws[wss[wss >= 0]] = True
                kill = resident & ~in_ws[np.clip(slots_t, 0, None)]
                state.slot_row[h, slots_t[kill]] = -1
                state.row_slot[h, touched[kill]] = -1
                state.freq[h, slots_t[kill]] = 0.0
                state.route.invalidations += int(kill.sum())
            seg_rows, seg_offs, seg_base = split_plan_by_owner(
                global_plan, self.shard_rows, hn, seg_cap=len(g_rows))
        else:
            u = len(g_rows)
            seg_rows = np.full((hn, u), -1, np.int32)
            seg_offs = np.zeros((hn, u + 1), np.int32)
            seg_base = np.zeros((hn,), np.int32)
        return MultiHostStepPlan(
            local_idx, miss_rows, miss_slots, ws_rows, ws_slots,
            seg_rows, seg_offs, seg_base,
            np.asarray(global_plan.bag_ids, np.int32))

    # -- slab install (shared by the jitted step and the eager paths) --------

    def fill_slabs(self, caches: jax.Array, source: jax.Array,
                   rows, slots) -> jax.Array:
        """Install `rows` gathered from `source` (the capacity tier) into
        each host's slab at `slots` (-1 pads drop). Pure jnp — traced
        inside the multi-host train step's jit (miss install AND
        post-update refresh) and run eagerly by eval lookups/prefetch, so
        every install leg is the same operation bit for bit.

        caches: (H, C, d); rows/slots: (H, M) int32, -1-padded."""
        c = self.cache_rows
        out = []
        for h in range(self.n_hosts):
            rows_h = jnp.asarray(rows[h], jnp.int32)
            slots_h = jnp.asarray(slots[h], jnp.int32)
            vals = jnp.take(source, jnp.maximum(rows_h, 0), axis=0)
            dst = jnp.where(slots_h >= 0, slots_h, c)
            out.append(caches[h].at[dst].set(vals.astype(caches.dtype),
                                             mode="drop"))
        return jnp.stack(out)

    # -- eval / serving ------------------------------------------------------

    def install_misses(self, state: MultiHostCacheState,
                       splan: MultiHostStepPlan) -> None:
        """Resolve the planned misses eagerly (the all-to-all fetch leg):
        gather each host's missing rows from the owning shards and install
        them in its slab. The train step performs this INSIDE its jit; this
        eager twin serves eval lookups and prefetch."""
        state.caches = self.fill_slabs(state.caches, state.capacity,
                                       splan.miss_rows, splan.miss_slots)

    def lookup(self, state: MultiHostCacheState, idx,
               host_plans=None, global_plan=None) -> jax.Array:
        """plan + fetch + per-host pooled lookup, concatenated back to the
        global batch: numerically identical to the uncached collection on
        the same indices. Eval path (no update legs)."""
        splan = self.plan_step(state, idx, host_plans, global_plan,
                               train=False)
        self.install_misses(state, splan)
        pooled = [self.ebc.lookup({"mega": state.caches[h]},
                                  jnp.asarray(splan.local_idx[h]))
                  for h in range(self.n_hosts)]
        return jnp.concatenate(pooled, axis=0)

    # -- prefetch ------------------------------------------------------------

    def prefetch(self, state: MultiHostCacheState, idx,
                 host_plans=None, global_plan=None,
                 gate: bool = False) -> int:
        """Best-effort admission of the NEXT batch's per-host miss rows so
        the owner fetch overlaps the in-flight step's device compute (the
        dispatch ordering guarantees post-update values — the gather
        consumes the updated capacity array). Never evicts a requested
        resident; overflow beyond free+evictable space is dropped.
        `gate=True` adds the EMA admission threshold per host (see the
        single-host `prefetch`). Returns rows admitted."""
        from repro.kernels.sparse_plan import (build_sparse_plan_host,
                                               split_plan_by_host)
        _fetch_guard(self.injector, self.retry)
        idx = np.asarray(idx)
        b, f, _ = idx.shape
        hn = self.n_hosts
        if global_plan is None:
            global_plan = build_sparse_plan_host(idx)
        if host_plans is None:
            host_plans = split_plan_by_host(global_plan, hn, b // hn * f)
        caches = state.caches
        c = self.cache_rows
        total = 0
        for h in range(hn):
            prows = np.asarray(host_plans[h].unique_rows)
            rows = prows[:int((prows >= 0).sum())].astype(np.int64)
            missing = rows[state.row_slot[h, rows] < 0]
            protect = np.zeros((c,), bool)
            keep = state.row_slot[h, rows[state.row_slot[h, rows] >= 0]]
            protect[keep] = True
            if self.ema_admission:
                seeds = _ema_score(state.ema, state.ema_tick, missing,
                                   state.tick, self.decay) + np.float32(1.0)
            else:
                seeds = np.ones((len(missing),), np.float32)
            if gate and len(missing):
                keep_mask = _gate_admission(state.slot_row[h],
                                            state.freq[h], protect,
                                            missing, seeds)
                missing, seeds = missing[keep_mask], seeds[keep_mask]
            evictable = int(((state.slot_row[h] >= 0) & ~protect).sum())
            free = int((state.slot_row[h] < 0).sum())
            missing, seeds = (missing[:free + evictable],
                              seeds[:free + evictable])
            slots = self._admit_host(state, h, missing, seeds, protect)
            if len(missing):
                vals = jnp.take(state.capacity,
                                jnp.asarray(missing, jnp.int32), axis=0)
                caches = caches.at[h, jnp.asarray(slots, jnp.int32)].set(
                    vals)
            owner = missing // self.shard_rows
            state.route.fetch_remote += int((owner != h).sum())
            state.route.fetch_local += int((owner == h).sum())
            total += len(missing)
        state.caches = caches
        state.stats.prefetched += total
        return total

    def mark_updated(self, state: MultiHostCacheState, capacity: jax.Array,
                     cap_accum: jax.Array, caches: jax.Array) -> None:
        """Install the jitted step's outputs (post-update capacity shards +
        refreshed host slabs)."""
        state.capacity = capacity
        state.cap_accum = cap_accum
        state.caches = caches

    def materialize(self, state: MultiHostCacheState
                    ) -> tuple[jax.Array, jax.Array]:
        """The up-to-date (mega, accum) capacity arrays. No flush needed:
        caches are clean by construction — every update already lives at
        its owner."""
        return state.capacity, state.cap_accum

    # -- EmbeddingTier protocol surface (core/tiers.py) ----------------------

    def take(self, state: MultiHostCacheState, idx, train: bool = True,
             plan=None) -> np.ndarray:
        """Protocol `take`: plan the batch, install its misses eagerly,
        and return the (H, B/H, F, L) slot-space remap. The jitted train
        step uses `plan_step` directly (its device worklist is richer than
        a remap); this entry serves eval / serving call sites. `plan` is
        the global host SparsePlan when the reader thread built one."""
        splan = self.plan_step(state, idx, global_plan=plan, train=train)
        self.install_misses(state, splan)
        return splan.local_idx

    def stage(self, state: MultiHostCacheState, idx, train: bool = True,
              plan=None) -> np.ndarray | None:
        """Protocol `stage`: the multi-host tier overlaps through
        `prefetch` (whole-batch idx) instead of a staged plan — returns
        None."""
        return None

    def prefetch_rows(self, state: MultiHostCacheState, rows,
                      gate: bool = False) -> int:
        """Protocol `prefetch_rows`: the multi-host planner needs the full
        (B, F, L) batch shape to split rows by host (see `prefetch`), so a
        bare row list admits nothing — returns 0."""
        return 0

    def commit(self, state: MultiHostCacheState) -> int:
        """Protocol `commit`: installs happen inside `plan_step`'s device
        worklist (or the eager `install_misses`) — nothing pending."""
        return 0

    def flush(self, state: MultiHostCacheState) -> int:
        """Protocol `flush`: caches are clean by construction (updates are
        owner-routed), so there is never a dirty slot — returns 0."""
        return 0

    def stats(self, state: MultiHostCacheState) -> CacheStats:
        """Protocol accessor for the tier's aggregate CacheStats."""
        return state.stats

    def placement(self) -> dict:
        """Static tier layout, fastest level first (protocol accessor)."""
        return {"strategy": "cached_host", "stream": "multihost",
                "n_hosts": self.n_hosts,
                "levels": [{"tier": "hbm", "rows": self.cache_rows},
                           {"tier": "dram",
                            "rows": self.ebc.plan.total_rows}]}

    # -- checkpointing -------------------------------------------------------

    def state_dict(self, state: MultiHostCacheState) -> dict:
        """Checkpoint-ready pytree of numpy leaves (see the single-host
        CachedEmbeddingBagCollection.state_dict). Nothing to drain: caches
        are clean by construction, so the snapshot is always consistent."""
        d = {k: np.asarray(getattr(state, k)) for k in
             ("capacity", "cap_accum", "caches", "freq",
              "slot_row", "row_slot", "ema", "ema_tick")}
        d["tick"] = np.int64(state.tick)
        d["stats"] = {k: np.int64(v)
                      for k, v in dataclasses.asdict(state.stats).items()}
        d["route"] = {k: np.int64(v)
                      for k, v in dataclasses.asdict(state.route).items()}
        return d

    def load_state_dict(self, d: dict) -> MultiHostCacheState:
        """Rebuild the multi-host tier from a `state_dict` pytree."""
        return MultiHostCacheState(
            capacity=jnp.asarray(d["capacity"]),
            cap_accum=jnp.asarray(d["cap_accum"]),
            caches=jnp.asarray(d["caches"]),
            freq=np.array(d["freq"], np.float32),
            slot_row=np.array(d["slot_row"], np.int64),
            row_slot=np.array(d["row_slot"], np.int32),
            ema=np.array(d["ema"], np.float32),
            ema_tick=np.array(d["ema_tick"], np.int64),
            tick=int(d["tick"]),
            stats=CacheStats(**{k: int(v) for k, v in d["stats"].items()}),
            route=RouteStats(**{k: int(v) for k, v in d["route"].items()}))
