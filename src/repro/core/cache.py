"""Software-managed cached embedding tier (paper section IV-B, Figs. 6-8).

The paper's central capacity problem: production embedding tables exceed
device memory, and its Fig. 6/7 show per-row access frequency is highly
skewed AND uncorrelated with table size — exactly the regime where a
software-managed hot-row cache beats static sharding. This module realizes
the "system memory" placement tier as two arrays:

  capacity tier  (total_rows, d)  the full mega table + row-wise AdaGrad
                 accumulator, host-resident / pooled-HBM, slow to touch;
  device cache   (cache_rows, d)  hot rows + their accumulators + an LFU
                 score per slot, sized by plan_placement("cached_host")
                 from the per-chip HBM budget.

`CachedEmbeddingBagCollection` wraps an EmbeddingBagCollection: each step the
host manager extracts the batch's unique global rows, remaps them to cache
slots (fetch-on-miss through the kernels/cache_ops.py exchange, which moves
row + accumulator together), and the device-side lookup/update then runs
entirely against the small cache array — so per-step cost scales with the
cache, not the table. Eviction is frequency-aware (LFU with decay): victims
are the coldest slots outside the current working set; dirty victims write
back to the capacity tier on the way out. Hit/miss/eviction/writeback
counters are first-class metrics (CacheStats).

State handling is split the only way JAX allows: payload arrays (capacity,
cache, accumulators, LFU scores) are jax Arrays updated functionally;
the slot maps (row<->slot, dirty bits) are host numpy, mutated in place —
eviction choice is data-dependent and lives on the host anyway (the same
split as CacheEmbedding's ChunkParamMgr and MTrainS's tier manager).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.core.embedding import EmbeddingBagCollection
from repro.kernels import cache_ops


@dataclasses.dataclass
class CacheStats:
    """First-class cache metrics. A miss is a CAPACITY-TIER FETCH: one per
    unique missing row per batch — that row's further accesses in the same
    batch are served from the just-filled slot and count as hits, like every
    other access (the FBGEMM/UVM-cache convention: hit_rate = 1 -
    unique_misses / accesses). fetches/evictions/writebacks count rows."""
    hits: int = 0
    misses: int = 0
    fetches: int = 0           # unique rows pulled from the capacity tier
    evictions: int = 0         # slots whose resident row was displaced
    writebacks: int = 0        # dirty evictions flushed to capacity
    prefetched: int = 0        # rows admitted ahead of use (pipeline hook)
    steps: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"cache_hits": float(self.hits),
                "cache_misses": float(self.misses),
                "cache_hit_rate": self.hit_rate,
                "cache_fetches": float(self.fetches),
                "cache_evictions": float(self.evictions),
                "cache_writebacks": float(self.writebacks),
                "cache_prefetched": float(self.prefetched)}


@dataclasses.dataclass
class CacheState:
    capacity: jax.Array        # (R, d) slow tier — the full mega table
    cap_accum: jax.Array       # (R,) fp32 AdaGrad accumulator, slow tier
    cache: jax.Array           # (C, d) device tier — hot rows
    cache_accum: jax.Array     # (C,) fp32 accumulators of cached rows
    freq: jax.Array            # (C,) fp32 LFU-with-decay score per slot
    slot_row: np.ndarray       # (C,) int64: global row held by slot, -1 free
    row_slot: np.ndarray       # (R,) int32: slot holding row, -1 uncached
    dirty: np.ndarray          # (C,) bool: slot updated since fetch
    stats: CacheStats

    @property
    def cache_rows(self) -> int:
        return int(self.cache.shape[0])

    @property
    def resident(self) -> int:
        return int((self.slot_row >= 0).sum())


@dataclasses.dataclass(frozen=True)
class CachedEmbeddingBagCollection:
    """EmbeddingBagCollection whose device working set is a hot-row cache.

    The wrapped collection's `mega` param IS the capacity tier; `lookup`
    results are numerically identical to the uncached collection (rows are
    moved bit-exactly and pooled by the same code path).
    """
    ebc: EmbeddingBagCollection
    cache_rows: int
    decay: float = 0.98        # LFU decay per step (1.0 = pure LFU; lower
                               # adapts faster but churns the tail more)
    use_kernel: Optional[bool] = None
    interpret: bool = False

    @classmethod
    def build(cls, cfg: DLRMConfig, cache_rows: Optional[int] = None,
              strategy: str = "cached_host", decay: float = 0.98,
              use_kernel: Optional[bool] = None,
              interpret: bool = False) -> "CachedEmbeddingBagCollection":
        ebc = EmbeddingBagCollection.build(cfg, n_shards=1, strategy=strategy)
        rows = cache_rows if cache_rows is not None else ebc.plan.cache_rows
        assert rows > 0, "cached_host plan produced an empty cache"
        return cls(ebc, int(rows), decay, use_kernel, interpret)

    # -- state ---------------------------------------------------------------

    def init_state(self, mega: jax.Array,
                   accum: Optional[jax.Array] = None) -> CacheState:
        """mega: (total_rows, d) capacity-tier table (e.g. params["emb"]
        ["mega"]); accum: optional (total_rows,) AdaGrad accumulator.

        The state COPIES mega/accum once and owns its buffers from then on:
        every subsequent exchange donates them to XLA so the swap updates
        rows in place instead of moving the whole tier (the caller's arrays
        stay valid; arrays handed out by `materialize` may be donated again
        by later flushes)."""
        r, d = mega.shape
        assert r == self.ebc.plan.total_rows, (r, self.ebc.plan.total_rows)
        c = self.cache_rows
        if accum is None:
            accum = jnp.zeros((r,), jnp.float32)
        return CacheState(
            capacity=jnp.array(mega, copy=True),
            cap_accum=jnp.array(accum, jnp.float32, copy=True),
            cache=jnp.zeros((c, d), mega.dtype),
            cache_accum=jnp.zeros((c,), jnp.float32),
            freq=jnp.zeros((c,), jnp.float32),
            slot_row=np.full((c,), -1, np.int64),
            row_slot=np.full((r,), -1, np.int32),
            dirty=np.zeros((c,), bool),
            stats=CacheStats())

    # -- admission -----------------------------------------------------------

    def _admit(self, state: CacheState, missing: np.ndarray,
               counts: np.ndarray, protect: np.ndarray) -> int:
        """Bring `missing` global rows into cache slots, evicting the coldest
        unprotected slots. `protect` is a (C,) bool mask of slots that must
        survive (the current working set). Returns rows written back."""
        n = len(missing)
        if n == 0:
            return 0
        free = np.flatnonzero(state.slot_row < 0)
        need = n - len(free)
        victims = np.empty((0,), np.int64)
        if need > 0:
            evictable = np.flatnonzero((state.slot_row >= 0) & ~protect)
            if len(evictable) < need:
                raise ValueError(
                    f"cache thrash: need {need} evictions but only "
                    f"{len(evictable)} unprotected slots — the batch working "
                    f"set exceeds cache_rows={state.cache_rows}; raise the "
                    "HBM budget or shrink the batch")
            freq_host = np.asarray(state.freq)
            order = np.argsort(freq_host[evictable], kind="stable")
            victims = evictable[order[:need]]
        slots = np.concatenate([free[:min(n, len(free))], victims])[:n]
        evicted_rows = state.slot_row[victims]
        wb_mask = state.dirty[victims]
        # worklist: dirty victims write back; every admitted slot fetches
        evict_rows = np.full((n,), -1, np.int64)
        evict_rows[len(slots) - len(victims):] = np.where(
            wb_mask, evicted_rows, -1)
        (state.capacity, state.cache, state.cap_accum, state.cache_accum,
         state.freq) = cache_ops.cache_exchange(
            state.capacity, state.cache, state.cap_accum, state.cache_accum,
            state.freq, jnp.asarray(slots, jnp.int32),
            jnp.asarray(evict_rows, jnp.int32),
            jnp.asarray(missing, jnp.int32),
            jnp.asarray(counts, jnp.float32),
            use_kernel=self.use_kernel, interpret=self.interpret)
        # host maps
        state.row_slot[evicted_rows] = -1
        state.slot_row[slots] = missing
        state.row_slot[missing] = slots.astype(np.int32)
        state.dirty[slots] = False
        state.stats.fetches += n
        state.stats.evictions += len(victims)
        state.stats.writebacks += int(wb_mask.sum())
        return int(wb_mask.sum())

    def prepare(self, state: CacheState, idx, train: bool = True
                ) -> np.ndarray:
        """Make every row of `idx` cache-resident and remap to slot space.

        idx: (B, F, L) OFFSET global rows (-1 pads), host or device array.
        Returns (B, F, L) int32 cache-slot indices (-1 pads preserved) —
        feed these to `lookup_cached` / the cached train step. When `train`,
        the working set's slots are marked dirty (they will receive sparse
        updates) so eviction writes them back.
        """
        idx = np.asarray(idx)
        valid = idx >= 0
        rows, counts = np.unique(idx[valid], return_counts=True)
        if len(rows) > state.cache_rows:
            raise ValueError(
                f"batch touches {len(rows)} unique rows > cache_rows="
                f"{state.cache_rows}; raise the HBM budget or shrink the "
                "batch")
        resident = state.row_slot[rows] >= 0
        hit_slots = state.row_slot[rows[resident]]
        hit_counts = counts[resident]
        missing = rows[~resident]
        # LFU accounting: decay everything, bump hit slots; admitted slots
        # are seeded with their batch counts by the exchange below.
        state.freq = cache_ops.lfu_touch(
            state.freq, jnp.asarray(hit_slots, jnp.int32),
            jnp.asarray(hit_counts, jnp.float32), decay=self.decay)
        protect = np.zeros((state.cache_rows,), bool)
        protect[hit_slots] = True
        self._admit(state, missing, counts[~resident], protect)
        state.stats.hits += int(counts.sum()) - len(missing)
        state.stats.misses += len(missing)
        state.stats.steps += 1
        if train:
            state.dirty[state.row_slot[rows]] = True
        # remap global rows -> slots (-1 pads preserved)
        local = state.row_slot[np.where(valid, idx, 0)]
        return np.where(valid, local, -1).astype(np.int32)

    def prefetch(self, state: CacheState, rows) -> int:
        """Best-effort admission of `rows` (unique global rows, e.g. the
        NEXT batch's deduplicated indices from the pipeline hook) so the
        capacity-tier fetch overlaps the current step's compute. Does not
        touch hit/miss accounting and never evicts the rows it brings in;
        overflow beyond free+evictable space is dropped. Returns the number
        of rows admitted."""
        rows = np.unique(np.asarray(rows))
        rows = rows[rows >= 0]
        missing = rows[state.row_slot[rows] < 0]
        protect = np.zeros((state.cache_rows,), bool)
        keep = state.row_slot[rows[state.row_slot[rows] >= 0]]
        protect[keep] = True
        evictable = int(((state.slot_row >= 0) & ~protect).sum())
        free = int((state.slot_row < 0).sum())
        missing = missing[:free + evictable]
        self._admit(state, missing, np.ones((len(missing),), np.float32),
                    protect)
        state.stats.prefetched += len(missing)
        return len(missing)

    # -- lookup --------------------------------------------------------------

    def lookup_cached(self, state: CacheState, local_idx,
                      rules=None) -> jax.Array:
        """Pooled lookup against the device cache. local_idx: (B, F, L)
        slot indices from `prepare`. Pure device function — jit-friendly."""
        return self.ebc.lookup({"mega": state.cache},
                               jnp.asarray(local_idx), rules)

    def lookup(self, state: CacheState, idx, train: bool = False,
               rules=None) -> jax.Array:
        """prepare + lookup_cached: numerically identical to
        `EmbeddingBagCollection.lookup` on the same (global) indices."""
        return self.lookup_cached(state, self.prepare(state, idx, train),
                                  rules)

    # -- training ------------------------------------------------------------

    def mark_updated(self, state: CacheState, new_cache: jax.Array,
                     new_cache_accum: jax.Array) -> None:
        """Install post-update cache arrays (dirty bits were already set by
        `prepare(train=True)`)."""
        state.cache = new_cache
        state.cache_accum = new_cache_accum

    # -- writeback -----------------------------------------------------------

    def flush(self, state: CacheState) -> int:
        """Write every dirty slot back to the capacity tier (rows stay
        cached, now clean). Returns rows written back."""
        slots = np.flatnonzero(state.dirty)
        if len(slots) == 0:
            return 0
        (state.capacity, state.cache, state.cap_accum, state.cache_accum,
         state.freq) = cache_ops.cache_exchange(
            state.capacity, state.cache, state.cap_accum, state.cache_accum,
            state.freq, jnp.asarray(slots, jnp.int32),
            jnp.asarray(state.slot_row[slots], jnp.int32),
            jnp.full((len(slots),), -1, jnp.int32),
            jnp.zeros((len(slots),), jnp.float32),
            use_kernel=self.use_kernel, interpret=self.interpret)
        state.dirty[slots] = False
        state.stats.writebacks += len(slots)
        return len(slots)

    def materialize(self, state: CacheState
                    ) -> Tuple[jax.Array, jax.Array]:
        """Flush and return the up-to-date (mega, accum) capacity arrays —
        what a checkpoint or an uncached evaluator should read."""
        self.flush(state)
        return state.capacity, state.cap_accum
