"""Overload-robust continuous-batching DLRM serving (docs/serving.md).

`DLRMEngine` (engine.py) is a single-caller predictor; this module wraps the
same read-only cached embedding tier in the machinery a production CTR
server needs when traffic stops being polite:

  * bounded admission queue with backpressure — `submit` returns a typed
    `Overloaded` result when the queue is full (never an unbounded queue,
    never an exception the caller has to map back to a request);
  * per-request deadlines + deadline-aware load shedding — expired requests
    are shed from the queue each step, and under queue pressure the
    `shed_slack` window sheds requests that would expire before service;
  * a batch former that coalesces queued requests into fixed-slot batches
    sized so the cache plan's thrash guard is consulted BEFORE dispatch
    (the running union of unique rows never exceeds `cache_rows`);
  * degrade-don't-die — on capacity-fetch faults (or in the breaker's
    stale_only state) misses resolve from a `StaleRowSnapshot` of
    last-known-good rows (zeros for never-seen rows) and the response is
    flagged `degraded=True`; non-degraded responses are bit-equal to the
    unloaded oracle;
  * a circuit-breaker state machine (healthy -> shedding -> stale_only ->
    healthy) mirroring train/fault_tolerance.py's DegradationManager,
    driven by the same `FaultInjector` via the `serve.fetch` /
    `serve.admit` sites so overload schedules are seeded + deterministic;
  * per-request p50/p99 latency, hit-rate, shed-rate and degraded-fraction
    counters (`ServeMetrics`) surfaced by benchmarks/serve_bench.py.

The serving invariant (tests/test_serve_chaos.py): under ANY fault /
overload schedule every submitted request resolves as exactly one of
{bit-equal-to-oracle, flagged degraded, cleanly shed} — never a wrong
unflagged score, never a crash, never a hang.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import StaleRowSnapshot, _fetch_guard
from repro.nn.sharding import SERVE_RULES, LogicalRules

#: `Overloaded.reason` values
SHED_REASONS = ("queue_full", "deadline", "admit_fault")


@dataclasses.dataclass
class ServeRequest:
    """One CTR scoring request: n examples with an optional deadline.

    `deadline` is an ABSOLUTE timestamp on the engine's clock (None = no
    SLO); `submitted` is stamped by `submit`."""

    uid: int
    dense: np.ndarray          # (n, n_dense) float32
    idx: np.ndarray            # (n, F, L) OFFSET global rows, -1 pads
    deadline: float | None = None
    submitted: float = 0.0


@dataclasses.dataclass
class ServeResponse:
    """A served request: (n,) click probabilities + the degraded flag.

    `degraded=False` responses are bit-equal to the unloaded oracle;
    `degraded=True` responses resolved at least one row from the stale
    snapshot (zeros for never-seen rows)."""

    uid: int
    probs: np.ndarray
    degraded: bool
    latency: float


@dataclasses.dataclass
class Overloaded:
    """A cleanly-shed request (typed backpressure, never an exception).

    `reason` is one of `SHED_REASONS`: the admission queue was full, the
    deadline expired (or fell inside the shedding state's slack window),
    or the admission path itself faulted."""

    uid: int
    reason: str
    queue_depth: int
    at: float


@dataclasses.dataclass
class ServeMetrics:
    """Serving counters; `snapshot` adds the derived SLO figures."""

    submitted: int = 0
    served: int = 0
    degraded: int = 0
    shed_queue_full: int = 0
    shed_deadline: int = 0
    shed_admit_fault: int = 0
    batches: int = 0
    stale_batches: int = 0
    latencies: list = dataclasses.field(default_factory=list)

    @property
    def shed(self) -> int:
        """Total cleanly-shed requests across all reasons."""
        return (self.shed_queue_full + self.shed_deadline
                + self.shed_admit_fault)

    def snapshot(self) -> dict[str, float]:
        """Flat metrics dict: p50/p99 latency, shed rate, degraded frac."""
        lat = np.asarray(self.latencies, np.float64)
        return {
            "submitted": float(self.submitted),
            "served": float(self.served),
            "shed": float(self.shed),
            "shed_rate": self.shed / self.submitted if self.submitted else 0.0,
            "degraded": float(self.degraded),
            "degraded_fraction": (self.degraded / self.served
                                  if self.served else 0.0),
            "p50_latency": float(np.percentile(lat, 50)) if len(lat) else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "batches": float(self.batches),
            "stale_batches": float(self.stale_batches),
        }


class ServeCircuitBreaker:
    """healthy -> shedding -> stale_only -> healthy state machine.

    The serving mirror of train/fault_tolerance.py's DegradationManager:

      * healthy -> shedding when queue pressure (depth / max_queue) crosses
        `shed_enter`; back when it falls below `shed_exit`. In shedding the
        engine also sheds requests whose deadline falls within `shed_slack`
        of now (they would expire before service anyway).
      * any state -> stale_only after `demote_after` CONSECUTIVE capacity-
        fetch failures (retries exhausted): every batch serves from the
        stale snapshot, no fetch is attempted except probes.
      * stale_only -> healthy after `promote_after` consecutive successful
        probe fetches (one probe every `probe_every` batches).

    All transitions are recorded in `transitions` as (state, event_count)
    for the chaos tests."""

    def __init__(self, shed_enter: float = 0.75, shed_exit: float = 0.25,
                 demote_after: int = 2, promote_after: int = 3,
                 probe_every: int = 4):
        self.shed_enter = shed_enter
        self.shed_exit = shed_exit
        self.demote_after = demote_after
        self.promote_after = promote_after
        self.probe_every = probe_every
        self.state = "healthy"
        self.transitions: list[tuple[str, int]] = []
        self._failures = 0
        self._probe_ok = 0
        self._probe_tick = 0
        self._events = 0

    def _to(self, state: str) -> None:
        self.state = state
        self.transitions.append((state, self._events))

    def record_pressure(self, frac: float) -> None:
        """Queue-depth watermark check (frac = depth / max_queue)."""
        self._events += 1
        if self.state == "healthy" and frac >= self.shed_enter:
            self._to("shedding")
        elif self.state == "shedding" and frac <= self.shed_exit:
            self._to("healthy")

    def record_fetch_failure(self) -> None:
        """One capacity-fetch dispatch that exhausted its retries."""
        self._events += 1
        self._failures += 1
        self._probe_ok = 0
        if self.state != "stale_only" and self._failures >= self.demote_after:
            self._to("stale_only")

    def record_fetch_success(self) -> None:
        """One clean capacity-fetch dispatch (counts as a probe success)."""
        self._events += 1
        self._failures = 0
        if self.state == "stale_only":
            self._probe_ok += 1
            if self._probe_ok >= self.promote_after:
                self._probe_ok = 0
                self._to("healthy")

    def should_probe(self) -> bool:
        """In stale_only: True every `probe_every`-th batch (a real fetch
        is attempted to test whether the capacity tier healed)."""
        self._probe_tick += 1
        return self._probe_tick % self.probe_every == 0


class DLRMServeEngine:
    """Continuous-batching CTR server over the read-only cached tier.

    Drive it with `submit` (returns `Overloaded` on backpressure, None on
    admission) + `step` (forms and dispatches one batch), or `run` to
    drain. Resolved requests land in `results` (uid -> ServeResponse |
    Overloaded). See the module docstring for the robustness contract and
    docs/serving.md for the knobs."""

    def __init__(self, params, cfg, cc, *, max_queue: int = 64,
                 max_batch: int = 32, shed_slack: float = 0.0,
                 clock: Callable[[], float] = time.monotonic,
                 injector: Any = None, retry: Any = None,
                 breaker: ServeCircuitBreaker | None = None,
                 rules: LogicalRules = SERVE_RULES):
        from repro.core.dlrm import dlrm_forward_dense
        self.cfg = cfg
        self.cc = cc
        self.rules = rules
        self.max_queue = int(max_queue)
        self.max_batch = int(max_batch)
        self.shed_slack = float(shed_slack)
        self.clock = clock
        self.injector = injector
        self.retry = retry
        self.breaker = breaker if breaker is not None else ServeCircuitBreaker()
        self.dense = {"bottom": params["bottom"], "top": params["top"]}
        self.state = cc.init_state(params["emb"]["mega"])
        r, d = params["emb"]["mega"].shape
        self.snapshot = StaleRowSnapshot.empty(r, d)
        self.queue: collections.deque[ServeRequest] = collections.deque()
        self.results: dict[int, ServeResponse | Overloaded] = {}
        self.metrics = ServeMetrics()

        def fwd(dense_params, table, dense_x, local_idx):
            pooled = cc.lookup_cached(_TableView(table), local_idx, rules)
            logits = dlrm_forward_dense({**dense_params, "emb": None},
                                        dense_x, pooled, cfg)
            return jax.nn.sigmoid(logits)

        # ONE compiled forward shared by the healthy path (table = the
        # device cache) and the degraded path (table = the stale slab):
        # both are (C, d) of the same dtype, and batches are padded to
        # (max_batch, ...) fixed slots, so nothing ever recompiles under
        # overload — the worst moment to pay a compile.
        self._fwd = jax.jit(fwd)

    # -- admission -----------------------------------------------------------

    def submit(self, req: ServeRequest) -> Overloaded | None:
        """Admit `req` or shed it with a typed `Overloaded` (also recorded
        in `results`). Raises ValueError for requests that could NEVER be
        served (more examples than `max_batch`, or a working set larger
        than the device cache) — malformed input, not overload."""
        req.dense = np.asarray(req.dense)
        req.idx = np.asarray(req.idx)
        n = int(req.idx.shape[0])
        if n > self.max_batch:
            raise ValueError(
                f"request carries {n} examples > max_batch={self.max_batch};"
                " split it client-side or build the engine with more slots")
        n_rows = len(np.unique(req.idx[req.idx >= 0]))
        if n_rows > self.cc.cache_rows:
            raise ValueError(
                f"request working set of {n_rows} unique rows exceeds "
                f"cache_rows={self.cc.cache_rows}; it can never form a "
                "servable batch — raise the HBM budget or shrink the "
                "request")
        now = self.clock()
        req.submitted = now
        self.metrics.submitted += 1
        try:
            _fetch_guard(self.injector, self.retry, site="serve.admit")
        except Exception as e:
            if not getattr(e, "transient", False):
                raise
            return self._shed(req, "admit_fault", now)
        if len(self.queue) >= self.max_queue:
            return self._shed(req, "queue_full", now)
        self.queue.append(req)
        return None

    def _shed(self, req: ServeRequest, reason: str,
              now: float) -> Overloaded:
        res = Overloaded(req.uid, reason, len(self.queue), now)
        self.results[req.uid] = res
        if reason == "queue_full":
            self.metrics.shed_queue_full += 1
        elif reason == "deadline":
            self.metrics.shed_deadline += 1
        else:
            self.metrics.shed_admit_fault += 1
        return res

    # -- batch forming + dispatch --------------------------------------------

    def _shed_expired(self, now: float) -> None:
        """Drop queued requests that missed (or cannot make) their
        deadline. In the breaker's shedding state the `shed_slack` window
        is added: a request that would expire before it plausibly reaches
        the head of the queue is shed now rather than served late."""
        slack = self.shed_slack if self.breaker.state == "shedding" else 0.0
        keep: collections.deque[ServeRequest] = collections.deque()
        while self.queue:
            r = self.queue.popleft()
            if r.deadline is not None and r.deadline < now + slack:
                self._shed(r, "deadline", now)
            else:
                keep.append(r)
        self.queue = keep

    def _form_batch(self) -> list[ServeRequest]:
        """Pop a FIFO prefix of the queue whose total examples fit
        `max_batch` AND whose running union of unique rows fits the device
        cache — the thrash guard consulted before dispatch, so `prepare`
        can never trip it. `submit` bounds any single request by both
        limits, so at least one request is always taken: progress is
        guaranteed."""
        mark = np.zeros((self.cc.ebc.plan.total_rows,), bool)
        batch: list[ServeRequest] = []
        total = count = 0
        while self.queue:
            r = self.queue[0]
            n = int(r.idx.shape[0])
            if total + n > self.max_batch:
                break
            rows = np.unique(r.idx[r.idx >= 0])
            new = rows[~mark[rows]]
            if count + len(new) > self.cc.cache_rows:
                break
            mark[new] = True
            count += len(new)
            total += n
            batch.append(self.queue.popleft())
        return batch

    def _pad(self, batch: list[ServeRequest]):
        """Concatenate + zero/-1-pad to the fixed (max_batch, ...) slots."""
        f, el = batch[0].idx.shape[1:]
        nd = batch[0].dense.shape[1]
        dense = np.zeros((self.max_batch, nd), np.float32)
        idx = np.full((self.max_batch, f, el), -1, np.int64)
        off = 0
        for r in batch:
            n = r.idx.shape[0]
            dense[off:off + n] = r.dense
            idx[off:off + n] = r.idx
            off += n
        return dense, idx, off

    def _stale_local(self, idx: np.ndarray):
        """Remap `idx` onto a stale slab: unique rows gather from the
        snapshot into a zero-padded (C, d) table, indices remap by
        searchsorted. Same shapes/dtype as the healthy path, so the same
        compiled forward serves both."""
        valid = idx >= 0
        rows = np.unique(idx[valid])
        slab = np.zeros((self.cc.cache_rows, self.state.cache.shape[1]),
                        np.float32)
        slab[:len(rows)] = self.snapshot.gather(rows)
        local = np.searchsorted(rows, np.where(valid, idx, rows[0] if
                                               len(rows) else 0))
        local = np.where(valid, local, -1).astype(np.int32)
        return jnp.asarray(slab, self.state.cache.dtype), local

    def step(self) -> list[ServeResponse]:
        """One engine step: shed expired work, form one thrash-safe batch,
        dispatch it (healthy or degraded), resolve its requests."""
        now = self.clock()
        self._shed_expired(now)
        self.breaker.record_pressure(
            len(self.queue) / self.max_queue if self.max_queue else 0.0)
        if not self.queue:
            return []
        batch = self._form_batch()
        dense, idx, _ = self._pad(batch)
        degraded = False
        table = None
        local = None
        if self.breaker.state == "stale_only" \
                and not self.breaker.should_probe():
            degraded = True
        else:
            try:
                _fetch_guard(self.injector, self.retry, site="serve.fetch")
                local = self.cc.take(self.state, idx, train=False)
            except Exception as e:
                if not getattr(e, "transient", False):
                    raise
                self.breaker.record_fetch_failure()
                degraded = True
            else:
                self.breaker.record_fetch_success()
                table = self.state.cache
                # remember every first-seen row while the tier is healthy:
                # the tier is read-only, so these can never go stale
                rows = np.unique(idx[idx >= 0])
                fresh = rows[~self.snapshot.seen[rows]]
                if len(fresh):
                    slots = self.state.row_slot[fresh]
                    self.snapshot.record(
                        fresh, np.asarray(self.state.cache[slots]))
        if degraded:
            table, local = self._stale_local(idx)
        probs = np.asarray(
            self._fwd(self.dense, table, jnp.asarray(dense),
                      jnp.asarray(local)), np.float32)
        done = self.clock()
        self.metrics.batches += 1
        if degraded:
            self.metrics.stale_batches += 1
        out: list[ServeResponse] = []
        off = 0
        for r in batch:
            n = int(r.idx.shape[0])
            resp = ServeResponse(r.uid, probs[off:off + n], degraded,
                                 done - r.submitted)
            self.results[r.uid] = resp
            self.metrics.served += 1
            self.metrics.degraded += int(degraded)
            self.metrics.latencies.append(resp.latency)
            out.append(resp)
            off += n
        return out

    def run(self, max_steps: int = 10_000):
        """Step until the queue drains (every step resolves >= 1 request,
        so `max_steps` only trips on a genuine logic error). Returns
        `results`."""
        steps = 0
        while self.queue:
            self.step()
            steps += 1
            if steps > max_steps:
                raise RuntimeError(
                    f"serve loop did not drain within {max_steps} steps "
                    f"({len(self.queue)} requests still queued)")
        return self.results

    @property
    def cache_stats(self):
        """Live `CacheStats` of the serving cache state."""
        return self.state.stats


@dataclasses.dataclass
class _TableView:
    """Duck-typed CacheState carrying only what lookup_cached reads, so
    the jitted serve forward closes over no host-side cache metadata."""

    cache: jax.Array
