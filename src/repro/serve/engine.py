"""Batched serving engine: continuous batching over fixed decode slots.

The engine keeps a fixed-batch KV/SSM cache (shape-stable => one compiled
decode step), admits queued requests into free slots, decodes all active
slots each step, and retires sequences that hit EOS or their token budget.
This is the slot-based continuous batching of production LM servers, sized
so the decode_32k / long_500k dry-run shapes are exactly what the engine
lowers.

The KV cache dtype (bf16 / int8 via cfg.kv_cache_dtype) is the serving-side
capacity lever — the same capacity-vs-placement trade the paper makes for
embedding tables (DESIGN.md section 4: qwen-32b's 32k x 128 cache only fits HBM
in int8).
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import decode_step, init_caches
from repro.nn.sharding import SERVE_RULES, LogicalRules


@dataclasses.dataclass
class Request:
    """One LM generation request: prompt tokens + a new-token budget."""

    uid: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int = 32
    generated: list[int] | None = None


class DrainTimeout(RuntimeError):
    """`run_until_drained` exceeded its step budget.

    Carries the work that DID finish (`completed`, uid -> tokens) plus the
    uids still in flight (`undrained`: occupied slots and queued requests),
    so a stalled drain loses nothing."""

    def __init__(self, completed: dict[int, list[int]],
                 undrained: list[int], steps: int):
        super().__init__(
            f"serve loop did not drain within {steps} steps; "
            f"{len(completed)} completed, {len(undrained)} in flight")
        self.completed = completed
        self.undrained = undrained


class ServeEngine:
    """Slot-based continuous-batching LM engine (see module docstring)."""

    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_len: int, rules: LogicalRules = SERVE_RULES,
                 eos_id: int = -1, greedy: bool = True):
        assert cfg.frontend is None or cfg.frontend == "vision", \
            "engine drives token-in/token-out archs"
        self.params = params
        self.cfg = cfg
        self.rules = rules
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.caches = init_caches(cfg, batch_slots, max_len)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.slot_budget = np.zeros(batch_slots, np.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.completed: dict[int, list[int]] = {}
        self._decode = jax.jit(
            lambda p, t, c, i: decode_step(p, t, c, i, cfg, rules))
        self.steps_run = 0

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request):
        """Enqueue `req`, validating it can ever fit the cache window.

        A prompt of `max_len` or more tokens would overflow `slot_pos` past
        the cache before the retire check could fire — reject it here with
        an actionable error instead of corrupting a slot. The new-token
        budget is clamped at admission (`_admit`), not here, so a request
        asking for more tokens than the window allows still runs — it just
        retires at the window edge."""
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens cannot fit max_len="
                f"{self.max_len} with room to generate; truncate the prompt "
                "or build the engine with a larger max_len")
        req.generated = []
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.batch_slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # prefill one slot: run prompt tokens through decode steps
            # (slot-local prefill keeps the cache layout fixed-batch).
            # The LAST prompt token is left to the first `step()` call —
            # it feeds at position len-1 and its logits sample the first
            # generated token; prefilling it here too would write it to
            # the KV cache twice and sample from one position past the
            # prompt (tests/test_lm_behaviour.py guards this).
            for t, tok in enumerate(req.prompt[:-1]):
                tok_arr = jnp.full((self.batch_slots, 1), int(tok), jnp.int32)
                logits, caches = self._decode(
                    self.params, tok_arr, self.caches,
                    jnp.asarray(t, jnp.int32))
                self.caches = _merge_slot(self.caches, caches, slot)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt) - 1
            # clamp the budget to the cache window: after g generated
            # tokens slot_pos is len(prompt)-1+g, and the slot retires at
            # max_len-1, so at most max_len - len(prompt) tokens fit
            self.slot_budget[slot] = min(req.max_new_tokens,
                                         self.max_len - len(req.prompt))

    # -- decode --------------------------------------------------------------

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        return np.argmax(logits, axis=-1).astype(np.int32)

    def step(self):
        """One engine step: admit, decode all active slots, retire."""
        self._admit()
        active = [s for s in range(self.batch_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return
        # current last token per slot (pad inactive with 0)
        toks = np.zeros((self.batch_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            toks[s, 0] = (req.generated[-1] if req.generated
                          else int(req.prompt[-1]))
        # per-slot positions: each sequence writes its cache at its own
        # depth and attends over its own valid prefix (continuous batching)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.slot_pos, jnp.int32))
        nxt = self._sample(np.asarray(logits, np.float32))
        self.steps_run += 1
        for s in active:
            req = self.slot_req[s]
            req.generated.append(int(nxt[s]))
            self.slot_pos[s] += 1
            done = (len(req.generated) >= self.slot_budget[s]
                    or int(nxt[s]) == self.eos_id
                    or self.slot_pos[s] >= self.max_len - 1)
            if done:
                self.completed[req.uid] = req.generated
                self.slot_req[s] = None

    def run_until_drained(self, max_steps: int = 10_000):
        """Step until queue + slots are empty; returns `completed`.

        `max_steps` bounds THIS call's decode steps (not the engine's
        lifetime `steps_run`, so a reused engine gets a fresh budget).
        On timeout raises `DrainTimeout` carrying the partial `completed`
        dict and the undrained uids — completed work is never lost."""
        start = self.steps_run
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
            if self.steps_run - start > max_steps:
                undrained = [r.uid for r in self.slot_req if r is not None]
                undrained += [r.uid for r in self.queue]
                raise DrainTimeout(dict(self.completed), undrained,
                                   self.steps_run - start)
        return self.completed


def _merge_slot(old_caches, new_caches, slot: int):
    """Keep only `slot`'s rows from new_caches (batch dim is axis 1 under the
    stacked-unit leading dim)."""
    def merge(o, n):
        return o.at[:, slot].set(n[:, slot])
    return jax.tree.map(merge, old_caches, new_caches)


# ---------------------------------------------------------------------------
# DLRM serving over the cached embedding tier
# ---------------------------------------------------------------------------


class DLRMEngine:
    """Batched CTR inference with the cached embedding tier in READ-ONLY
    mode: the full mega table stays in the capacity tier, hot rows are
    served from the device cache, misses fetch on demand, and eviction
    never writes back (no row is ever dirtied) — the serving-side analogue
    of the paper's system-memory placement, where the same access skew
    (Figs. 6/7) lets a small device cache absorb most lookup traffic.
    """

    def __init__(self, params, cfg, cc, rules: LogicalRules = SERVE_RULES):
        from repro.core.dlrm import dlrm_forward_dense
        self.cfg = cfg
        self.cc = cc
        self.rules = rules
        self.dense = {"bottom": params["bottom"], "top": params["top"]}
        self.state = cc.init_state(params["emb"]["mega"])
        self.requests_served = 0

        def fwd(dense_params, cache, dense_x, local_idx):
            pooled = cc.lookup_cached(
                _StateView(cache), local_idx, rules)
            logits = dlrm_forward_dense({**dense_params, "emb": None},
                                        dense_x, pooled, cfg)
            return jax.nn.sigmoid(logits)

        self._fwd = jax.jit(fwd)

    def _split_spans(self, idx: np.ndarray) -> list[tuple[int, int]]:
        """Greedy prefix packing: contiguous example spans whose CUMULATIVE
        unique-row working set fits the device cache, computed BEFORE any
        dispatch — the thrash guard is consulted proactively, never tripped.

        A reusable (R,) mark array tracks the rows the open span already
        counted; when an example would push the union past `cache_rows` the
        span closes and the example re-evaluates against fresh marks. A
        single example whose own working set exceeds the cache cannot be
        split further — that raises with the actual sizes."""
        b = idx.shape[0]
        c = self.cc.cache_rows
        mark = np.zeros((self.cc.ebc.plan.total_rows,), bool)
        touched: list[np.ndarray] = []
        spans: list[tuple[int, int]] = []
        start, count, e = 0, 0, 0
        while e < b:
            rows = np.unique(idx[e][idx[e] >= 0])
            new = rows[~mark[rows]]
            if count + len(new) > c:
                if e == start:
                    raise ValueError(
                        f"single example touches {len(rows)} unique rows > "
                        f"cache_rows={c}; it cannot be split further — "
                        "raise the HBM budget or shorten the example's "
                        "multi-hot lists")
                spans.append((start, e))
                for t in touched:
                    mark[t] = False
                touched.clear()
                start, count = e, 0
                continue        # re-evaluate e against the fresh span
            mark[new] = True
            touched.append(new)
            count += len(new)
            e += 1
        if b:
            spans.append((start, b))
        return spans

    def predict(self, batch: dict) -> np.ndarray:
        """batch: {"dense" (B, n_dense), "idx" (B, F, L) OFFSET global rows}.
        Returns (B,) click probabilities.

        A batch whose working set exceeds the device cache would trip the
        planner's thrash guard; serving must degrade, not die, so the batch
        is pre-split into working-set-sized spans (`_split_spans`) and each
        span dispatches knowing it fits. Splitting is exact here — the tier
        is read-only, so earlier spans only change which rows are RESIDENT
        for later ones, never their values."""
        idx = np.asarray(batch["idx"])
        dense_x = np.asarray(batch["dense"])
        if idx.shape[0] == 0:
            return np.zeros((0,), np.float32)
        outs = []
        for s, e in self._split_spans(idx):
            local = self.cc.take(self.state, idx[s:e], train=False)
            probs = self._fwd(self.dense, self.state.cache,
                              jnp.asarray(dense_x[s:e]), jnp.asarray(local))
            outs.append(np.asarray(probs, np.float32))
        self.requests_served += int(idx.shape[0])
        return outs[0] if len(outs) == 1 else np.concatenate(outs)

    @property
    def cache_stats(self):
        """Live `CacheStats` of the serving cache state."""
        return self.state.stats


@dataclasses.dataclass
class _StateView:
    """Duck-typed CacheState carrying only what lookup_cached reads, so the
    jitted serve forward closes over no host-side cache metadata."""
    cache: jax.Array
