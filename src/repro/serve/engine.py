"""Batched serving engine: continuous batching over fixed decode slots.

The engine keeps a fixed-batch KV/SSM cache (shape-stable => one compiled
decode step), admits queued requests into free slots, decodes all active
slots each step, and retires sequences that hit EOS or their token budget.
This is the slot-based continuous batching of production LM servers, sized
so the decode_32k / long_500k dry-run shapes are exactly what the engine
lowers.

The KV cache dtype (bf16 / int8 via cfg.kv_cache_dtype) is the serving-side
capacity lever — the same capacity-vs-placement trade the paper makes for
embedding tables (DESIGN.md section 4: qwen-32b's 32k x 128 cache only fits HBM
in int8).
"""
from __future__ import annotations

import dataclasses
import queue

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.lm import decode_step, init_caches
from repro.nn.sharding import SERVE_RULES, LogicalRules


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int = 32
    generated: list[int] | None = None


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, batch_slots: int,
                 max_len: int, rules: LogicalRules = SERVE_RULES,
                 eos_id: int = -1, greedy: bool = True):
        assert cfg.frontend is None or cfg.frontend == "vision", \
            "engine drives token-in/token-out archs"
        self.params = params
        self.cfg = cfg
        self.rules = rules
        self.batch_slots = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.caches = init_caches(cfg, batch_slots, max_len)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self.slot_budget = np.zeros(batch_slots, np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.completed: dict[int, list[int]] = {}
        self._decode = jax.jit(
            lambda p, t, c, i: decode_step(p, t, c, i, cfg, rules))
        self.steps_run = 0

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request):
        req.generated = []
        self.queue.put(req)

    def _admit(self):
        for slot in range(self.batch_slots):
            if self.slot_req[slot] is not None or self.queue.empty():
                continue
            req = self.queue.get()
            # prefill one slot: run prompt tokens through decode steps
            # (slot-local prefill keeps the cache layout fixed-batch).
            # The LAST prompt token is left to the first `step()` call —
            # it feeds at position len-1 and its logits sample the first
            # generated token; prefilling it here too would write it to
            # the KV cache twice and sample from one position past the
            # prompt (tests/test_lm_behaviour.py guards this).
            for t, tok in enumerate(req.prompt[:-1]):
                tok_arr = jnp.full((self.batch_slots, 1), int(tok), jnp.int32)
                logits, caches = self._decode(
                    self.params, tok_arr, self.caches,
                    jnp.asarray(t, jnp.int32))
                self.caches = _merge_slot(self.caches, caches, slot)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt) - 1
            self.slot_budget[slot] = req.max_new_tokens

    # -- decode --------------------------------------------------------------

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        return np.argmax(logits, axis=-1).astype(np.int32)

    def step(self):
        """One engine step: admit, decode all active slots, retire."""
        self._admit()
        active = [s for s in range(self.batch_slots)
                  if self.slot_req[s] is not None]
        if not active:
            return
        # current last token per slot (pad inactive with 0)
        toks = np.zeros((self.batch_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            toks[s, 0] = (req.generated[-1] if req.generated
                          else int(req.prompt[-1]))
        # per-slot positions: each sequence writes its cache at its own
        # depth and attends over its own valid prefix (continuous batching)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.slot_pos, jnp.int32))
        nxt = self._sample(np.asarray(logits, np.float32))
        self.steps_run += 1
        for s in active:
            req = self.slot_req[s]
            req.generated.append(int(nxt[s]))
            self.slot_pos[s] += 1
            done = (len(req.generated) >= self.slot_budget[s]
                    or int(nxt[s]) == self.eos_id
                    or self.slot_pos[s] >= self.max_len - 1)
            if done:
                self.completed[req.uid] = req.generated
                self.slot_req[s] = None

    def run_until_drained(self, max_steps: int = 10_000):
        while (not self.queue.empty()
               or any(r is not None for r in self.slot_req)):
            self.step()
            if self.steps_run > max_steps:
                raise RuntimeError("serve loop did not drain")
        return self.completed


def _merge_slot(old_caches, new_caches, slot: int):
    """Keep only `slot`'s rows from new_caches (batch dim is axis 1 under the
    stacked-unit leading dim)."""
    def merge(o, n):
        return o.at[:, slot].set(n[:, slot])
    return jax.tree.map(merge, old_caches, new_caches)


# ---------------------------------------------------------------------------
# DLRM serving over the cached embedding tier
# ---------------------------------------------------------------------------


class DLRMEngine:
    """Batched CTR inference with the cached embedding tier in READ-ONLY
    mode: the full mega table stays in the capacity tier, hot rows are
    served from the device cache, misses fetch on demand, and eviction
    never writes back (no row is ever dirtied) — the serving-side analogue
    of the paper's system-memory placement, where the same access skew
    (Figs. 6/7) lets a small device cache absorb most lookup traffic.
    """

    def __init__(self, params, cfg, cc, rules: LogicalRules = SERVE_RULES):
        from repro.core.dlrm import dlrm_forward_dense
        self.cfg = cfg
        self.cc = cc
        self.rules = rules
        self.dense = {"bottom": params["bottom"], "top": params["top"]}
        self.state = cc.init_state(params["emb"]["mega"])
        self.requests_served = 0

        def fwd(dense_params, cache, dense_x, local_idx):
            pooled = cc.lookup_cached(
                _StateView(cache), local_idx, rules)
            logits = dlrm_forward_dense({**dense_params, "emb": None},
                                        dense_x, pooled, cfg)
            return jax.nn.sigmoid(logits)

        self._fwd = jax.jit(fwd)

    def predict(self, batch: dict) -> np.ndarray:
        """batch: {"dense" (B, n_dense), "idx" (B, F, L) OFFSET global rows}.
        Returns (B,) click probabilities.

        A batch whose working set exceeds the device cache trips the
        planner's thrash guard; serving must degrade, not die, so the batch
        recursively halves until each piece's unique rows fit. Splitting is
        exact here — the tier is read-only, so earlier pieces only change
        which rows are RESIDENT for later ones, never their values."""
        idx = np.asarray(batch["idx"])
        try:
            local = self.cc.prepare(self.state, idx, train=False)
        except ValueError as e:
            if "unique rows" not in str(e) or idx.shape[0] <= 1:
                raise   # a single example over capacity cannot split
            h = idx.shape[0] // 2
            dense_x = np.asarray(batch["dense"])
            return np.concatenate([
                self.predict({"dense": dense_x[:h], "idx": idx[:h]}),
                self.predict({"dense": dense_x[h:], "idx": idx[h:]})])
        probs = self._fwd(self.dense, self.state.cache,
                          jnp.asarray(batch["dense"]), jnp.asarray(local))
        self.requests_served += int(local.shape[0])
        return np.asarray(probs, np.float32)

    @property
    def cache_stats(self):
        return self.state.stats


@dataclasses.dataclass
class _StateView:
    """Duck-typed CacheState carrying only what lookup_cached reads, so the
    jitted serve forward closes over no host-side cache metadata."""
    cache: jax.Array
