"""Serving engines: slot-based LM decode + overload-robust DLRM CTR."""
from repro.serve.dlrm_engine import (  # noqa: F401
    DLRMServeEngine,
    Overloaded,
    ServeCircuitBreaker,
    ServeMetrics,
    ServeRequest,
    ServeResponse,
)
from repro.serve.engine import (  # noqa: F401
    DLRMEngine,
    DrainTimeout,
    Request,
    ServeEngine,
)
