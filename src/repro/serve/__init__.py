from repro.serve.engine import DLRMEngine, Request, ServeEngine  # noqa: F401
