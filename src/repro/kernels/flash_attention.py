"""Pallas TPU kernel: causal flash attention (the prefill_32k hot spot).

TPU-native design: grid (batch, heads, q_blocks); the (block_q, dh) query
tile and the fp32 running (max, denom, acc) live in VMEM; K/V stay in HBM
(`MemorySpace.ANY`) and stream through double-buffered DMA in (block_k, dh)
tiles. The causal bound truncates the kv loop per q block (the static-skip
that the XLA fallback only gets via `causal_skip` unrolling). dh is padded
to the 128-lane width and block sizes to the 8-sublane width by ops.py.

This is the kernel counterpart of nn/layers.blockwise_attention (the pure-
XLA fallback used under pjit); interpret=True validates the body on CPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace, SemaphoreType


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, kbuf, vbuf, sems, *,
                  block_q: int, block_k: int, sk: int, causal: bool,
                  scale: float):
    """One grid step = one (b, h, q_block).

    q_ref: (block_q, dh) VMEM block; k_ref/v_ref: (b, h, sk, dh) HBM;
    o_ref: (block_q, dh) VMEM block; kbuf/vbuf: (2, block_k, dh) VMEM
    scratch; sems: (2, 2) DMA semaphores (slot x {k, v}).
    """
    b, h, qi = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    dh = q_ref.shape[-1]
    nk = sk // block_k
    if causal:
        hi = jnp.minimum((qi * block_q + block_q - 1) // block_k + 1, nk)
    else:
        hi = nk

    def start(slot, ki):
        """Kick off K/V block ki's DMAs into double-buffer slot."""
        pltpu.make_async_copy(
            k_ref.at[b, h, pl.ds(ki * block_k, block_k)],
            kbuf.at[slot], sems.at[slot, 0]).start()
        pltpu.make_async_copy(
            v_ref.at[b, h, pl.ds(ki * block_k, block_k)],
            vbuf.at[slot], sems.at[slot, 1]).start()

    def wait(slot):
        """Await the K/V DMAs parked in slot."""
        pltpu.make_async_copy(k_ref.at[b, h, pl.ds(0, block_k)],
                              kbuf.at[slot], sems.at[slot, 0]).wait()
        pltpu.make_async_copy(v_ref.at[b, h, pl.ds(0, block_k)],
                              vbuf.at[slot], sems.at[slot, 1]).wait()

    start(0, 0)
    q = q_ref[0, 0].astype(jnp.float32) * scale   # (block_q, dh)
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)

    def body(ki, carry):
        """Online-softmax update over K/V block ki."""
        m, den, acc = carry
        slot = jax.lax.rem(ki, 2)

        @pl.when(ki + 1 < hi)
        def _():
            start(jax.lax.rem(ki + 1, 2), ki + 1)

        wait(slot)
        k = kbuf[slot].astype(jnp.float32)           # (block_k, dh)
        v = vbuf[slot].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos > qpos, -1e30, s)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        den_new = den * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, den_new, acc_new

    m0 = jnp.full((block_q, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    a0 = jnp.zeros((block_q, dh), jnp.float32)
    m, den, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(den, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret"))
def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_q: int = 128, block_k: int = 128,
                           causal: bool = True,
                           interpret: bool = False) -> jax.Array:
    """q, k, v: (b, h, s, dh) with dh % 128 == 0 and s % block == 0
    (pad in ops.py). Returns (b, h, s, dh)."""
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, sk=sk,
        causal=causal, scale=1.0 / math.sqrt(dh))
    return pl.pallas_call(
        kernel,
        grid=(b, h, sq // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec(memory_space=MemorySpace.ANY),
            pl.BlockSpec(memory_space=MemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            MemorySpace.VMEM((2, block_k, dh), k.dtype),
            MemorySpace.VMEM((2, block_k, dh), v.dtype),
            SemaphoreType.DMA((2, 2)),
        ],
        interpret=interpret,
    )(q, k, v)
