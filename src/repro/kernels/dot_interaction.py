"""Pallas TPU kernel: fused pairwise-dot feature interaction.

DLRM's interaction (paper section III-A.3) forms Z Z^T per example over the
stacked feature matrix Z = [dense_proj; pooled_emb_1; ...] (F, D) and keeps
the strictly-lower triangle. This kernel keeps Z in VMEM per batch tile,
runs the (F, D) x (D, F) contraction on the MXU with fp32 accumulation, and
masks the upper triangle with an iota comparison in VREGs (no gather — TPU
vector units have no efficient in-kernel gather). The cheap triangle packing
(a static-index gather over the already-masked (F, F) tile) remains in XLA
where it fuses with the downstream concat.

Tiling: grid over batch tiles; block (TB, F, D) with F padded to the sublane
(8) and D to the lane (128) width by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_int_kernel(z_ref, out_ref):
    z = z_ref[...]                                           # (tb, F, D)
    f = z.shape[1]
    s = jax.lax.dot_general(
        z, z, dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                  # (tb, F, F)
    rows = jax.lax.broadcasted_iota(jnp.int32, (f, f), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (f, f), 1)
    s = jnp.where((cols < rows)[None], s, 0.0)               # strict lower
    out_ref[...] = s.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def dot_interaction_kernel(z: jax.Array, tile_b: int = 8,
                           interpret: bool = False) -> jax.Array:
    """z: (B, F, D), B % tile_b == 0. Returns (B, F, F) strictly-lower-
    triangular pairwise-dot matrix (zeros elsewhere)."""
    b, f, d = z.shape
    assert b % tile_b == 0, (b, tile_b)
    return pl.pallas_call(
        _dot_int_kernel,
        grid=(b // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile_b, f, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, f), z.dtype),
        interpret=interpret,
    )(z)
