"""Pallas API-drift shim.

jax renamed `pltpu.TPUMemorySpace` to `pltpu.MemorySpace` (and kept the
semantics: enum members double as scratch-shape constructors). The kernels
import the name from here so one tree runs on both the pinned CI jax and
older container toolchains.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
SemaphoreType = pltpu.SemaphoreType
