"""Pallas TPU kernel: fused sparse gradient aggregation + row-wise AdaGrad.

The paper (section VII) notes near-memory designs (RecNMP, TensorDIMM) are "not
optimized for gradient aggregation" — this is the training-side hot spot.
The ops.py wrapper first DEDUPLICATES per-lookup gradients (duplicate rows in
a batch are summed — the synchronous replacement for HogWild's racy applies,
DESIGN.md section 2), then this kernel streams unique rows through VMEM:

  per grid step (one updated row):
    DMA row + accumulator in (HBM->VMEM), compute
      acc' = acc + mean(g^2);  w' = w - lr * g * rsqrt(acc' + eps)
    DMA row + accumulator back (VMEM->HBM), in-place via io aliasing.

Padding slots (index -1) are skipped with pl.when, so a fixed-shape lowered
kernel serves any batch sparsity pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace, SemaphoreType


def _rwadagrad_kernel(idx_ref, gsum_ref, lr_ref, table_ref, accum_ref,
                      table_out, accum_out, row_vmem, acc_vmem, sems,
                      *, eps: float):
    """Grid step i updates unique row idx_ref[i].

    idx_ref: (N,) SMEM; gsum_ref: (1, D) VMEM block (deduped grad);
    table_ref/table_out: (H, D) HBM aliased; accum_ref/accum_out: (H, 1) HBM
    aliased; row_vmem: (1, D); acc_vmem: (1, 1); sems: 2 DMA semaphores.
    """
    i = pl.program_id(0)
    ix = idx_ref[i]

    @pl.when(ix >= 0)
    def _():
        # fetch row + accumulator
        cp_r = pltpu.make_async_copy(table_ref.at[pl.ds(ix, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(accum_ref.at[pl.ds(ix, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()

        g = gsum_ref[...].astype(jnp.float32)                # (1, D)
        acc_new = acc_vmem[...].astype(jnp.float32) + \
            jnp.mean(jnp.square(g), axis=-1, keepdims=True)
        w_new = row_vmem[...].astype(jnp.float32) - \
            lr_ref[0] * g * jax.lax.rsqrt(acc_new + eps)

        row_vmem[...] = w_new.astype(row_vmem.dtype)
        acc_vmem[...] = acc_new.astype(acc_vmem.dtype)

        cp_wr = pltpu.make_async_copy(row_vmem, table_out.at[pl.ds(ix, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, accum_out.at[pl.ds(ix, 1)],
                                      sems.at[1])
        cp_wr.start()
        cp_wa.start()
        cp_wr.wait()
        cp_wa.wait()


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rowwise_adagrad_kernel(table: jax.Array, accum: jax.Array,
                           uniq_idx: jax.Array, gsum: jax.Array,
                           lr: jax.Array, eps: float = 1e-8,
                           interpret: bool = False):
    """table: (H, D) D % 128 == 0; accum: (H, 1) fp32; uniq_idx: (N,) int32
    (-1 skips); gsum: (N, D) deduped row grads; lr: () fp32.
    Returns (new_table, new_accum) updated in place (io aliasing)."""
    h, d = table.shape
    n = uniq_idx.shape[0]
    kernel = functools.partial(_rwadagrad_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),   # gsum
                pl.BlockSpec(memory_space=MemorySpace.SMEM),  # lr
                pl.BlockSpec(memory_space=MemorySpace.ANY),   # table
                pl.BlockSpec(memory_space=MemorySpace.ANY),   # accum
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, d), table.dtype),
                MemorySpace.VMEM((1, 1), jnp.float32),
                SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((h, d), table.dtype),
                   jax.ShapeDtypeStruct((h, 1), jnp.float32)],
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(uniq_idx, gsum, jnp.asarray(lr, jnp.float32).reshape(1), table,
      accum.reshape(h, 1).astype(jnp.float32))
