"""Pallas TPU kernels: fused multi-hot embedding gather + pooling.

Two forward designs (docs/embedding_forward.md):

* `embedding_bag_kernel` — the legacy one-bag-per-grid-step layout: bag
  indices are scalar-prefetched into SMEM so they can drive row DMAs; each
  grid step owns one bag and double-buffers row copies HBM->VMEM (fetch row
  l+1 while accumulating row l), pooling in fp32 VREGs. Every valid lookup
  slot costs one irregular HBM row read — the paper's "irregular vector
  access" bottleneck (section III-A.2) — so a Zipf-skewed batch re-reads
  its hot rows many times per step.

* `dedup_embedding_bag_kernel` — the plan-driven dedup'd layout: the
  batch's CSR bucketing plan (kernels/sparse_plan.py) is scalar-prefetched;
  each grid step owns a TILE of unique rows and streams them HBM->VMEM
  through an `nbuf`-deep DMA slot rotation (deeper than the legacy 2-slot
  pipeline), then expands each row into every bag that references it via
  the plan's CSR slice. Accumulation happens in the VMEM-resident
  (n_bags, D) output block — revisited by every grid step — so each unique
  row is read from HBM exactly ONCE per batch no matter how many bags
  reference it: forward row traffic drops by the batch duplication factor
  (`launch.analysis.embedding_forward_traffic`).

The embedding dim D is padded to the 128-lane width by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace, SemaphoreType

# the dedup kernel keeps the whole pooled output resident in VMEM across
# the grid; beyond this it must fall back to the legacy kernel (bag-tiled
# output is the tracked follow-on, docs/embedding_forward.md)
_DEDUP_OUT_VMEM_BYTES = 8 * 2**20


def _bag_kernel(idx_ref, table_ref, out_ref, rows_vmem, sems, *,
                max_len: int, mode: str):
    """One grid step = one bag. idx_ref: (B, L) SMEM; table_ref: (H, D) HBM;
    out_ref: (1, D) VMEM block; rows_vmem: (2, 1, D) scratch; sems: 2 DMAs."""
    b = pl.program_id(0)
    d = out_ref.shape[-1]

    def row_copy(slot, j):
        """DMA descriptor for bag row j into double-buffer slot."""
        # ONE descriptor builder serves both start() and wait(): a DMA must
        # be awaited with the descriptor it was started with (any slice of
        # equal shape happens to work, but a mismatched source is latent
        # fragility the moment the shapes stop agreeing)
        ix = jnp.maximum(idx_ref[b, j], 0)
        return pltpu.make_async_copy(table_ref.at[pl.ds(ix, 1)],
                                     rows_vmem.at[slot], sems.at[slot])

    row_copy(0, 0).start()

    def body(j, carry):
        """Pool one bag member; prefetches the next behind it."""
        acc, cnt = carry
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < max_len)
        def _():
            row_copy(jax.lax.rem(j + 1, 2), j + 1).start()

        row_copy(slot, j).wait()
        valid = idx_ref[b, j] >= 0
        acc = acc + jnp.where(valid,
                              rows_vmem[slot].astype(jnp.float32), 0.0)
        cnt = cnt + jnp.where(valid, 1.0, 0.0)
        return acc, cnt

    acc, cnt = jax.lax.fori_loop(
        0, max_len, body,
        (jnp.zeros((1, d), jnp.float32), jnp.zeros((), jnp.float32)))
    if mode == "mean":
        acc = acc / jnp.maximum(cnt, 1.0)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret"))
def embedding_bag_kernel(table: jax.Array, indices: jax.Array,
                         mode: str = "sum",
                         interpret: bool = False) -> jax.Array:
    """table: (H, D) with D a multiple of 128 (pad in ops.py);
    indices: (B, L) int32 (-1 pads). Returns (B, D) pooled rows."""
    b, max_len = indices.shape
    _, d = table.shape
    kernel = functools.partial(_bag_kernel, max_len=max_len, mode=mode)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,),
            in_specs=[pl.BlockSpec(memory_space=MemorySpace.ANY)],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
            scratch_shapes=[
                MemorySpace.VMEM((2, 1, d), table.dtype),
                SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(indices, table)


# ---------------------------------------------------------------------------
# dedup'd plan-driven forward
# ---------------------------------------------------------------------------


def _dedup_bag_kernel(uniq_ref, off_ref, bag_ref, table_ref, out_ref,
                      rows_vmem, sems, *, tile: int, nbuf: int):
    """Grid step t gathers-and-expands unique rows [t*tile, (t+1)*tile).

    uniq_ref: (U,), off_ref: (U+1,), bag_ref: (N,) SMEM (scalar prefetch;
    U is padded to a tile multiple by the wrapper, pads are -1);
    table_ref: (H, D) HBM; out_ref: (n_bags, D) fp32 VMEM block whose index
    map is CONSTANT — the accumulator stays resident across the whole grid
    and spills to HBM once at the end; rows_vmem: (nbuf, 1, D) DMA slot
    rotation; sems: (nbuf,) DMA semaphores.

    Valid unique rows form a prefix (the planner sorts, -1 pads trail), so
    a skipped row never precedes a live one — the pipeline never stalls on
    phantom fetches.
    """
    t = pl.program_id(0)
    base = t * tile

    @pl.when(t == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    def row_copy(r):
        """DMA descriptor for unique row r into its ring slot."""
        # same-descriptor start/wait discipline as _bag_kernel
        ix = jnp.maximum(uniq_ref[base + r], 0)
        slot = jax.lax.rem(r, nbuf)
        return pltpu.make_async_copy(table_ref.at[pl.ds(ix, 1)],
                                     rows_vmem.at[slot], sems.at[slot])

    def start(r):
        """Kick off row r's fetch (live rows only)."""
        @pl.when(uniq_ref[base + r] >= 0)
        def _():
            row_copy(r).start()

    for r in range(min(nbuf, tile)):      # static warmup: fill the pipeline
        start(r)

    def body(r, carry):
        """Await row r, expand its CSR runs, refill the drained slot."""
        valid = uniq_ref[base + r] >= 0

        @pl.when(valid)
        def _():
            row_copy(r).wait()

        # load to VREGs, then immediately refill the drained slot so the
        # next fetch overlaps this row's CSR expansion
        row = rows_vmem[jax.lax.rem(r, nbuf)].astype(jnp.float32)

        @pl.when(r + nbuf < tile)
        def _():
            start(r + nbuf)

        @pl.when(valid)
        def _():
            def expand(j, c):
                """Accumulate the row into bag j's output slot."""
                bag = bag_ref[j]
                out_ref[pl.ds(bag, 1)] = out_ref[pl.ds(bag, 1)] + row
                return c

            jax.lax.fori_loop(off_ref[base + r], off_ref[base + r + 1],
                              expand, 0)

        return carry

    jax.lax.fori_loop(0, tile, body, 0)


@functools.partial(jax.jit, static_argnames=("n_bags", "tile", "nbuf",
                                             "interpret"))
def dedup_embedding_bag_kernel(table: jax.Array, unique_rows: jax.Array,
                               bag_offsets: jax.Array, bag_ids: jax.Array,
                               n_bags: int, tile: int = 8, nbuf: int = 4,
                               interpret: bool = False) -> jax.Array:
    """table: (H, D) with D a multiple of 128 (pad in ops.py); plan arrays
    from kernels/sparse_plan.py (int32, possibly capacity-trimmed); n_bags
    static (= B*F). Returns (n_bags, D) fp32 SUM-pooled bags (mean and the
    output cast are applied by the ops.py wrapper).

    Per-bag accumulation arrives in sorted-row (CSR) order, not flat slot
    order — tested allclose against the oracle like every kernel body; the
    jnp fallback (`ref.dedup_embedding_bag_ref`) is the bit-exact contract.
    """
    _, d = table.shape
    u = unique_rows.shape[0]
    up = max(tile, -(-u // tile) * tile)   # >= one step: step 0 zeroes out
    if up != u:                            # pad U to a tile multiple
        unique_rows = jnp.pad(unique_rows, (0, up - u), constant_values=-1)
        bag_offsets = jnp.pad(bag_offsets, (0, up - u), mode="edge")
    nb = -(-n_bags // 8) * 8               # sublane-align the out block
    if nb * d * 4 > _DEDUP_OUT_VMEM_BYTES:
        raise ValueError(
            f"dedup forward out block {nb}x{d} fp32 exceeds the "
            f"{_DEDUP_OUT_VMEM_BYTES >> 20}MiB VMEM budget — use the "
            "legacy kernel (bag-tiled dedup output is the tracked "
            "follow-on, docs/embedding_forward.md)")
    kernel = functools.partial(_dedup_bag_kernel, tile=tile, nbuf=nbuf)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(up // tile,),
            in_specs=[pl.BlockSpec(memory_space=MemorySpace.ANY)],  # table
            out_specs=pl.BlockSpec((nb, d), lambda t, u_, o_, b_: (0, 0)),
            scratch_shapes=[
                MemorySpace.VMEM((nbuf, 1, d), table.dtype),
                SemaphoreType.DMA((nbuf,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((nb, d), jnp.float32),
        interpret=interpret,
    )(unique_rows, bag_offsets, bag_ids, table)
    return out[:n_bags]
