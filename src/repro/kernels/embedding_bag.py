"""Pallas TPU kernel: fused multi-hot embedding gather + pooling.

TPU-native design (DESIGN.md section 2): the table stays in HBM
(`MemorySpace.ANY`); bag indices are scalar-prefetched into SMEM so they can
drive row DMAs; each grid step owns one bag and double-buffers row copies
HBM->VMEM (fetch row l+1 while accumulating row l), pooling in fp32 VREGs.
The embedding dim D is padded to the 128-lane width by the ops.py wrapper.

This replaces the GPU's warp-per-bag gather with an explicitly scheduled
DMA pipeline — the TPU analogue of the paper's "irregular vector access"
bottleneck (section III-A.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace, SemaphoreType


def _bag_kernel(idx_ref, table_ref, out_ref, rows_vmem, sems, *,
                max_len: int, mode: str):
    """One grid step = one bag. idx_ref: (B, L) SMEM; table_ref: (H, D) HBM;
    out_ref: (1, D) VMEM block; rows_vmem: (2, 1, D) scratch; sems: 2 DMAs."""
    b = pl.program_id(0)
    d = out_ref.shape[-1]

    def start_fetch(slot, j):
        ix = jnp.maximum(idx_ref[b, j], 0)
        pltpu.make_async_copy(table_ref.at[pl.ds(ix, 1)],
                              rows_vmem.at[slot], sems.at[slot]).start()

    start_fetch(0, 0)

    def body(j, carry):
        acc, cnt = carry
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < max_len)
        def _():
            start_fetch(jax.lax.rem(j + 1, 2), j + 1)

        pltpu.make_async_copy(table_ref.at[pl.ds(0, 1)],
                              rows_vmem.at[slot], sems.at[slot]).wait()
        valid = idx_ref[b, j] >= 0
        acc = acc + jnp.where(valid,
                              rows_vmem[slot].astype(jnp.float32), 0.0)
        cnt = cnt + jnp.where(valid, 1.0, 0.0)
        return acc, cnt

    acc, cnt = jax.lax.fori_loop(
        0, max_len, body,
        (jnp.zeros((1, d), jnp.float32), jnp.zeros((), jnp.float32)))
    if mode == "mean":
        acc = acc / jnp.maximum(cnt, 1.0)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret"))
def embedding_bag_kernel(table: jax.Array, indices: jax.Array,
                         mode: str = "sum",
                         interpret: bool = False) -> jax.Array:
    """table: (H, D) with D a multiple of 128 (pad in ops.py);
    indices: (B, L) int32 (-1 pads). Returns (B, D) pooled rows."""
    b, max_len = indices.shape
    _, d = table.shape
    kernel = functools.partial(_bag_kernel, max_len=max_len, mode=mode)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,),
            in_specs=[pl.BlockSpec(memory_space=MemorySpace.ANY)],
            out_specs=pl.BlockSpec((1, d), lambda i, idx_ref: (i, 0)),
            scratch_shapes=[
                MemorySpace.VMEM((2, 1, d), table.dtype),
                SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(indices, table)
