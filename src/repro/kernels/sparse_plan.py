"""Bucketing planner for the fused sparse backward (docs/sparse_optimizer.md).

The legacy sparse path broadcast each bag's pooled gradient to every lookup
slot — a `(B*F*L, D)` float tensor — and then argsorted + segment-summed that
full-width payload before the optimizer kernel ran. The planner here sorts
ONLY the `(B*F*L,)` int32 index stream and emits a CSR-style layout over the
batch's unique rows:

  unique_rows (N,)    i-th unique mega-table row, -1 beyond the unique count
  bag_offsets (N+1,)  [bag_offsets[i], bag_offsets[i+1]) slices bag_ids for
                      unique row i (empty for i >= n_unique)
  bag_ids     (N,)    for each valid lookup slot, in sorted-row order, the
                      flat (example*F + feature) bag whose pooled gradient
                      the slot contributes; N = B*F*L, static

so the optimizer can gather each unique row's referenced POOLED `(1, D)`
gradients directly — per-lookup gradients are never materialized. Slots of
equal row keep their flat-batch order (stable sort), which is what makes the
fused accumulation bit-identical to the legacy scatter-add.

Two implementations with identical outputs:
  * `build_sparse_plan` — pure jnp, jits on-device (used inside train steps
    and shard_map bodies; lowering contains no float tensors — asserted in
    tests/test_sparse_fused.py);
  * `build_sparse_plan_host` — numpy, for the data-pipeline reader thread
    (`data.sparse_plan_hook`) so batch k+1's plan is built while batch k
    computes, mirroring the async cache-exchange overlap of PR 2.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# rows are mega-table offsets (< total_rows << 2**31), so int32 max is a safe
# sort-last sentinel for -1 padding slots
_SENTINEL = np.iinfo(np.int32).max


class SparsePlan(NamedTuple):
    """CSR layout of a batch's lookups, grouped by unique row. A NamedTuple
    of arrays — a pytree, so it rides through jit/shard_map/batch dicts.

    `unique_rows`/`bag_offsets` may be CAPACITY-TRIMMED to (U,)/(U+1,) with
    U < N (see the builders' `capacity`): the tail past the unique count is
    -1 / n_valid either way, and every consumer — the dedup'd forward
    gather, the fused backward, `ref.bag_grad_sums`, the cached tiers'
    miss planning — sizes itself from the arrays, so a trimmed plan just
    means smaller gathers and a shorter kernel grid. Invariant relied on
    by the forward's compact-buffer remap: the live prefix of
    `unique_rows` is STRICTLY ASCENDING (the planner sorts; `cache.
    plan_to_slots` re-sorts after its row->slot relabel to keep it)."""
    unique_rows: jax.Array     # (U,) int32, -1 past the unique count
    bag_offsets: jax.Array     # (U+1,) int32, nondecreasing
    bag_ids: jax.Array         # (N,) int32 flat (example*F + feature) bags

    def to_batch(self) -> dict:
        """The three arrays under the batch-dict keys the train steps read."""
        return {"plan_rows": self.unique_rows,
                "plan_offsets": self.bag_offsets,
                "plan_bags": self.bag_ids}


def plan_from_batch(batch: dict) -> SparsePlan | None:
    """Rehydrate a plan attached by `data.sparse_plan_hook` (or None)."""
    if "plan_rows" not in batch:
        return None
    return SparsePlan(jnp.asarray(batch["plan_rows"], jnp.int32),
                      jnp.asarray(batch["plan_offsets"], jnp.int32),
                      jnp.asarray(batch["plan_bags"], jnp.int32))


def host_plan_from_batch(batch: dict) -> SparsePlan | None:
    """numpy view of a hook-attached plan, no device transfer — what the
    cached tiers' host-side miss planning consumes (core/cache.py)."""
    if "plan_rows" not in batch:
        return None
    return SparsePlan(np.asarray(batch["plan_rows"]),
                      np.asarray(batch["plan_offsets"]),
                      np.asarray(batch["plan_bags"]))


def host_plans_from_batch(batch: dict) -> list[SparsePlan] | None:
    """numpy views of the PER-HOST sub-plans a `data.sparse_plan_hook`
    configured with `n_hosts` attaches (stacked under hplan_* keys) — what
    the multi-host cached tier's per-host miss planning consumes."""
    if "hplan_rows" not in batch:
        return None
    rows = np.asarray(batch["hplan_rows"])
    offs = np.asarray(batch["hplan_offsets"])
    bags = np.asarray(batch["hplan_bags"])
    return [SparsePlan(rows[h], offs[h], bags[h])
            for h in range(rows.shape[0])]


def split_plan_by_host(plan: SparsePlan, n_hosts: int,
                       bags_per_host: int) -> list[SparsePlan]:
    """Split a GLOBAL host-built plan into per-host sub-plans by bag range
    (host h owns the contiguous flat bags [h*bags_per_host,
    (h+1)*bags_per_host) — the data-parallel batch split). Each sub-plan is
    in HOST-LOCAL bag space and equals `build_sparse_plan_host` run on that
    host's sub-batch (asserted in tests/test_cache_multihost.py): the
    multiset of (row, bag) pairs partitions the global plan's and the
    ascending-rows live prefix survives per host.

    No sort runs here: the global plan's runs are row-ascending and each
    run's bags are flat-order ascending, so a host's pairs are found by a
    mask + stable selection and its rows by run-head detection.
    """
    rows = np.asarray(plan.unique_rows)
    offs = np.asarray(plan.bag_offsets).astype(np.int64)
    bags = np.asarray(plan.bag_ids).astype(np.int64)
    nh = bags.shape[0] // n_hosts          # per-host lookup capacity
    n_live = int((rows >= 0).sum())
    n_valid = int(offs[n_live])
    host_of = bags[:n_valid] // bags_per_host
    # run id per live pair: offsets' live prefix is sorted, pads trail
    run_of = np.searchsorted(offs[:n_live + 1], np.arange(n_valid),
                             side="right") - 1
    out = []
    for h in range(n_hosts):
        sel = np.flatnonzero(host_of == h)  # ascending pair position ==
        r_sel = run_of[sel]                 # ascending (row, local bag)
        sub_rows = np.full((nh,), -1, np.int32)
        sub_offs = np.zeros((nh + 1,), np.int32)
        sub_bags = np.zeros((nh,), np.int32)
        if len(sel):
            change = np.empty(len(sel), bool)
            change[0] = True
            change[1:] = r_sel[1:] != r_sel[:-1]
            head_pos = np.flatnonzero(change)
            k = len(head_pos)
            sub_rows[:k] = rows[r_sel[head_pos]]
            ends = np.append(head_pos[1:], len(sel)).astype(np.int64)
            sub_offs[:k + 1] = np.concatenate([[0], ends])
            sub_offs[k + 1:] = ends[-1]
            sub_bags[:len(sel)] = bags[sel] - h * bags_per_host
        out.append(SparsePlan(sub_rows, sub_offs, sub_bags))
    return out


def split_plan_by_ranges(plan: SparsePlan, starts, ends,
                         seg_cap: int | None = None
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Slice a plan into segments over arbitrary DISJOINT ascending row
    ranges — the shared core of `split_plan_by_owner` (uniform contiguous
    owner blocks) and `split_plan_by_table` (each table's row span under
    any layout). Segment s covers global rows [starts[s], ends[s]).

    Because the plan's live prefix is sorted ascending and the ranges are
    ascending and disjoint, each segment's rows — and its (row, bag) pairs
    in `bag_ids` — form a CONTIGUOUS slice: the split is two searchsorted
    calls and pure slicing, no sort. Rows outside every range are simply
    not claimed by any segment (e.g. a table_wise mega table's per-shard
    tail padding).

    Returns (seg_rows (S, cap) int32 SEGMENT-LOCAL rows (global minus
    starts[s]) -1-padded, seg_offsets (S, cap+1) int32 ABSOLUTE positions
    into the shared `bag_ids` with pad entries equal to the segment's bag
    end, and seg_base (S,) int32 = starts — the base the segmented fused
    backward adds back). `seg_cap` fixes the per-segment capacity for
    stable jit shapes (raises on overflow); default is the tight
    per-call maximum.
    """
    rows = np.asarray(plan.unique_rows)
    offs = np.asarray(plan.bag_offsets).astype(np.int64)
    starts = np.asarray(starts, np.int64)
    ends = np.asarray(ends, np.int64)
    n_seg = len(starts)
    assert len(ends) == n_seg, (len(ends), n_seg)
    if n_seg:
        assert np.all(ends >= starts)
        assert np.all(starts[1:] >= ends[:-1]), \
            "ranges must be ascending and disjoint"
    n_live = int((rows >= 0).sum())
    live = rows[:n_live].astype(np.int64)
    lo = np.searchsorted(live, starts)
    hi = np.searchsorted(live, ends)
    widest = int((hi - lo).max()) if n_seg else 0
    cap = widest if seg_cap is None else seg_cap
    if widest > cap:
        raise ValueError(
            f"owner segment overflow: widest owner holds {widest} unique "
            f"rows > seg_cap={cap}")
    seg_rows = np.full((n_seg, cap), -1, np.int32)
    seg_offs = np.zeros((n_seg, cap + 1), np.int32)
    for s in range(n_seg):
        a, b = int(lo[s]), int(hi[s])
        k = b - a
        seg_rows[s, :k] = live[a:b] - starts[s]
        seg_offs[s, :k + 1] = offs[a:b + 1]
        seg_offs[s, k + 1:] = offs[b]
    seg_base = starts.astype(np.int32)
    return seg_rows, seg_offs, seg_base


def split_plan_by_owner(plan: SparsePlan, shard_rows: int, n_shards: int,
                        seg_cap: int | None = None
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Slice a plan into per-OWNER segments for the routed sparse update:
    owner s of the row-sharded capacity tier — or of a table_wise placement,
    whose owners are the same contiguous blocks — holds rows
    [s*shard_rows, (s+1)*shard_rows). The uniform-blocks special case of
    `split_plan_by_ranges`; see it for the returned layout.
    """
    starts = np.arange(n_shards, dtype=np.int64) * shard_rows
    return split_plan_by_ranges(plan, starts, starts + shard_rows,
                                seg_cap=seg_cap)


def split_plan_by_table(plan: SparsePlan, table_offsets, table_rows,
                        seg_cap: int | None = None
                        ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Slice a plan into PER-TABLE segments: table t owns the mega rows
    [table_offsets[t], table_offsets[t] + table_rows[t]) under any layout
    whose tables don't interleave (all of core/placement.py's). Feeds the
    per-table pricing of `launch.analysis.recommend_placement` (each
    segment's live-row count is the table's per-batch unique footprint)
    and per-table update granularity.

    Segments are returned in TABLE order (the caller's table ids), not row
    order — `split_plan_by_ranges` requires ascending ranges, so the split
    runs in row order and is unpermuted here. Same layout as
    `split_plan_by_owner`, with seg_base[t] = table_offsets[t].
    """
    starts = np.asarray(table_offsets, np.int64)
    ends = starts + np.asarray(table_rows, np.int64)
    order = np.argsort(starts, kind="stable")
    seg_rows, seg_offs, seg_base = split_plan_by_ranges(
        plan, starts[order], ends[order], seg_cap=seg_cap)
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    return seg_rows[inv], seg_offs[inv], seg_base[inv]


def coalesce_rows(rows: np.ndarray, chunk: int, total_rows: int,
                  min_fill: int = 1) -> tuple[np.ndarray, np.ndarray]:
    """Greedily cover a sorted row list with contiguous `chunk`-row blocks —
    the run-coalescer behind the chunk-granular capacity<->cache transfers
    (kernels/cache_ops.cache_fetch_chunked).

    rows: (N,) int64/int32 ASCENDING capacity rows (the live prefix of a
    plan's miss list — `split_plan_by_host` sub-plans and `_split_batch`
    both emit sorted rows, so no sort runs here); chunk: block height >= 1;
    total_rows: capacity height R, used to clamp block starts so
    start+chunk <= R (a block may over-fetch rows below its first member —
    harmless, the fetch is read-only).

    `min_fill` is the density-adaptive fallback: blocks holding fewer than
    `min_fill` member rows are DROPPED (their rows get pos = -1) so the
    caller routes isolated misses through the per-row path instead of
    paying (chunk - 1) rows of over-fetch each. min_fill = 1 keeps every
    block (pure fixed-chunk coverage).

    Returns (starts (K,) int32 block start rows, pos (N,) int32 with
    pos[i] = k*chunk + (rows[i] - starts[k]) — row i's position inside the
    (K*chunk, D) shadow slab, the `src_pos` a chunked
    `cache_ops.cache_commit` consumes — or -1 for rows of dropped blocks).
    Greedy left-to-right: a new block opens at min(row, R-chunk) whenever
    the current block cannot hold the next row; on the frequency-reordered
    Zipf head (core/placement.frequency_reorder) consecutive misses
    collapse to K << N blocks.
    """
    rows = np.asarray(rows, np.int64)
    n = rows.shape[0]
    if chunk <= 1 or n == 0:
        starts = rows.astype(np.int32)
        return starts, np.arange(n, dtype=np.int32)
    chunk = min(chunk, total_rows)
    starts_list = []
    pos = np.empty((n,), np.int32)
    i = 0
    while i < n:
        start = min(int(rows[i]), total_rows - chunk)
        # all rows the block covers: rows are ascending, so one searchsorted
        j = int(np.searchsorted(rows, start + chunk, side="left"))
        if j - i >= min_fill:
            k = len(starts_list)
            starts_list.append(start)
            pos[i:j] = k * chunk + (rows[i:j] - start).astype(np.int32)
        else:
            pos[i:j] = -1
        i = j
    return np.asarray(starts_list, np.int32), pos


def build_sparse_plan(idx: jax.Array,
                      lookups_per_bag: int | None = None,
                      capacity: int | None = None) -> SparsePlan:
    """idx: (B, F, L) offset global rows with -1 pads (or already-flat (N,)
    with `lookups_per_bag=L`). Pure int32 compute; O(N log N) in LOOKUPS,
    independent of table height (the paper's flat CPU hash-size curve,
    Fig. 12, depends on exactly this property).

    `capacity` trims unique_rows/bag_offsets to (capacity,)/(capacity+1,)
    — the static unique budget the dedup'd forward gather sizes itself by.
    The trim is a static slice, so the CALLER owns the contract that the
    batch's unique count fits (jit cannot raise data-dependently; the host
    twin below DOES raise, which is what the reader-thread hook runs)."""
    if idx.ndim == 3:
        _, _, lk = idx.shape
    else:
        assert lookups_per_bag is not None, "flat idx needs lookups_per_bag"
        lk = lookups_per_bag
    flat = idx.reshape(-1).astype(jnp.int32)
    n = flat.shape[0]
    valid = flat >= 0
    safe = jnp.where(valid, flat, _SENTINEL)          # pads sort last
    order = jnp.argsort(safe)                         # stable: flat order
    s = safe[order]                                   # kept within a run
    bag_ids = (order // lk).astype(jnp.int32)
    head = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]]) \
        & (s != _SENTINEL)
    rank = jnp.cumsum(head) - 1                       # unique id at heads
    n_valid = valid.sum().astype(jnp.int32)
    unique_rows = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(head, rank, n)].set(s, mode="drop")
    # run i starts at its head's sorted position; runs are contiguous and
    # valid slots sort first, so offsets[i+1] doubles as run i's end and the
    # n_valid fill closes the last run / empties the tail
    bag_offsets = jnp.full((n + 1,), n_valid, jnp.int32).at[
        jnp.where(head, rank, n + 1)].set(
            jnp.arange(n, dtype=jnp.int32), mode="drop")
    if capacity is not None and capacity < n:
        unique_rows = unique_rows[:capacity]
        bag_offsets = bag_offsets[:capacity + 1]
    return SparsePlan(unique_rows, bag_offsets, bag_ids)


def build_sparse_plan_host(idx: np.ndarray,
                           lookups_per_bag: int | None = None,
                           capacity: int | None = None) -> SparsePlan:
    """numpy twin of `build_sparse_plan` with identical outputs (asserted in
    tests/test_sparse_fused.py) — runs in the pipeline reader thread so the
    sort overlaps the in-flight batch's device compute. Unlike the jnp
    twin, `capacity` overflow RAISES here (shapes are host-side)."""
    idx = np.asarray(idx)
    if idx.ndim == 3:
        lk = idx.shape[2]
    else:
        assert lookups_per_bag is not None, "flat idx needs lookups_per_bag"
        lk = lookups_per_bag
    flat = idx.reshape(-1).astype(np.int64)
    n = flat.shape[0]
    valid = flat >= 0
    safe = np.where(valid, flat, _SENTINEL)
    order = np.argsort(safe, kind="stable")
    s = safe[order]
    bag_ids = (order // lk).astype(np.int32)
    head = np.concatenate([np.ones((1,), bool), s[1:] != s[:-1]]) \
        & (s != _SENTINEL)
    n_valid = int(valid.sum())
    heads = np.flatnonzero(head)
    if capacity is not None and len(heads) > capacity:
        raise ValueError(
            f"plan capacity overflow: batch has {len(heads)} unique rows "
            f"> capacity={capacity}; raise the capacity or shrink the batch")
    u = n if capacity is None else min(capacity, n)
    unique_rows = np.full((u,), -1, np.int32)
    unique_rows[:len(heads)] = s[heads]
    bag_offsets = np.full((u + 1,), n_valid, np.int32)
    bag_offsets[:len(heads)] = heads
    return SparsePlan(unique_rows, bag_offsets, bag_ids)
