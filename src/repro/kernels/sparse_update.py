"""Pallas TPU kernel: fused sparse backward — bag-gradient gather +
aggregation + row-wise AdaGrad in ONE pass over unique rows.

This supersedes the two-pass `dedup_grads_ref` + `rowwise_adagrad_kernel`
pipeline for the training hot spot the paper calls out ("not optimized for
gradient aggregation", section VII). The host/device planner
(kernels/sparse_plan.py) has already bucketed the batch's lookup stream by
unique row — int32 arrays only — so per grid step (one unique row) this
kernel:

    DMA row + accumulator in (HBM->VMEM)
    for each referencing bag (CSR slice of the plan):
        DMA the bag's POOLED (1, D) gradient in — DOUBLE-BUFFERED, bag
        j+1's fetch rides behind bag j's accumulate — then add in VMEM
    acc' = acc + mean(g^2);  w' = w - lr * g * rsqrt(acc' + eps)
    DMA row + accumulator back, in place via io aliasing

No `(B*F*L, D)` per-lookup gradient tensor ever exists: the only full-width
traffic is the pooled `(B*F, D)` grads (which autodiff produces anyway) and
the touched table rows. Padding entries (unique_rows[i] < 0) are skipped
with pl.when so one lowered kernel serves any batch sparsity pattern.

Capacity note: the plan arrays ride in scalar-prefetch SMEM (same contract
as rowwise_adagrad's idx); at production B*F*L the bag list needs chunked
SMEM staging — tracked in docs/sparse_optimizer.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace, SemaphoreType


def _fused_kernel(uniq_ref, off_ref, bag_ref, base_ref, lr_ref, grads_ref,
                  table_ref, accum_ref, table_out, accum_out, row_vmem,
                  acc_vmem, gbuf, gacc, sems, *, eps: float):
    """Grid step (s, i) updates segment s's unique row uniq_ref[s, i].

    The grid is (S, C): S per-owner SEGMENTS of C rows each (the routed
    multi-host update groups a plan's rows by owning capacity shard —
    docs/cache.md; the single-plan path is simply S=1). Rows are
    SEGMENT-LOCAL; base_ref[s] rebases them into this table.

    uniq_ref: (S, C), off_ref: (S, C+1) ABSOLUTE positions into bag_ref,
    bag_ref: (N,), base_ref: (S,) SMEM (scalar prefetch; C may be
    capacity-trimmed below N); lr_ref: (1,) SMEM; grads_ref: (B*F, D) HBM
    pooled grads; table_ref/table_out: (H, D) HBM aliased;
    accum_ref/accum_out: (H, 1) HBM aliased; row_vmem: (1, D); acc_vmem:
    (1, 1); gbuf: (2, 1, D) f32 double-buffered grad staging; gacc: (1, D)
    f32 accumulator; sems: 4 DMA semaphores (row, accum, grad slot 0/1).
    """
    s = pl.program_id(0)
    i = pl.program_id(1)
    ix = uniq_ref[s, i]

    @pl.when(ix >= 0)
    def _():
        row = base_ref[s] + ix
        # row + accumulator fetches overlap the bag-gradient stream
        cp_r = pltpu.make_async_copy(table_ref.at[pl.ds(row, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(accum_ref.at[pl.ds(row, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        gacc[...] = jnp.zeros_like(gacc)

        lo = off_ref[s, i]
        hi = off_ref[s, i + 1]

        def grad_copy(j):
            """DMA descriptor for bag j's grad row (parity-slotted)."""
            # slot = parity of the ABSOLUTE bag position, so start(j+1)
            # and wait(j) always address different slots/semaphores; one
            # descriptor builder serves start AND wait (see embedding_bag)
            slot = jax.lax.rem(j, 2)
            return pltpu.make_async_copy(
                grads_ref.at[pl.ds(bag_ref[j], 1)], gbuf.at[slot],
                sems.at[2 + slot])

        @pl.when(lo < hi)
        def _():
            grad_copy(lo).start()

        def body(j, carry):
            """Accumulate bag j's grad; prefetch bag j+1 behind it."""
            @pl.when(j + 1 < hi)
            def _():
                grad_copy(j + 1).start()    # fetch bag j+1 behind bag j
            grad_copy(j).wait()
            # flat-batch bag order (the planner's stable sort) — keeps the
            # accumulation bit-identical to the legacy scatter-add
            gacc[...] = gacc[...] + \
                gbuf[jax.lax.rem(j, 2)].astype(jnp.float32)
            return carry

        jax.lax.fori_loop(lo, hi, body, 0)
        cp_r.wait()
        cp_a.wait()

        g = gacc[...]
        acc_new = acc_vmem[...].astype(jnp.float32) + \
            jnp.mean(jnp.square(g), axis=-1, keepdims=True)
        w_new = row_vmem[...].astype(jnp.float32) - \
            lr_ref[0] * g * jax.lax.rsqrt(acc_new + eps)

        row_vmem[...] = w_new.astype(row_vmem.dtype)
        acc_vmem[...] = acc_new.astype(acc_vmem.dtype)

        cp_wr = pltpu.make_async_copy(row_vmem, table_out.at[pl.ds(row, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, accum_out.at[pl.ds(row, 1)],
                                      sems.at[1])
        cp_wr.start()
        cp_wa.start()
        cp_wr.wait()
        cp_wa.wait()


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_bag_backward_adagrad_segments_kernel(
        table: jax.Array, accum: jax.Array, seg_rows: jax.Array,
        seg_offsets: jax.Array, bag_ids: jax.Array, pooled_grads: jax.Array,
        lr: jax.Array, seg_base: jax.Array, eps: float = 1e-8,
        interpret: bool = False):
    """Per-owner-segment generalization: seg_rows (S, C) SEGMENT-LOCAL rows
    (-1 pads), seg_offsets (S, C+1) ABSOLUTE into bag_ids (N,), seg_base
    (S,) per-segment row bases (`kernels.sparse_plan.split_plan_by_owner`'s
    layout); table: (H, D) D % 128 == 0; accum: (H,) or (H, 1) fp32;
    pooled_grads: (B*F, D) fp32; lr: () fp32. Grid (S, C), rows update in
    place (io aliasing). Returns (new_table (H, D), new_accum (H, 1))."""
    h, d = table.shape
    s, c = seg_rows.shape
    kernel = functools.partial(_fused_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(s, c),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.SMEM),  # lr
                pl.BlockSpec(memory_space=MemorySpace.ANY),   # pooled grads
                pl.BlockSpec(memory_space=MemorySpace.ANY),   # table
                pl.BlockSpec(memory_space=MemorySpace.ANY),   # accum
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, d), table.dtype),
                MemorySpace.VMEM((1, 1), jnp.float32),
                MemorySpace.VMEM((2, 1, d), jnp.float32),
                MemorySpace.VMEM((1, d), jnp.float32),
                SemaphoreType.DMA((4,)),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((h, d), table.dtype),
                   jax.ShapeDtypeStruct((h, 1), jnp.float32)],
        input_output_aliases={6: 0, 7: 1},
        interpret=interpret,
    )(seg_rows, seg_offsets, bag_ids, seg_base.astype(jnp.int32),
      jnp.asarray(lr, jnp.float32).reshape(1),
      pooled_grads.astype(jnp.float32), table,
      accum.reshape(h, 1).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_bag_backward_adagrad_kernel(table: jax.Array, accum: jax.Array,
                                      unique_rows: jax.Array,
                                      bag_offsets: jax.Array,
                                      bag_ids: jax.Array,
                                      pooled_grads: jax.Array,
                                      lr: jax.Array, eps: float = 1e-8,
                                      interpret: bool = False):
    """table: (H, D) D % 128 == 0; accum: (H,) or (H, 1) fp32; plan arrays
    from kernels/sparse_plan.py (int32); pooled_grads: (B*F, D) fp32;
    lr: () fp32. Returns (new_table (H, D), new_accum (H, 1)) updated in
    place (io aliasing). The ONE-segment case of the segmented kernel
    above (a plan's bag_offsets are already absolute when unsegmented)."""
    return fused_bag_backward_adagrad_segments_kernel(
        table, accum, unique_rows[None, :], bag_offsets[None, :], bag_ids,
        pooled_grads, lr, jnp.zeros((1,), jnp.int32), eps=eps,
        interpret=interpret)
