"""jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * pad the embedding dim to the TPU lane width (128) and the feature count
    to the sublane width (8) before invoking kernels, un-pad after;
  * dispatch: real Pallas kernel on TPU, `interpret=True` kernel body when
    explicitly requested (tests), pure-jnp oracle otherwise (CPU runtime);
  * differentiability: embedding_bag carries a custom VJP (scatter-add);
    dot_interaction is natively differentiable through the oracle and uses
    the kernel only for the forward pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dot_interaction import dot_interaction_kernel
from repro.kernels.embedding_bag import (dedup_embedding_bag_kernel,
                                         embedding_bag_kernel)
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.rowwise_adagrad import rowwise_adagrad_kernel
from repro.kernels.sparse_plan import SparsePlan, build_sparse_plan
from repro.kernels.sparse_update import (
    fused_bag_backward_adagrad_kernel,
    fused_bag_backward_adagrad_segments_kernel)

LANE = 128
SUBLANE = 8


def _use_pallas(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)

# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def embedding_bag(table: jax.Array, indices: jax.Array, mode: str = "sum",
                  use_kernel: bool | None = None,
                  interpret: bool = False) -> jax.Array:
    """Pooled multi-hot lookup. table: (H, D); indices: (B, L) int32, -1 pads.
    Returns (B, D)."""
    if _use_pallas(use_kernel) or interpret:
        d = table.shape[1]
        tp = _pad_to(table, LANE, 1)
        out = embedding_bag_kernel(tp, indices, mode=mode,
                                   interpret=interpret)
        return out[:, :d]
    return ref.embedding_bag_ref(table, indices, mode)


def _bag_fwd(table, indices, mode, use_kernel, interpret):
    out = embedding_bag(table, indices, mode, use_kernel, interpret)
    return out, (indices, table.shape[0],
                 (indices >= 0).sum(1) if mode == "mean" else None)


def _bag_bwd(mode, use_kernel, interpret, res, g):
    indices, h, cnt = res
    b, lk = indices.shape
    gf = g.astype(jnp.float32)
    if mode == "mean":
        gf = gf / jnp.maximum(cnt, 1)[:, None]
    valid = indices >= 0
    idx = jnp.where(valid, indices, h)
    gexp = jnp.broadcast_to(gf[:, None, :], (b, lk, g.shape[-1]))
    gtab = jnp.zeros((h + 1, g.shape[-1]), jnp.float32).at[idx.reshape(-1)] \
        .add(jnp.where(valid.reshape(-1)[:, None], gexp.reshape(b * lk, -1),
                       0.0))[:h]
    return gtab.astype(g.dtype), None


embedding_bag.defvjp(_bag_fwd, _bag_bwd)

# ---------------------------------------------------------------------------
# dedup_embedding_bag — the plan-shared forward (docs/embedding_forward.md)
# ---------------------------------------------------------------------------


def dedup_embedding_bag(table: jax.Array, indices: jax.Array,
                        plan: SparsePlan | None = None, mode: str = "sum",
                        use_kernel: bool | None = None,
                        interpret: bool = False) -> jax.Array:
    """Deduplicated pooled multi-hot lookup: the table is gathered once per
    plan entry (unique row), not once per lookup slot.

    table: (H, D); indices: (B, L) int32, -1 pads; plan: SparsePlan built
    over indices' FLAT stream (bag = slot // L) — e.g. the reader thread's
    `data.sparse_plan_hook` product, possibly capacity-trimmed; built on
    device when None. Returns (B, D).

    The jnp fallback is BIT-EXACT vs `embedding_bag`/`ref.embedding_bag_ref`
    (the forward's acceptance contract); the Pallas kernel expands bags in
    the plan's CSR order and is tested allclose like every kernel body.
    """
    if plan is None:
        plan = build_sparse_plan(indices.reshape(-1),
                                 lookups_per_bag=indices.shape[1])
    return _dedup_bag(table, indices, plan.unique_rows, plan.bag_offsets,
                      plan.bag_ids, mode, use_kernel, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _dedup_bag(table, indices, rows, offs, bags, mode, use_kernel,
               interpret):
    if _use_pallas(use_kernel) or interpret:
        d = table.shape[1]
        tp = _pad_to(table, LANE, 1)
        out = dedup_embedding_bag_kernel(tp, rows, offs, bags,
                                         n_bags=indices.shape[0],
                                         interpret=interpret)[:, :d]
        if mode == "mean":
            cnt = jnp.maximum((indices >= 0).sum(1, keepdims=True), 1)
            out = out / cnt
        return out.astype(table.dtype)
    return ref.dedup_embedding_bag_ref(table, indices, rows, mode)


def _dedup_fwd(table, indices, rows, offs, bags, mode, use_kernel,
               interpret):
    out = _dedup_bag(table, indices, rows, offs, bags, mode, use_kernel,
                     interpret)
    # identical residual layout to embedding_bag's VJP — same backward
    return out, (indices, table.shape[0],
                 (indices >= 0).sum(1) if mode == "mean" else None)


def _dedup_bwd(mode, use_kernel, interpret, res, g):
    gtab, _ = _bag_bwd(mode, use_kernel, interpret, res, g)
    return gtab, None, None, None, None


_dedup_bag.defvjp(_dedup_fwd, _dedup_bwd)

# ---------------------------------------------------------------------------
# dot_interaction
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def dot_interaction(z: jax.Array, tile_b: int = 8,
                    use_kernel: bool | None = None,
                    interpret: bool = False) -> jax.Array:
    """z: (B, F, D) -> (B, F*(F-1)//2) strict-lower-triangle pairwise dots."""
    if _use_pallas(use_kernel) or interpret:
        b, f, d = z.shape
        zp = _pad_to(_pad_to(z, LANE, 2), SUBLANE, 1)
        tb = tile_b if b % tile_b == 0 else 1
        s = dot_interaction_kernel(zp, tile_b=tb, interpret=interpret)
        rows, cols = np.tril_indices(f, -1)     # static pack, fuses in XLA
        return s[:, rows, cols]
    return ref.dot_interaction_ref(z)


def _dot_fwd(z, tile_b, use_kernel, interpret):
    return dot_interaction(z, tile_b, use_kernel, interpret), z


def _dot_bwd(tile_b, use_kernel, interpret, z, g):
    b, f, d = z.shape
    rows, cols = np.tril_indices(f, -1)
    s_bar = jnp.zeros((b, f, f), jnp.float32)
    s_bar = s_bar.at[:, rows, cols].set(g.astype(jnp.float32))
    s_bar = s_bar + jnp.swapaxes(s_bar, 1, 2)   # d(zi.zj) hits both rows
    gz = jnp.einsum("bfg,bgd->bfd", s_bar, z.astype(jnp.float32))
    return (gz.astype(z.dtype),)


dot_interaction.defvjp(_dot_fwd, _dot_bwd)

# ---------------------------------------------------------------------------
# rowwise_adagrad (not differentiated through — it IS the optimizer)
# ---------------------------------------------------------------------------


def _pad_scale_lr(table, grads, lr):
    """Lane-pad (table, grads) and compensate lr for the padded mean(g^2).

    The kernels compute mean(g^2) over the PADDED dim Dp; scaling the padded
    grads by sqrt(Dp/d) makes that equal the true mean over d, and lr is
    divided by the same factor so the weight delta lr_k * g_k * rsqrt(...)
    stays lr * g * rsqrt(...). When D is already lane-aligned (every
    production config: d=128) all three pass through UNTOUCHED — no
    whole-table pad copy and no full-payload scale multiply per step.
    """
    d = table.shape[1]
    tp = _pad_to(table, LANE, 1)
    if tp.shape[1] == d:
        return tp, grads, jnp.asarray(lr, jnp.float32)
    scale = np.sqrt(tp.shape[1] / d).astype(np.float32)
    return tp, _pad_to(grads, LANE, 1) * scale, \
        jnp.asarray(lr, jnp.float32) / scale


def rowwise_adagrad_update(table: jax.Array, accum: jax.Array,
                           indices: jax.Array, grads: jax.Array,
                           lr, eps: float = 1e-8,
                           use_kernel: bool | None = None,
                           interpret: bool = False
                           ) -> tuple[jax.Array, jax.Array]:
    """Apply deduplicated row-wise AdaGrad (legacy two-pass layout).

    table: (H, D); accum: (H,) fp32; indices: (N,) int32 per-lookup rows
    (-1 pads); grads: (N, D) per-lookup gradients. Returns (table', accum').

    Prefer `fused_sparse_backward` where the caller holds (idx, pooled
    grads): it skips the per-lookup broadcast this signature forces.
    """
    h, d = table.shape
    if _use_pallas(use_kernel) or interpret:
        uniq, gsum = ref.dedup_grads_ref(indices, grads, h)
        tp, gp, lr_eff = _pad_scale_lr(table, gsum, lr)
        new_t, new_a = rowwise_adagrad_kernel(tp, accum, uniq, gp, lr_eff,
                                              eps=eps, interpret=interpret)
        return new_t[:, :d], new_a[:, 0]
    return ref.rowwise_adagrad_ref(table, accum, indices, grads, lr, eps)


def fused_sparse_backward(table: jax.Array, accum: jax.Array,
                          idx: jax.Array | None, pooled_grad: jax.Array,
                          lr, eps: float = 1e-8,
                          plan: SparsePlan | None = None,
                          use_kernel: bool | None = None,
                          interpret: bool = False
                          ) -> tuple[jax.Array, jax.Array]:
    """One-pass sparse backward + row-wise AdaGrad from POOLED gradients —
    per-lookup gradients are never materialized (docs/sparse_optimizer.md).

    table: (H, D); accum: (H,) fp32; idx: (B, F, L) int32 rows (-1 pads) —
    may be None when `plan` is given; pooled_grad: (B, F, D) bag gradients
    straight from autodiff. `plan` short-circuits the on-device bucketing
    with one built ahead of time (`data.sparse_plan_hook` builds batch k+1's
    in the reader thread while batch k computes). Returns (table', accum').

    Matches `rowwise_adagrad_update` fed the legacy broadcast layout
    bit-for-bit (same per-row accumulation order — the planner's stable
    sort), minus the (B*F*L, D) intermediates.
    """
    h, d = table.shape
    if plan is None:
        assert idx is not None, "need idx to build a SparsePlan"
        plan = build_sparse_plan(idx)
    pooled2 = pooled_grad.reshape(-1, d)
    if _use_pallas(use_kernel) or interpret:
        tp, gp, lr_eff = _pad_scale_lr(table, pooled2, lr)
        new_t, new_a = fused_bag_backward_adagrad_kernel(
            tp, accum, plan.unique_rows, plan.bag_offsets, plan.bag_ids,
            gp, lr_eff, eps=eps, interpret=interpret)
        return new_t[:, :d], new_a[:, 0]
    return ref.fused_bag_backward_adagrad_ref(
        table, accum, plan.unique_rows, plan.bag_offsets, plan.bag_ids,
        pooled2, lr, eps)


def fused_sparse_backward_segments(table: jax.Array, accum: jax.Array,
                                   seg_rows: jax.Array,
                                   seg_offsets: jax.Array,
                                   bag_ids: jax.Array,
                                   pooled_grad: jax.Array, lr,
                                   seg_base: jax.Array | None = None,
                                   eps: float = 1e-8,
                                   use_kernel: bool | None = None,
                                   interpret: bool = False
                                   ) -> tuple[jax.Array, jax.Array]:
    """`fused_sparse_backward` over PER-OWNER SEGMENTS of one plan — the
    routed update of the multi-host cached tier (docs/cache.md): segment s
    covers the rows the s-th capacity shard owns, with SEGMENT-LOCAL row
    ids rebased by seg_base[s] (`kernels.sparse_plan.split_plan_by_owner`).

    seg_rows: (S, C) int32 -1-padded; seg_offsets: (S, C+1) int32 ABSOLUTE
    into bag_ids (N,); pooled_grad: (B, F, D) or (B*F, D); seg_base
    defaults to all-zero (segments already in table row space — the
    shard_map per-owner body, where `table` IS the owner's shard). Each
    covered row updates with bits identical to the unsegmented
    `fused_sparse_backward` (asserted in tests/test_cache_multihost.py).
    """
    h, d = table.shape
    s = seg_rows.shape[0]
    if seg_base is None:
        seg_base = jnp.zeros((s,), jnp.int32)
    pooled2 = pooled_grad.reshape(-1, d)
    if _use_pallas(use_kernel) or interpret:
        tp, gp, lr_eff = _pad_scale_lr(table, pooled2, lr)
        new_t, new_a = fused_bag_backward_adagrad_segments_kernel(
            tp, accum, seg_rows, seg_offsets, bag_ids, gp, lr_eff,
            jnp.asarray(seg_base, jnp.int32), eps=eps, interpret=interpret)
        return new_t[:, :d], new_a[:, 0]
    # jnp path: segments are disjoint row ranges of one plan, so the
    # flattened (rows rebased, offsets kept absolute) view is itself a
    # valid abs-offset plan over the whole table
    rows_flat = jnp.where(seg_rows >= 0,
                          seg_rows + jnp.asarray(seg_base, jnp.int32)[:, None],
                          -1).reshape(-1)
    offs_flat = jnp.concatenate(
        [seg_offsets[:, :-1].reshape(-1), seg_offsets[-1:, -1]])
    return ref.fused_bag_backward_adagrad_abs_ref(
        table, accum, rows_flat, offs_flat, bag_ids, pooled2, lr, eps)


# ---------------------------------------------------------------------------
# flash_attention (forward; training uses the XLA blockwise fallback)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    block_q: int = 128, block_k: int = 128,
                    causal: bool = True,
                    use_kernel: bool | None = None,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: (b, s, h, dh) (layer-zoo layout). Pads dh to the lane width
    and s to the block size; padded KV rows are masked by causality."""
    if not (_use_pallas(use_kernel) or interpret):
        from repro.kernels.ref import flash_attention_ref
        out = flash_attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                  v.swapaxes(1, 2), causal)
        return out.swapaxes(1, 2)
    assert causal, "kernel path masks seq padding via causality"
    b, s, h, dh = q.shape
    qt = _pad_to(_pad_to(q.swapaxes(1, 2), LANE, 3), block_q, 2)
    kt = _pad_to(_pad_to(k.swapaxes(1, 2), LANE, 3), block_k, 2)
    vt = _pad_to(_pad_to(v.swapaxes(1, 2), LANE, 3), block_k, 2)
    # dh padding changes softmax scale: kernel divides by sqrt(padded dh);
    # pre-scale q to compensate
    scale_fix = np.sqrt(qt.shape[-1] / dh).astype(np.float32)
    out = flash_attention_kernel(qt * scale_fix, kt, vt, block_q=block_q,
                                 block_k=block_k, causal=True,
                                 interpret=interpret)
    return out[:, :, :s, :dh].swapaxes(1, 2)
