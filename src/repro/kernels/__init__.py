"""Pallas TPU kernels for the paper's compute hot-spots.

The paper (section III-A.2) identifies irregular embedding-vector access as the
throughput limiter of recommendation training, and section VII notes prior
near-memory accelerators are "not optimized for gradient aggregation". The
three kernels here cover exactly that path:

  embedding_bag    fused multi-hot gather + pooling (fwd) — the EMB lookup,
                   legacy one-row-read-per-slot AND the plan-driven dedup'd
                   design (each unique row leaves HBM once per batch)
  dot_interaction  pairwise-dot feature interaction (section III-A.3), MXU-shaped
  rowwise_adagrad  deduplicated sparse gradient aggregation + row-wise
                   AdaGrad apply — the EMB backward/update (legacy two-pass)
  sparse_update    fused bag-gradient gather + aggregation + row-wise
                   AdaGrad over the sparse_plan.py CSR bucketing — the
                   EMB backward/update without per-lookup gradients
  cache_ops        capacity<->cache row exchange (eviction-writeback +
                   fetch-on-miss) with fused LFU counter updates — the
                   swap engine of the cached embedding tier (core/cache.py)
  flash_attention  causal streaming attention with static triangle
                   skipping — the prefill_32k hot spot of the LM family

Each kernel ships an `ops.py` jit wrapper and a pure-jnp oracle in `ref.py`;
tests sweep shapes/dtypes with interpret=True. On non-TPU backends the
wrappers transparently fall back to the oracle so the full system trains on
CPU; `interpret=True` executes the real kernel body for validation.
"""
from repro.kernels.cache_ops import cache_exchange, lfu_touch  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    dedup_embedding_bag,
    dot_interaction,
    embedding_bag,
    flash_attention,
    fused_sparse_backward,
    rowwise_adagrad_update,
)
from repro.kernels.sparse_plan import (  # noqa: F401
    SparsePlan,
    build_sparse_plan,
    build_sparse_plan_host,
    host_plan_from_batch,
    plan_from_batch,
)
