"""Pallas TPU kernel: batched capacity<->cache row exchange + LFU counters.

The cached embedding tier (core/cache.py) keeps the mega table in a slow
"capacity" tier (host-resident / pooled-HBM) and a fixed-size hot-row cache
on device. Each step the manager emits a per-slot WORKLIST: slot i may first
write its dirty victim row back to capacity (eviction-writeback) and then be
refilled from a missed capacity row (fetch-on-miss), seeding the slot's LFU
score. This kernel executes that worklist as an explicitly scheduled DMA
pipeline — the TPU analogue of the UVM/CacheEmbedding swap-in/swap-out path —
moving the embedding row AND its row-wise AdaGrad accumulator together so an
evicted row can resume training after a later re-fetch.

Grid step i = worklist entry i; `pl.when` guards skip -1 entries, so one
lowered kernel serves any hit/miss pattern. Rows ride HBM->VMEM->HBM through
a (1, D) scratch; the accumulator and LFU scalar through (1, 1) scratches.

The `cache_exchange` / `lfu_touch` wrappers dispatch: Pallas kernel on TPU
(or `interpret=True` for tests), pure-jnp oracle (kernels/ref.py) otherwise.
D is padded to the 128-lane width here; real deployments keep D lane-aligned
so the pad is a no-op.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import MemorySpace, SemaphoreType

from repro.kernels import ref

LANE = 128


def _use_pallas(force: Optional[bool]) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad_lane(x: jax.Array) -> jax.Array:
    pad = (-x.shape[1]) % LANE
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)))


def _exchange_kernel(slots_ref, evict_ref, fetch_ref, counts_ref,
                     capacity_ref, cache_ref, cap_acc_ref, cache_acc_ref,
                     freq_ref, capacity_out, cache_out, cap_acc_out,
                     cache_acc_out, freq_out, row_vmem, acc_vmem, frq_vmem,
                     sems):
    """Grid step i executes worklist entry i (see module docstring).

    slots/evict/fetch/counts: (N,) SMEM scalar-prefetch; capacity/(R, D),
    cache/(C, D), cap_acc/(R, 1), cache_acc/(C, 1), freq/(C, 1) all HBM and
    io-aliased in->out; row_vmem: (1, D); acc_vmem/frq_vmem: (1, 1)."""
    i = pl.program_id(0)
    s = slots_ref[i]
    ev = evict_ref[i]
    ft = fetch_ref[i]

    @pl.when((s >= 0) & (ev >= 0))
    def _writeback():
        cp_r = pltpu.make_async_copy(cache_ref.at[pl.ds(s, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(cache_acc_ref.at[pl.ds(s, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()
        cp_wr = pltpu.make_async_copy(row_vmem, capacity_out.at[pl.ds(ev, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, cap_acc_out.at[pl.ds(ev, 1)],
                                      sems.at[1])
        cp_wr.start()
        cp_wa.start()
        cp_wr.wait()
        cp_wa.wait()

    @pl.when((s >= 0) & (ft >= 0))
    def _fetch():
        cp_r = pltpu.make_async_copy(capacity_ref.at[pl.ds(ft, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(cap_acc_ref.at[pl.ds(ft, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()
        frq_vmem[...] = jnp.full((1, 1), counts_ref[i], jnp.float32)
        cp_wr = pltpu.make_async_copy(row_vmem, cache_out.at[pl.ds(s, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, cache_acc_out.at[pl.ds(s, 1)],
                                      sems.at[1])
        cp_wf = pltpu.make_async_copy(frq_vmem, freq_out.at[pl.ds(s, 1)],
                                      sems.at[2])
        cp_wr.start()
        cp_wa.start()
        cp_wf.start()
        cp_wr.wait()
        cp_wa.wait()
        cp_wf.wait()


# only the (·, D) payloads are donated: the 1-D accum/freq args are
# reshaped to (·, 1) before the pallas_call, so their input buffers cannot
# alias the outputs anyway (and they are 64x smaller than the payload)
@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0, 1))
def cache_exchange_kernel(capacity: jax.Array, cache: jax.Array,
                          cap_accum: jax.Array, cache_accum: jax.Array,
                          freq: jax.Array, slots: jax.Array,
                          evict_rows: jax.Array, fetch_rows: jax.Array,
                          counts: jax.Array, interpret: bool = False):
    """capacity: (R, D), cache: (C, D) with D % 128 == 0; cap_accum: (R, 1),
    cache_accum: (C, 1), freq: (C, 1) fp32; worklist slots/evict_rows/
    fetch_rows: (N,) int32 (-1 = skip); counts: (N,) fp32 LFU seeds.
    Returns the five arrays updated in place (io aliasing)."""
    r, d = capacity.shape
    c = cache.shape[0]
    n = slots.shape[0]
    return pl.pallas_call(
        _exchange_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(n,),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # capacity
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cache
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cap_acc
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cache_acc
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # freq
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, d), capacity.dtype),
                MemorySpace.VMEM((1, 1), jnp.float32),
                MemorySpace.VMEM((1, 1), jnp.float32),
                SemaphoreType.DMA((3,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, d), capacity.dtype),
            jax.ShapeDtypeStruct((c, d), cache.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1, 6: 2, 7: 3, 8: 4},
        interpret=interpret,
    )(slots, evict_rows, fetch_rows, counts, capacity, cache,
      cap_accum.reshape(r, 1).astype(jnp.float32),
      cache_accum.reshape(c, 1).astype(jnp.float32),
      freq.reshape(c, 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# public wrappers (kernel on TPU / interpret, jnp oracle on CPU)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
def _exchange_ref_jit(capacity, cache, cap_accum, cache_accum, freq,
                      slots, evict_rows, fetch_rows, counts):
    return ref.cache_exchange_ref(capacity, cache, cap_accum, cache_accum,
                                  freq, slots, evict_rows, fetch_rows, counts)


def cache_exchange(capacity: jax.Array, cache: jax.Array,
                   cap_accum: jax.Array, cache_accum: jax.Array,
                   freq: jax.Array, slots: jax.Array, evict_rows: jax.Array,
                   fetch_rows: jax.Array, counts: jax.Array,
                   use_kernel: Optional[bool] = None,
                   interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                              jax.Array]:
    """Batched eviction-writeback + fetch-on-miss between the capacity tier
    and the device cache. See cache_exchange_kernel / ref.cache_exchange_ref
    for the worklist contract. Returns (capacity', cache', cap_accum',
    cache_accum', freq').

    ALL FIVE ARRAYS ARE DONATED: the swap must update a few rows in place,
    not move the whole capacity tier through memory — callers (core/cache.py
    owns its buffers, see init_state) must use the returned arrays."""
    slots = slots.astype(jnp.int32)
    evict_rows = evict_rows.astype(jnp.int32)
    fetch_rows = fetch_rows.astype(jnp.int32)
    counts = counts.astype(jnp.float32)
    if _use_pallas(use_kernel) or interpret:
        d = capacity.shape[1]
        new_cap, new_cache, new_ca, new_cc, new_f = cache_exchange_kernel(
            _pad_lane(capacity), _pad_lane(cache), cap_accum, cache_accum,
            freq, slots, evict_rows, fetch_rows, counts, interpret=interpret)
        return (new_cap[:, :d], new_cache[:, :d], new_ca[:, 0], new_cc[:, 0],
                new_f[:, 0])
    return _exchange_ref_jit(capacity, cache, cap_accum, cache_accum,
                             freq, slots, evict_rows, fetch_rows, counts)


@functools.partial(jax.jit, static_argnames=("decay",))
def lfu_touch(freq: jax.Array, slots: jax.Array, counts: jax.Array,
              decay: float = 0.8) -> jax.Array:
    """LFU-with-decay hit accounting: freq' = decay * freq then
    freq'[slots] += counts. Dense decay + sparse scatter-add lower to
    efficient XLA on every backend, so there is one path (ref)."""
    return ref.lfu_touch_ref(freq, slots.astype(jnp.int32),
                             counts.astype(jnp.float32), decay)
