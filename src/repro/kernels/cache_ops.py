"""Pallas TPU kernel: batched capacity<->cache row exchange + LFU counters.

The cached embedding tier (core/cache.py) keeps the mega table in a slow
"capacity" tier (host-resident / pooled-HBM) and a fixed-size hot-row cache
on device. Each step the manager emits a per-slot WORKLIST: slot i may first
write its dirty victim row back to capacity (eviction-writeback) and then be
refilled from a missed capacity row (fetch-on-miss), seeding the slot's LFU
score. This kernel executes that worklist as an explicitly scheduled DMA
pipeline — the TPU analogue of the UVM/CacheEmbedding swap-in/swap-out path —
moving the embedding row AND its row-wise AdaGrad accumulator together so an
evicted row can resume training after a later re-fetch.

Grid step i = worklist entry i; `pl.when` guards skip -1 entries, so one
lowered kernel serves any hit/miss pattern. Rows ride HBM->VMEM->HBM through
a (1, D) scratch; the accumulator and LFU scalar through (1, 1) scratches.

The `cache_exchange` / `lfu_touch` wrappers dispatch: Pallas kernel on TPU
(or `interpret=True` for tests), pure-jnp oracle (kernels/ref.py) otherwise.
D is padded to the 128-lane width here; real deployments keep D lane-aligned
so the pad is a no-op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.compat import MemorySpace, SemaphoreType

LANE = 128


def _use_pallas(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad_lane(x: jax.Array) -> jax.Array:
    pad = (-x.shape[1]) % LANE
    if pad == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, pad)))


def _exchange_kernel(slots_ref, evict_ref, fetch_ref, counts_ref,
                     capacity_ref, cache_ref, cap_acc_ref, cache_acc_ref,
                     freq_ref, capacity_out, cache_out, cap_acc_out,
                     cache_acc_out, freq_out, row_vmem, acc_vmem, frq_vmem,
                     sems):
    """Grid step i executes worklist entry i (see module docstring).

    slots/evict/fetch/counts: (N,) SMEM scalar-prefetch; capacity/(R, D),
    cache/(C, D), cap_acc/(R, 1), cache_acc/(C, 1), freq/(C, 1) all HBM and
    io-aliased in->out; row_vmem: (1, D); acc_vmem/frq_vmem: (1, 1)."""
    i = pl.program_id(0)
    s = slots_ref[i]
    ev = evict_ref[i]
    ft = fetch_ref[i]

    @pl.when((s >= 0) & (ev >= 0))
    def _writeback():
        cp_r = pltpu.make_async_copy(cache_ref.at[pl.ds(s, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(cache_acc_ref.at[pl.ds(s, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()
        cp_wr = pltpu.make_async_copy(row_vmem, capacity_out.at[pl.ds(ev, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, cap_acc_out.at[pl.ds(ev, 1)],
                                      sems.at[1])
        cp_wr.start()
        cp_wa.start()
        cp_wr.wait()
        cp_wa.wait()

    @pl.when((s >= 0) & (ft >= 0))
    def _fetch():
        cp_r = pltpu.make_async_copy(capacity_ref.at[pl.ds(ft, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(cap_acc_ref.at[pl.ds(ft, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()
        frq_vmem[...] = jnp.full((1, 1), counts_ref[i], jnp.float32)
        cp_wr = pltpu.make_async_copy(row_vmem, cache_out.at[pl.ds(s, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, cache_acc_out.at[pl.ds(s, 1)],
                                      sems.at[1])
        cp_wf = pltpu.make_async_copy(frq_vmem, freq_out.at[pl.ds(s, 1)],
                                      sems.at[2])
        cp_wr.start()
        cp_wa.start()
        cp_wf.start()
        cp_wr.wait()
        cp_wa.wait()
        cp_wf.wait()


# only the (·, D) payloads are donated: the 1-D accum/freq args are
# reshaped to (·, 1) before the pallas_call, so their input buffers cannot
# alias the outputs anyway (and they are 64x smaller than the payload)
@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0, 1))
def cache_exchange_kernel(capacity: jax.Array, cache: jax.Array,
                          cap_accum: jax.Array, cache_accum: jax.Array,
                          freq: jax.Array, slots: jax.Array,
                          evict_rows: jax.Array, fetch_rows: jax.Array,
                          counts: jax.Array, interpret: bool = False):
    """capacity: (R, D), cache: (C, D) with D % 128 == 0; cap_accum: (R, 1),
    cache_accum: (C, 1), freq: (C, 1) fp32; worklist slots/evict_rows/
    fetch_rows: (N,) int32 (-1 = skip); counts: (N,) fp32 LFU seeds.
    Returns the five arrays updated in place (io aliasing)."""
    r, d = capacity.shape
    c = cache.shape[0]
    n = slots.shape[0]
    return pl.pallas_call(
        _exchange_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(n,),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # capacity
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cache
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cap_acc
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cache_acc
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # freq
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, d), capacity.dtype),
                MemorySpace.VMEM((1, 1), jnp.float32),
                MemorySpace.VMEM((1, 1), jnp.float32),
                SemaphoreType.DMA((3,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, d), capacity.dtype),
            jax.ShapeDtypeStruct((c, d), cache.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1, 6: 2, 7: 3, 8: 4},
        interpret=interpret,
    )(slots, evict_rows, fetch_rows, counts, capacity, cache,
      cap_accum.reshape(r, 1).astype(jnp.float32),
      cache_accum.reshape(c, 1).astype(jnp.float32),
      freq.reshape(c, 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# split async exchange: fetch (capacity -> shadow) / commit (shadow -> cache)
# ---------------------------------------------------------------------------
#
# The synchronous cache_exchange above blocks the step on its worklist: the
# fetch DMA sits on the critical path between batch k's update and batch
# k+1's forward. The async stream (core/cache.py AsyncCacheState) splits it:
#
#   fetch   capacity rows -> a fresh SHADOW slab. No cache/capacity output,
#           no donation — it only READS the tiers, so it runs concurrently
#           with the in-flight batch's dense compute.
#   commit  at the step boundary: dirty-victim writeback (cache -> capacity,
#           reading the POST-update cache) + shadow row -> cache slot. Only
#           device-resident row copies — the slow capacity fetch already
#           happened off the critical path.
#
# fetch + commit over one worklist == one cache_exchange (asserted in
# tests/test_cache_async.py against kernels/ref.py oracles).


def _fetch_kernel(fetch_ref, capacity_ref, cap_acc_ref, shadow_out,
                  shadow_acc_out, row_vmem, acc_vmem, sems):
    """Grid step i gathers capacity row fetch_ref[i] into shadow row i.

    fetch: (N,) SMEM scalar-prefetch (-1 = pad, zero-fills the shadow row);
    capacity: (R, D), cap_acc: (R, 1) HBM read-only; shadow_out: (N, D),
    shadow_acc_out: (N, 1) HBM; row_vmem: (1, D); acc_vmem: (1, 1)."""
    i = pl.program_id(0)
    ft = fetch_ref[i]

    @pl.when(ft >= 0)
    def _gather():
        cp_r = pltpu.make_async_copy(capacity_ref.at[pl.ds(ft, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(cap_acc_ref.at[pl.ds(ft, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()

    @pl.when(ft < 0)
    def _zero():
        row_vmem[...] = jnp.zeros(row_vmem.shape, row_vmem.dtype)
        acc_vmem[...] = jnp.zeros(acc_vmem.shape, acc_vmem.dtype)

    cp_wr = pltpu.make_async_copy(row_vmem, shadow_out.at[pl.ds(i, 1)],
                                  sems.at[0])
    cp_wa = pltpu.make_async_copy(acc_vmem, shadow_acc_out.at[pl.ds(i, 1)],
                                  sems.at[1])
    cp_wr.start()
    cp_wa.start()
    cp_wr.wait()
    cp_wa.wait()


# NO donation: fetch only reads the tiers — the caller's capacity array and
# the in-flight batch's cache stay live while the DMA is in flight.
@functools.partial(jax.jit, static_argnames=("interpret",))
def cache_fetch_kernel(capacity: jax.Array, cap_accum: jax.Array,
                       fetch_rows: jax.Array, interpret: bool = False):
    """capacity: (R, D) with D % 128 == 0; cap_accum: (R,) fp32;
    fetch_rows: (N,) int32 (-1 = pad). Returns (shadow (N, D),
    shadow_accum (N, 1)) — a fresh slab, the tiers are untouched."""
    r, d = capacity.shape
    n = fetch_rows.shape[0]
    return pl.pallas_call(
        _fetch_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # capacity
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cap_acc
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, d), capacity.dtype),
                MemorySpace.VMEM((1, 1), jnp.float32),
                SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n, d), capacity.dtype),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(fetch_rows, capacity, cap_accum.reshape(r, 1).astype(jnp.float32))


def _fetch_chunked_kernel(starts_ref, capacity_ref, cap_acc_ref, shadow_out,
                          shadow_acc_out, blk_vmem, acc_vmem, sems, *,
                          chunk: int):
    """Grid step k gathers the `chunk`-row capacity block at starts_ref[k]
    into shadow rows [k*chunk, (k+1)*chunk) — ONE DMA descriptor per block
    instead of one per row.

    starts: (K,) SMEM scalar-prefetch (-1 = pad, zero-fills the block);
    capacity: (R, D), cap_acc: (R, 1) HBM read-only; shadow_out:
    (K*chunk, D), shadow_acc_out: (K*chunk, 1) HBM; blk_vmem: (chunk, D);
    acc_vmem: (chunk, 1)."""
    k = pl.program_id(0)
    s = starts_ref[k]

    @pl.when(s >= 0)
    def _gather():
        cp_r = pltpu.make_async_copy(capacity_ref.at[pl.ds(s, chunk)],
                                     blk_vmem, sems.at[0])
        cp_a = pltpu.make_async_copy(cap_acc_ref.at[pl.ds(s, chunk)],
                                     acc_vmem, sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()

    @pl.when(s < 0)
    def _zero():
        blk_vmem[...] = jnp.zeros(blk_vmem.shape, blk_vmem.dtype)
        acc_vmem[...] = jnp.zeros(acc_vmem.shape, acc_vmem.dtype)

    cp_wr = pltpu.make_async_copy(
        blk_vmem, shadow_out.at[pl.ds(k * chunk, chunk)], sems.at[0])
    cp_wa = pltpu.make_async_copy(
        acc_vmem, shadow_acc_out.at[pl.ds(k * chunk, chunk)], sems.at[1])
    cp_wr.start()
    cp_wa.start()
    cp_wr.wait()
    cp_wa.wait()


# NO donation, same reason as cache_fetch_kernel: read-only on the tiers.
@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def cache_fetch_chunked_kernel(capacity: jax.Array, cap_accum: jax.Array,
                               chunk_starts: jax.Array, chunk: int,
                               interpret: bool = False):
    """capacity: (R, D) with D % 128 == 0; cap_accum: (R,) fp32;
    chunk_starts: (K,) int32 block starts, clamped so start+chunk <= R
    (-1 = pad). Returns (shadow (K*chunk, D), shadow_accum (K*chunk, 1))
    — a fresh slab, the tiers are untouched."""
    r, d = capacity.shape
    k = chunk_starts.shape[0]
    return pl.pallas_call(
        functools.partial(_fetch_chunked_kernel, chunk=chunk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # capacity
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cap_acc
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((chunk, d), capacity.dtype),
                MemorySpace.VMEM((chunk, 1), jnp.float32),
                SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((k * chunk, d), capacity.dtype),
            jax.ShapeDtypeStruct((k * chunk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(chunk_starts, capacity, cap_accum.reshape(r, 1).astype(jnp.float32))


def _commit_kernel(slots_ref, evict_ref, fetch_ref, src_pos_ref, shadow_ref,
                   shadow_acc_ref, capacity_ref, cache_ref, cap_acc_ref,
                   cache_acc_ref, capacity_out, cache_out, cap_acc_out,
                   cache_acc_out, row_vmem, acc_vmem, sems):
    """Grid step i installs shadow row src_pos_ref[i] into cache slot
    slots_ref[i], writing the slot's dirty victim back to capacity row
    evict_ref[i] first.

    slots/evict/fetch/src_pos: (N,) SMEM scalar-prefetch (-1 = skip; fetch
    gates the install — pure-writeback entries keep the slot; src_pos is
    arange(N) for a one-row-per-entry shadow or the coalescer's `pos` for a
    chunk-granular slab); shadow: (M, D), shadow_acc: (M, 1) HBM read-only
    with M >= N; capacity/(R, D), cache/(C, D), cap_acc/(R, 1),
    cache_acc/(C, 1) HBM io-aliased in->out."""
    i = pl.program_id(0)
    s = slots_ref[i]
    ev = evict_ref[i]
    ft = fetch_ref[i]
    sp = src_pos_ref[i]

    @pl.when((s >= 0) & (ev >= 0))
    def _writeback():
        cp_r = pltpu.make_async_copy(cache_ref.at[pl.ds(s, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(cache_acc_ref.at[pl.ds(s, 1)], acc_vmem,
                                     sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()
        cp_wr = pltpu.make_async_copy(row_vmem, capacity_out.at[pl.ds(ev, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, cap_acc_out.at[pl.ds(ev, 1)],
                                      sems.at[1])
        cp_wr.start()
        cp_wa.start()
        cp_wr.wait()
        cp_wa.wait()

    @pl.when((s >= 0) & (ft >= 0))
    def _install():
        cp_r = pltpu.make_async_copy(shadow_ref.at[pl.ds(sp, 1)], row_vmem,
                                     sems.at[0])
        cp_a = pltpu.make_async_copy(shadow_acc_ref.at[pl.ds(sp, 1)],
                                     acc_vmem, sems.at[1])
        cp_r.start()
        cp_a.start()
        cp_r.wait()
        cp_a.wait()
        cp_wr = pltpu.make_async_copy(row_vmem, cache_out.at[pl.ds(s, 1)],
                                      sems.at[0])
        cp_wa = pltpu.make_async_copy(acc_vmem, cache_acc_out.at[pl.ds(s, 1)],
                                      sems.at[1])
        cp_wr.start()
        cp_wa.start()
        cp_wr.wait()
        cp_wa.wait()


# the four tier arrays are donated/io-aliased (in-place row swap); the
# shadow slab is consumed by this call but NOT aliased (different height)
@functools.partial(jax.jit, static_argnames=("interpret",),
                   donate_argnums=(0, 1))
def cache_commit_kernel(capacity: jax.Array, cache: jax.Array,
                        cap_accum: jax.Array, cache_accum: jax.Array,
                        shadow: jax.Array, shadow_accum: jax.Array,
                        slots: jax.Array, evict_rows: jax.Array,
                        fetch_rows: jax.Array, src_pos: jax.Array,
                        interpret: bool = False):
    """capacity: (R, D), cache: (C, D), shadow: (M, D) with D % 128 == 0 and
    M >= N; cap_accum: (R, 1), cache_accum: (C, 1), shadow_accum: (M, 1)
    fp32; slots/evict_rows/fetch_rows/src_pos: (N,) int32 (-1 = skip; fetch
    gates the shadow install, which reads shadow row src_pos[i]). Returns
    (capacity', cache', cap_accum', cache_accum') updated in place
    (io aliasing)."""
    r, d = capacity.shape
    c = cache.shape[0]
    n = slots.shape[0]
    m = shadow.shape[0]
    return pl.pallas_call(
        _commit_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(n,),
            in_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # shadow
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # shadow_acc
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # capacity
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cache
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cap_acc
                pl.BlockSpec(memory_space=MemorySpace.ANY),  # cache_acc
            ],
            out_specs=[
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
                pl.BlockSpec(memory_space=MemorySpace.ANY),
            ],
            scratch_shapes=[
                MemorySpace.VMEM((1, d), capacity.dtype),
                MemorySpace.VMEM((1, 1), jnp.float32),
                SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((r, d), capacity.dtype),
            jax.ShapeDtypeStruct((c, d), cache.dtype),
            jax.ShapeDtypeStruct((r, 1), jnp.float32),
            jax.ShapeDtypeStruct((c, 1), jnp.float32),
        ],
        input_output_aliases={6: 0, 7: 1, 8: 2, 9: 3},
        interpret=interpret,
    )(slots, evict_rows, fetch_rows, src_pos, shadow,
      shadow_accum.reshape(m, 1), capacity, cache,
      cap_accum.reshape(r, 1).astype(jnp.float32),
      cache_accum.reshape(c, 1).astype(jnp.float32))


# ---------------------------------------------------------------------------
# public wrappers (kernel on TPU / interpret, jnp oracle on CPU)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
def _exchange_ref_jit(capacity, cache, cap_accum, cache_accum, freq,
                      slots, evict_rows, fetch_rows, counts):
    return ref.cache_exchange_ref(capacity, cache, cap_accum, cache_accum,
                                  freq, slots, evict_rows, fetch_rows, counts)


def cache_exchange(capacity: jax.Array, cache: jax.Array,
                   cap_accum: jax.Array, cache_accum: jax.Array,
                   freq: jax.Array, slots: jax.Array, evict_rows: jax.Array,
                   fetch_rows: jax.Array, counts: jax.Array,
                   use_kernel: bool | None = None,
                   interpret: bool = False
                   ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                              jax.Array]:
    """Batched eviction-writeback + fetch-on-miss between the capacity tier
    and the device cache. See cache_exchange_kernel / ref.cache_exchange_ref
    for the worklist contract. Returns (capacity', cache', cap_accum',
    cache_accum', freq').

    ALL FIVE ARRAYS ARE DONATED: the swap must update a few rows in place,
    not move the whole capacity tier through memory — callers (core/cache.py
    owns its buffers, see init_state) must use the returned arrays."""
    slots = slots.astype(jnp.int32)
    evict_rows = evict_rows.astype(jnp.int32)
    fetch_rows = fetch_rows.astype(jnp.int32)
    counts = counts.astype(jnp.float32)
    if _use_pallas(use_kernel) or interpret:
        d = capacity.shape[1]
        new_cap, new_cache, new_ca, new_cc, new_f = cache_exchange_kernel(
            _pad_lane(capacity), _pad_lane(cache), cap_accum, cache_accum,
            freq, slots, evict_rows, fetch_rows, counts, interpret=interpret)
        return (new_cap[:, :d], new_cache[:, :d], new_ca[:, 0], new_cc[:, 0],
                new_f[:, 0])
    return _exchange_ref_jit(capacity, cache, cap_accum, cache_accum,
                             freq, slots, evict_rows, fetch_rows, counts)


@functools.partial(jax.jit)
def _fetch_ref_jit(capacity, cap_accum, fetch_rows):
    return ref.cache_fetch_ref(capacity, cap_accum, fetch_rows)


def cache_fetch(capacity: jax.Array, cap_accum: jax.Array,
                fetch_rows: jax.Array, use_kernel: bool | None = None,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """FETCH half of the split async exchange: gather `fetch_rows` (+ their
    accumulators) from the capacity tier into a fresh shadow slab. Read-only
    on the tiers (nothing donated) so it overlaps the in-flight batch's
    compute. Returns (shadow (N, D), shadow_accum (N,)).

    The Pallas path requires D % 128 == 0; an unaligned D would force a
    full O(R x D') pad-copy of the capacity tier EVERY call (the fetch
    cannot donate, unlike the exchange), so unless `interpret` explicitly
    asks for the kernel, unaligned tables take the jnp gather — a cheap
    XLA dynamic-gather that keeps the fetch off the critical path."""
    fetch_rows = fetch_rows.astype(jnp.int32)
    d = capacity.shape[1]
    if (_use_pallas(use_kernel) and d % LANE == 0) or interpret:
        shadow, shadow_acc = cache_fetch_kernel(
            _pad_lane(capacity), cap_accum, fetch_rows, interpret=interpret)
        return shadow[:, :d], shadow_acc[:, 0]
    return _fetch_ref_jit(capacity, cap_accum, fetch_rows)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _fetch_chunked_ref_jit(capacity, cap_accum, chunk_starts, chunk):
    return ref.cache_fetch_chunked_ref(capacity, cap_accum, chunk_starts,
                                       chunk)


def cache_fetch_chunked(capacity: jax.Array, cap_accum: jax.Array,
                        chunk_starts: jax.Array, chunk: int,
                        use_kernel: bool | None = None,
                        interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array]:
    """CHUNK-granular fetch: gather K contiguous `chunk`-row capacity blocks
    (+ accumulators) into one (K*chunk, D) shadow slab — one DMA descriptor
    per BLOCK. `chunk_starts` comes from kernels/sparse_plan.coalesce_rows
    (starts clamped so start+chunk <= R; -1 = pad, zero block). Read-only on
    the tiers, same overlap contract as `cache_fetch`. Pair with
    `cache_commit(..., src_pos=pos)` to install individual rows out of the
    block slab. Returns (shadow (K*chunk, D), shadow_accum (K*chunk,))."""
    chunk_starts = chunk_starts.astype(jnp.int32)
    d = capacity.shape[1]
    if (_use_pallas(use_kernel) and d % LANE == 0) or interpret:
        shadow, shadow_acc = cache_fetch_chunked_kernel(
            _pad_lane(capacity), cap_accum, chunk_starts, chunk,
            interpret=interpret)
        return shadow[:, :d], shadow_acc[:, 0]
    return _fetch_chunked_ref_jit(capacity, cap_accum, chunk_starts, chunk)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _commit_ref_jit(capacity, cache, cap_accum, cache_accum, shadow,
                    shadow_accum, slots, evict_rows, fetch_rows, src_pos):
    return ref.cache_commit_ref(capacity, cache, cap_accum, cache_accum,
                                shadow, shadow_accum, slots, evict_rows,
                                fetch_rows, src_pos)


def cache_commit(capacity: jax.Array, cache: jax.Array, cap_accum: jax.Array,
                 cache_accum: jax.Array, shadow: jax.Array,
                 shadow_accum: jax.Array, slots: jax.Array,
                 evict_rows: jax.Array, fetch_rows: jax.Array,
                 use_kernel: bool | None = None,
                 interpret: bool = False,
                 src_pos: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """COMMIT half of the split async exchange: dirty-victim writeback
    (cache slot -> capacity row, reading the post-update cache) + shadow row
    -> cache slot install, at a step boundary. `fetch_rows` is the worklist
    the shadow slab was fetched with; -1 entries gate the install off
    (pure writeback). `src_pos` maps worklist entry i to its shadow row
    (default arange(n), the one-row-per-entry slab; pass the coalescer's
    `pos` for a chunk-granular slab). The four tier arrays are DONATED
    (in-place row swap, same contract as cache_exchange) — callers must use
    the returned arrays. Returns (capacity', cache', cap_accum',
    cache_accum')."""
    slots = slots.astype(jnp.int32)
    evict_rows = evict_rows.astype(jnp.int32)
    fetch_rows = fetch_rows.astype(jnp.int32)
    n = slots.shape[0]
    if src_pos is None:
        src_pos = jnp.arange(n, dtype=jnp.int32)
    else:
        src_pos = src_pos.astype(jnp.int32)
    if _use_pallas(use_kernel) or interpret:
        d = capacity.shape[1]
        new_cap, new_cache, new_ca, new_cc = cache_commit_kernel(
            _pad_lane(capacity), _pad_lane(cache), cap_accum, cache_accum,
            _pad_lane(shadow), shadow_accum, slots, evict_rows, fetch_rows,
            src_pos, interpret=interpret)
        return new_cap[:, :d], new_cache[:, :d], new_ca[:, 0], new_cc[:, 0]
    return _commit_ref_jit(capacity, cache, cap_accum, cache_accum,
                           shadow, shadow_accum, slots, evict_rows,
                           fetch_rows, src_pos)


@functools.partial(jax.jit, static_argnames=("decay",))
def lfu_touch(freq: jax.Array, slots: jax.Array, counts: jax.Array,
              decay: float = 0.8) -> jax.Array:
    """LFU-with-decay hit accounting: freq' = decay * freq then
    freq'[slots] += counts. Dense decay + sparse scatter-add lower to
    efficient XLA on every backend, so there is one path (ref)."""
    return ref.lfu_touch_ref(freq, slots.astype(jnp.int32),
                             counts.astype(jnp.float32), decay)
