"""Pure-jnp oracles for every kernel in this package.

These are the correctness references (tests assert_allclose kernels against
them) AND the CPU fallback path used when running the full system without a
TPU. They are written for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(table: jax.Array, indices: jax.Array,
                      mode: str = "sum") -> jax.Array:
    """Multi-hot embedding lookup + pooling.

    table: (H, D); indices: (B, L) int32, -1 = padding slot.
    Returns (B, D) pooled embeddings (sum or mean over valid slots).
    """
    valid = indices >= 0
    rows = table[jnp.maximum(indices, 0)]                    # (B, L, D)
    rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
    out = rows.sum(axis=1)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    return out.astype(table.dtype)


def dedup_embedding_bag_ref(table: jax.Array, indices: jax.Array,
                            unique_rows: jax.Array,
                            mode: str = "sum") -> jax.Array:
    """Plan-shared dedup'd forward (docs/embedding_forward.md), pure jnp —
    BIT-EXACT vs `embedding_bag_ref` on the same (table, indices) whenever
    `unique_rows` covers every valid index (the planner contract): the
    (H, D) table is gathered ONCE per plan entry (U rows, not B*L), each
    lookup slot then reads its row from that compact buffer through an
    index-only searchsorted remap, and the masked pooling that follows is
    the SAME expression as the legacy oracle — identical float values
    through an identical reduction (asserted in tests/test_dedup_forward.py).

    table: (H, D); indices: (B, L) int32, -1 = padding; unique_rows: (U,)
    the plan's unique rows, live prefix strictly ascending, -1 past the
    unique count. Returns (B, D).
    """
    sent = jnp.where(unique_rows >= 0, unique_rows,
                     jnp.iinfo(jnp.int32).max)        # -1 tail sorts last
    compact = table[jnp.maximum(unique_rows, 0)]      # the ONLY table gather
    valid = indices >= 0
    pos = jnp.searchsorted(sent, jnp.maximum(indices, 0).reshape(-1))
    rows = compact[pos].reshape(*indices.shape, -1)   # (B, L, D)
    rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
    out = rows.sum(axis=1)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    return out.astype(table.dtype)


def dot_interaction_ref(z: jax.Array) -> jax.Array:
    """Pairwise dot-product feature interaction (paper section III-A.3).

    z: (B, F, D) stacked feature vectors (dense projection + pooled EMBs).
    Returns (B, F*(F-1)//2): strictly-lower-triangle of z @ z^T per example.
    """
    f = z.shape[1]
    s = jnp.einsum("bfd,bgd->bfg", z.astype(jnp.float32),
                   z.astype(jnp.float32))
    rows, cols = np.tril_indices(f, -1)
    return s[:, rows, cols].astype(z.dtype)


def rowwise_adagrad_ref(table: jax.Array, accum: jax.Array,
                        indices: jax.Array, grads: jax.Array,
                        lr: float, eps: float = 1e-8):
    """Deduplicating sparse row-wise AdaGrad (the paper's 'gradient
    aggregation' step).

    table: (H, D); accum: (H,) row-wise second-moment; indices: (N,) int32
    (-1 = padding); grads: (N, D) per-lookup gradients.

    Duplicate rows are aggregated FIRST, then a single update is applied —
    matching a synchronous dedup (not HogWild's racy per-duplicate applies).
    Returns (new_table, new_accum).
    """
    h, d = table.shape
    valid = indices >= 0
    idx = jnp.where(valid, indices, h)                       # h = sentinel
    gsum = jnp.zeros((h + 1, d), jnp.float32).at[idx].add(
        jnp.where(valid[:, None], grads.astype(jnp.float32), 0.0))[:h]
    touched = jnp.zeros((h + 1,), bool).at[idx].set(valid)[:h]
    g2 = jnp.mean(jnp.square(gsum), axis=-1)                 # (H,)
    new_accum = accum + jnp.where(touched, g2, 0.0)
    upd = lr * gsum * jax.lax.rsqrt(new_accum[:, None] + eps)
    new_table = table - jnp.where(touched[:, None], upd, 0.0
                                  ).astype(table.dtype)
    return new_table.astype(table.dtype), new_accum


def dedup_grads_ref(indices: jax.Array, grads: jax.Array, num_rows: int):
    """Aggregate per-lookup grads into unique-row grads — O(n log n) in the
    number of LOOKUPS (sort + run-length segment sum), independent of the
    table height (the paper's flat CPU hash-size curve, Fig. 12, depends on
    exactly this property).

    Returns (unique_idx (N,), summed_grads (N, D)): each unique row appears
    once (at its run head in sorted order); all other slots are -1 / zeros —
    the layout the rowwise_adagrad kernel consumes (it skips -1).
    """
    n, d = grads.shape
    valid = indices >= 0
    safe = jnp.where(valid, indices, num_rows)               # pads sort last
    order = jnp.argsort(safe)
    s_idx = safe[order]
    s_g = jnp.where(valid[order][:, None], grads[order].astype(jnp.float32),
                    0.0)
    is_head = jnp.concatenate(
        [jnp.ones((1,), bool), s_idx[1:] != s_idx[:-1]])
    seg = jnp.cumsum(is_head) - 1                            # run id per slot
    gsum_by_run = jax.ops.segment_sum(s_g, seg, num_segments=n)
    s_valid = s_idx < num_rows
    uniq = jnp.where(is_head & s_valid, s_idx, -1).astype(jnp.int32)
    gsum = jnp.where((is_head & s_valid)[:, None], gsum_by_run[seg], 0.0)
    return uniq, gsum


def bag_grad_sums(unique_rows: jax.Array, bag_offsets: jax.Array,
                  bag_ids: jax.Array, pooled: jax.Array) -> jax.Array:
    """Aggregate POOLED bag gradients into per-unique-row sums through a
    `SparsePlan` (kernels/sparse_plan.py) — the index-only replacement for
    broadcast-then-dedup: nothing `(B*F*L, D)`-shaped is built before this
    gather, and XLA fuses the gather into the segment sum.

    unique_rows: (U,); bag_offsets: (U+1,); bag_ids: (N,) — U may be
    smaller than N for a capacity-trimmed plan; pooled: (B*F, D) fp32.
    Returns (U, D) fp32 `gsum` aligned with `unique_rows` (zeros past the
    unique count). Slots within a run arrive in flat-batch order (the
    planner's stable sort), so each row's accumulation order — and hence
    its bits — matches the legacy per-lookup scatter-add.
    """
    n = bag_ids.shape[0]
    u = bag_offsets.shape[0] - 1                    # plan's unique capacity
    n_valid = bag_offsets[u]                        # planner fills tail
    pos = jnp.arange(n)
    # run id per sorted slot, O(n): count the run starts at or before each
    # position (phantom runs all "start" at n_valid, inflating only the
    # dead tail, which is routed to the dropped segment below)
    marks = jnp.zeros((n + 1,), jnp.int32).at[bag_offsets[1:]].add(1)
    seg = jnp.cumsum(marks[:n])
    seg = jnp.where(pos < n_valid, seg, u)          # u = dropped
    contrib = pooled[bag_ids].astype(jnp.float32)   # dead slots drop via seg
    return jax.ops.segment_sum(contrib, seg, num_segments=u + 1)[:u]


def fused_bag_backward_adagrad_ref(table: jax.Array, accum: jax.Array,
                                   unique_rows: jax.Array,
                                   bag_offsets: jax.Array,
                                   bag_ids: jax.Array, pooled: jax.Array,
                                   lr, eps: float = 1e-8):
    """Oracle for the fused sparse backward (kernels/sparse_update.py):
    gather + aggregate pooled bag grads per unique row, then the row-wise
    AdaGrad apply — one pass, no per-lookup gradient tensor.

    table: (H, D); accum: (H,) fp32; plan arrays as in `SparsePlan`;
    pooled: (B*F, D). Bit-identical to `rowwise_adagrad_ref` fed the legacy
    broadcast per-lookup layout (asserted in tests/test_sparse_fused.py).
    Returns (new_table, new_accum).
    """
    h, _ = table.shape
    gsum = bag_grad_sums(unique_rows, bag_offsets, bag_ids, pooled)
    valid = unique_rows >= 0
    safe = jnp.where(valid, unique_rows, 0)
    drop = jnp.where(valid, unique_rows, h)          # h = dropped
    g2 = jnp.mean(jnp.square(gsum), axis=-1)
    acc_rows = accum[safe] + g2
    upd = lr * gsum * jax.lax.rsqrt(acc_rows[:, None] + eps)
    # invalid entries need no masking: their scatter index is h -> dropped
    new_table = table.at[drop].add(-upd.astype(table.dtype), mode="drop")
    new_accum = accum.at[drop].set(acc_rows, mode="drop")
    return new_table.astype(table.dtype), new_accum


def bag_grad_sums_abs(bag_offsets: jax.Array, bag_ids: jax.Array,
                      pooled: jax.Array) -> jax.Array:
    """`bag_grad_sums` for a SEGMENT whose offsets are ABSOLUTE positions
    into the shared `bag_ids` (a contiguous per-owner slice of a plan,
    `kernels.sparse_plan.split_plan_by_owner`): pairs before bag_offsets[0]
    or at/after bag_offsets[U] belong to other owners and drop; padded rows
    are empty runs (their offsets equal the segment end). Accumulation per
    run stays in ascending pair position — flat-batch order — so each row's
    sum is bit-identical to the unsegmented `bag_grad_sums`'s."""
    n = bag_ids.shape[0]
    u = bag_offsets.shape[0] - 1
    pos = jnp.arange(n)
    # run id per pair: offsets are nondecreasing, so the count of offsets
    # <= pos names the run even across empty (padded) runs
    seg = jnp.searchsorted(bag_offsets, pos, side="right") - 1
    in_seg = (pos >= bag_offsets[0]) & (pos < bag_offsets[u])
    seg = jnp.where(in_seg, jnp.clip(seg, 0, u - 1), u)  # u = dropped
    contrib = pooled[bag_ids].astype(jnp.float32)
    return jax.ops.segment_sum(contrib, seg, num_segments=u + 1)[:u]


def fused_bag_backward_adagrad_abs_ref(table: jax.Array, accum: jax.Array,
                                       unique_rows: jax.Array,
                                       bag_offsets: jax.Array,
                                       bag_ids: jax.Array,
                                       pooled: jax.Array,
                                       lr, eps: float = 1e-8):
    """`fused_bag_backward_adagrad_ref` over a segment plan with ABSOLUTE
    offsets (see `bag_grad_sums_abs`) — the jnp oracle behind the per-owner
    segmented update of the multi-host cached tier (docs/cache.md). Rows
    the segment doesn't cover are untouched; covered rows update with the
    exact unsegmented bits."""
    h, _ = table.shape
    gsum = bag_grad_sums_abs(bag_offsets, bag_ids, pooled)
    valid = unique_rows >= 0
    safe = jnp.where(valid, unique_rows, 0)
    drop = jnp.where(valid, unique_rows, h)          # h = dropped
    g2 = jnp.mean(jnp.square(gsum), axis=-1)
    acc_rows = accum[safe] + g2
    upd = lr * gsum * jax.lax.rsqrt(acc_rows[:, None] + eps)
    new_table = table.at[drop].add(-upd.astype(table.dtype), mode="drop")
    new_accum = accum.at[drop].set(acc_rows, mode="drop")
    return new_table.astype(table.dtype), new_accum


def cache_exchange_ref(capacity: jax.Array, cache: jax.Array,
                       cap_accum: jax.Array, cache_accum: jax.Array,
                       freq: jax.Array, slots: jax.Array,
                       evict_rows: jax.Array, fetch_rows: jax.Array,
                       counts: jax.Array):
    """Oracle for the cache_exchange kernel (cache_ops.py): one batched
    swap between the capacity tier and the device cache.

    capacity: (R, D) slow tier; cache: (C, D) device tier; cap_accum: (R,)
    and cache_accum: (C,) row-wise AdaGrad accumulators riding along;
    freq: (C,) LFU scores. The worklist is per-slot: entry i touches cache
    slot slots[i] (-1 = no-op pad) and
      * writes the slot back to capacity row evict_rows[i] if >= 0
        (dirty-victim writeback), then
      * fills it from capacity row fetch_rows[i] if >= 0 (fetch-on-miss),
        seeding its LFU score with counts[i].
    Worklist slots are distinct and evict/fetch row sets are disjoint
    (the manager's working-set protection guarantees this), so entry
    order does not matter. Returns all five arrays updated.
    """
    r = capacity.shape[0]
    c = cache.shape[0]
    safe_slot = jnp.where(slots >= 0, slots, 0)
    # 1) dirty-victim writeback: cache -> capacity
    wb = jnp.where(evict_rows >= 0, evict_rows, r)          # r drops
    capacity = capacity.at[wb].set(cache[safe_slot], mode="drop")
    cap_accum = cap_accum.at[wb].set(cache_accum[safe_slot], mode="drop")
    # 2) fetch-on-miss: capacity -> cache (+ seed the slot's LFU counter)
    take = jnp.where(fetch_rows >= 0, fetch_rows, 0)
    dst = jnp.where((fetch_rows >= 0) & (slots >= 0), slots, c)  # c drops
    cache = cache.at[dst].set(capacity[take], mode="drop")
    cache_accum = cache_accum.at[dst].set(cap_accum[take], mode="drop")
    freq = freq.at[dst].set(counts.astype(freq.dtype), mode="drop")
    return capacity, cache, cap_accum, cache_accum, freq


def cache_fetch_ref(capacity: jax.Array, cap_accum: jax.Array,
                    fetch_rows: jax.Array):
    """Oracle for the FETCH half of the split async exchange
    (cache_ops.cache_fetch): gather `fetch_rows` (+ their row-wise AdaGrad
    accumulators) from the capacity tier into a fresh SHADOW slab, without
    touching the device cache. -1 entries produce zero rows (padding).

    capacity: (R, D); cap_accum: (R,). Returns (shadow (N, D),
    shadow_accum (N,)). The shadow slab is what the async stream fills
    while the in-flight batch's dense compute runs — see core/cache.py.
    """
    valid = fetch_rows >= 0
    take = jnp.where(valid, fetch_rows, 0)
    shadow = jnp.where(valid[:, None], capacity[take].astype(jnp.float32),
                       0.0).astype(capacity.dtype)
    shadow_accum = jnp.where(valid, cap_accum[take], 0.0)
    return shadow, shadow_accum


def cache_fetch_chunked_ref(capacity: jax.Array, cap_accum: jax.Array,
                            chunk_starts: jax.Array, chunk: int):
    """Oracle for the CHUNK-granular fetch (cache_ops.cache_fetch_chunked).

    Gathers K contiguous row blocks of height `chunk` from the capacity
    tier into one (K*chunk, D) shadow slab — one DMA descriptor per block
    instead of one per row. chunk_starts: (K,) block start rows, already
    clamped so start+chunk <= R (kernels/sparse_plan.coalesce_rows); -1
    entries produce zero blocks (padding). Individual rows are addressed
    inside the slab as k*chunk + (row - chunk_starts[k]) — the `pos` array
    the coalescer returns. Returns (shadow (K*chunk, D),
    shadow_accum (K*chunk,)).
    """
    valid = chunk_starts >= 0
    base = jnp.where(valid, chunk_starts, 0)                  # (K,)
    rows = base[:, None] + jnp.arange(chunk)[None, :]         # (K, chunk)
    rows = rows.reshape(-1)
    keep = jnp.repeat(valid, chunk)
    shadow = jnp.where(keep[:, None], capacity[rows].astype(jnp.float32),
                       0.0).astype(capacity.dtype)
    shadow_accum = jnp.where(keep, cap_accum[rows], 0.0)
    return shadow, shadow_accum


def cache_commit_ref(capacity: jax.Array, cache: jax.Array,
                     cap_accum: jax.Array, cache_accum: jax.Array,
                     shadow: jax.Array, shadow_accum: jax.Array,
                     slots: jax.Array, evict_rows: jax.Array,
                     fetch_rows: jax.Array,
                     src_pos: jax.Array | None = None):
    """Oracle for the COMMIT half of the split async exchange
    (cache_ops.cache_commit): install a previously fetched shadow slab into
    the device cache at a step boundary. Entry i
      * writes cache slot slots[i] (post-update dirty victim) back to
        capacity row evict_rows[i] if >= 0, then
      * overwrites the slot with shadow row src_pos[i] (+ accumulator) if
        fetch_rows[i] >= 0 (pure-writeback entries pass -1 and keep the
        slot's contents). src_pos defaults to arange(n) — the classic
        one-row-per-entry shadow; a chunk-granular fetch passes the
        coalescer's `pos` so entry i reads its row out of the block slab.
    slots[i] < 0 skips the entry. Worklist slots are distinct and the
    evict-row set is disjoint from the fetched rows (the manager's
    working-set protection guarantees both), so entry order does not
    matter. fetch(fetch_rows) + commit over the same worklist is equivalent
    to one cache_exchange_ref call (modulo the LFU seed, which the async
    manager keeps on the host). Returns the four arrays updated.
    """
    r = capacity.shape[0]
    c = cache.shape[0]
    n = slots.shape[0]
    if src_pos is None:
        src_pos = jnp.arange(n)
    safe_slot = jnp.where(slots >= 0, slots, 0)
    wb = jnp.where((slots >= 0) & (evict_rows >= 0), evict_rows, r)  # r drops
    capacity = capacity.at[wb].set(cache[safe_slot], mode="drop")
    cap_accum = cap_accum.at[wb].set(cache_accum[safe_slot], mode="drop")
    dst = jnp.where((slots >= 0) & (fetch_rows >= 0), slots, c)      # c drops
    cache = cache.at[dst].set(shadow[src_pos].astype(cache.dtype),
                              mode="drop")
    cache_accum = cache_accum.at[dst].set(shadow_accum[src_pos], mode="drop")
    return capacity, cache, cap_accum, cache_accum


def lfu_touch_ref(freq: jax.Array, slots: jax.Array, counts: jax.Array,
                  decay: float) -> jax.Array:
    """Decay-then-bump LFU counter update: freq' = decay * freq, then
    freq'[slots[i]] += counts[i] for every valid (>= 0) slot. Dense decay +
    sparse scatter-add — the frequency half of the paper's observation that
    access skew, not table size, decides cacheability (Fig. 6/7)."""
    c = freq.shape[0]
    dst = jnp.where(slots >= 0, slots, c)                   # c drops
    return (freq * decay).at[dst].add(counts.astype(freq.dtype),
                                      mode="drop")


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Oracle for the flash_attention kernel. q,k,v: (b, h, s, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32))
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = np.arange(sk)[None, :] > np.arange(sq)[:, None]
        s = jnp.where(jnp.asarray(mask)[None, None], -1e30, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
