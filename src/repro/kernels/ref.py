"""Pure-jnp oracles for every kernel in this package.

These are the correctness references (tests assert_allclose kernels against
them) AND the CPU fallback path used when running the full system without a
TPU. They are written for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(table: jax.Array, indices: jax.Array,
                      mode: str = "sum") -> jax.Array:
    """Multi-hot embedding lookup + pooling.

    table: (H, D); indices: (B, L) int32, -1 = padding slot.
    Returns (B, D) pooled embeddings (sum or mean over valid slots).
    """
    valid = indices >= 0
    rows = table[jnp.maximum(indices, 0)]                    # (B, L, D)
    rows = jnp.where(valid[..., None], rows.astype(jnp.float32), 0.0)
    out = rows.sum(axis=1)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1)
        out = out / cnt
    return out.astype(table.dtype)


def dot_interaction_ref(z: jax.Array) -> jax.Array:
    """Pairwise dot-product feature interaction (paper section III-A.3).

    z: (B, F, D) stacked feature vectors (dense projection + pooled EMBs).
    Returns (B, F*(F-1)//2): strictly-lower-triangle of z @ z^T per example.
    """
    f = z.shape[1]
    s = jnp.einsum("bfd,bgd->bfg", z.astype(jnp.float32),
                   z.astype(jnp.float32))
    rows, cols = np.tril_indices(f, -1)
    return s[:, rows, cols].astype(z.dtype)


def rowwise_adagrad_ref(table: jax.Array, accum: jax.Array,
                        indices: jax.Array, grads: jax.Array,
                        lr: float, eps: float = 1e-8):
    """Deduplicating sparse row-wise AdaGrad (the paper's 'gradient
    aggregation' step).

    table: (H, D); accum: (H,) row-wise second-moment; indices: (N,) int32
    (-1 = padding); grads: (N, D) per-lookup gradients.

    Duplicate rows are aggregated FIRST, then a single update is applied —
    matching a synchronous dedup (not HogWild's racy per-duplicate applies).
    Returns (new_table, new_accum).
    """
    h, d = table.shape
    valid = indices >= 0
    idx = jnp.where(valid, indices, h)                       # h = sentinel
    gsum = jnp.zeros((h + 1, d), jnp.float32).at[idx].add(
        jnp.where(valid[:, None], grads.astype(jnp.float32), 0.0))[:h]
    touched = jnp.zeros((h + 1,), bool).at[idx].set(valid)[:h]
    g2 = jnp.mean(jnp.square(gsum), axis=-1)                 # (H,)
    new_accum = accum + jnp.where(touched, g2, 0.0)
    upd = lr * gsum * jax.lax.rsqrt(new_accum[:, None] + eps)
    new_table = table - jnp.where(touched[:, None], upd, 0.0
                                  ).astype(table.dtype)
    return new_table.astype(table.dtype), new_accum


def dedup_grads_ref(indices: jax.Array, grads: jax.Array, num_rows: int):
    """Aggregate per-lookup grads into unique-row grads — O(n log n) in the
    number of LOOKUPS (sort + run-length segment sum), independent of the
    table height (the paper's flat CPU hash-size curve, Fig. 12, depends on
    exactly this property).

    Returns (unique_idx (N,), summed_grads (N, D)): each unique row appears
    once (at its run head in sorted order); all other slots are -1 / zeros —
    the layout the rowwise_adagrad kernel consumes (it skips -1).
    """
    n, d = grads.shape
    valid = indices >= 0
    safe = jnp.where(valid, indices, num_rows)               # pads sort last
    order = jnp.argsort(safe)
    s_idx = safe[order]
    s_g = jnp.where(valid[order][:, None], grads[order].astype(jnp.float32),
                    0.0)
    is_head = jnp.concatenate(
        [jnp.ones((1,), bool), s_idx[1:] != s_idx[:-1]])
    seg = jnp.cumsum(is_head) - 1                            # run id per slot
    gsum_by_run = jax.ops.segment_sum(s_g, seg, num_segments=n)
    s_valid = s_idx < num_rows
    uniq = jnp.where(is_head & s_valid, s_idx, -1).astype(jnp.int32)
    gsum = jnp.where((is_head & s_valid)[:, None], gsum_by_run[seg], 0.0)
    return uniq, gsum


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Oracle for the flash_attention kernel. q,k,v: (b, h, s, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32))
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = np.arange(sk)[None, :] > np.arange(sq)[:, None]
        s = jnp.where(jnp.asarray(mask)[None, None], -1e30, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
