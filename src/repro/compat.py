"""jax API-drift shims (see also kernels/compat.py for the Pallas side).

The tree supports the verified range pinned in pyproject.toml
(jax>=0.4.35,<0.8: the 0.4.37 container floor and the 0.7 CI pin); these
helpers absorb the names that moved inside that range:

  shard_map       jax.shard_map            <- jax.experimental.shard_map
  cost_analysis   dict                     <- [dict] on old jax

Retired once both floors supported them natively: `make_mesh` (plain
`jax.make_mesh(shape, axis_names)` exists since 0.4.35 and defaults to Auto
axis types where the concept exists) and `pcast` (its only caller, the
shard_map scan in train/steps.py, was replaced by the index-only sparse
bucketing — no replicated carry left to mark varying).
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover — exercised on old toolchains
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f=None, **kwargs):
    """jax.shard_map with the rep/vma-check kwarg translated: callers pass
    the current name (check_vma); old jax called it check_rep."""
    if "check_vma" in kwargs and _CHECK_KW != "check_vma":
        kwargs[_CHECK_KW] = kwargs.pop("check_vma")
    if f is None:
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict (old jax returned [dict])."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
