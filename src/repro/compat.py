"""jax API-drift shims (see also kernels/compat.py for the Pallas side).

The tree targets current jax; these helpers keep it running on older
toolchains where a handful of names moved:

  shard_map       jax.shard_map            <- jax.experimental.shard_map
  pcast           jax.lax.pcast            <- no-op (old shard_map has no
                                              varying-marking; harmless)
  make_mesh       axis_types=Auto kwarg    <- dropped when unsupported
  cost_analysis   dict                     <- [dict] on old jax
"""
from __future__ import annotations

import jax

try:
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover — exercised on old toolchains
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f=None, **kwargs):
    """jax.shard_map with the rep/vma-check kwarg translated: callers pass
    the current name (check_vma); old jax called it check_rep."""
    if "check_vma" in kwargs and _CHECK_KW != "check_vma":
        kwargs[_CHECK_KW] = kwargs.pop("check_vma")
    if f is None:
        return lambda g: _shard_map(g, **kwargs)
    return _shard_map(f, **kwargs)


def pcast(x, axes, to: str = "varying"):
    """Mark a value device-varying inside shard_map. Old jax has no notion
    of varying-ness (no rep-checking of scan carries) — identity there."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to=to)
    return x


def make_mesh(shape, axis_names):
    """jax.make_mesh with Auto axis types where the concept exists."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(shape, axis_names)


def cost_analysis_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict (old jax returned [dict])."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})
