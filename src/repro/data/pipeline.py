"""Host-side data pipeline: the paper's reader-server tier (section IV-B.2).

Readers are decoupled from trainers so data loading never stalls training:
`DataPipeline` runs generator workers in a background thread pool feeding a
bounded queue (double buffering by default), and `ShardedLoader` slices each
global batch into this host's shard (the `(pod, data)` axes of the mesh) with
deterministic per-step seeds — any host can regenerate any shard of any step,
which is also what makes elastic restart (train/elastic.py) possible without
data-state checkpoints.
"""
from __future__ import annotations

import collections
import contextlib
import queue
import threading
from collections.abc import Callable, Iterator, Sequence

import numpy as np


class _WorkerError:
    """Marker riding the batch queue: the generator/transform raised."""

    def __init__(self, error: BaseException):
        self.error = error


class DataPipeline:
    """Prefetching wrapper: gen(step) -> batch, produced ahead of use.

    `transform` runs on each batch INSIDE the worker thread — host-side
    preprocessing (e.g. the cached-tier dedup hook below) overlaps device
    compute for free, the reader-tier decoupling of section IV-B.2.

    Failure contract (tests/test_train_runtime.py fault injection): any
    exception in the reader thread — including BaseExceptions like a
    simulated kill — surfaces in the consumer as a RuntimeError within one
    step; a worker that dies without parking an error (or is killed
    mid-put) is detected by a liveness check instead of deadlocking the
    consumer on an empty queue.

    `peek(i)` exposes the i-th UPCOMING batch without consuming it — the
    k-step lookahead feeding the cached tier's async fetch stream
    (`lookahead_rows` below). Peeked batches are buffered consumer-side and
    are still returned, in order, by `__next__`.
    """

    _POLL_S = 0.05             # liveness-check poll while waiting on the queue

    def __init__(self, gen: Callable[[int], dict[str, np.ndarray]],
                 prefetch: int = 2, start_step: int = 0,
                 transform: Callable[[dict[str, np.ndarray]],
                                              dict[str, np.ndarray]] | None = None,
                 injector=None):
        # `injector` (train.fault_tolerance.FaultInjector) fires the
        # "pipeline.batch" site inside the worker once per produced batch:
        # an "error"/"kill" spec is the reader-thread-death fault, which
        # surfaces to the consumer through the failure contract above
        self._gen = gen
        self._transform = transform
        self._injector = injector
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._buf: collections.deque = collections.deque()   # peeked batches
        self._failed: BaseException | None = None   # sticky failure for next()
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        try:
            while not self._stop.is_set():
                batch = self._gen(step)
                if self._transform is not None:
                    batch = self._transform(batch)
                if self._injector is not None:
                    self._injector.fire("pipeline.batch", step=step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # noqa: BLE001 — surface in the consumer
            # a dead reader must fail the trainer loudly, not starve it:
            # park the error where __next__ will re-raise it (BaseException
            # too: a SystemExit/KeyboardInterrupt "kill" of the reader must
            # not strand the trainer)
            while not self._stop.is_set():
                try:
                    self._q.put((step, _WorkerError(e)), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def _pull(self):
        """Blocking queue get with worker-liveness checks: never deadlocks
        on a dead reader. Returns the (step, batch-or-error) tuple."""
        while True:
            try:
                return self._q.get(timeout=self._POLL_S)
            except queue.Empty:
                if self._stop.is_set():
                    raise StopIteration from None
                if not self._thread.is_alive():
                    # one last non-blocking look: the worker may have parked
                    # its error between our get() and is_alive()
                    try:
                        return self._q.get_nowait()
                    except queue.Empty:
                        # sticky: even if this raise is swallowed by peek(),
                        # the next __next__ must re-raise, not StopIteration
                        self._failed = RuntimeError(
                            "data pipeline worker died without reporting an "
                            "error (reader thread no longer alive)")
                        self._stop.set()
                        raise self._failed from None

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._buf:
            # good batches peeked before a failure was observed are still
            # delivered, in order, before the failure raises — same degrade
            # path as a parked _WorkerError riding behind them in the queue
            step, batch = self._buf.popleft()
        else:
            if self._stop.is_set():
                if self._failed is not None:
                    raise self._failed      # stream FAILED, didn't just end
                raise StopIteration
            step, batch = self._pull()
        if isinstance(batch, _WorkerError):
            self._failed = RuntimeError(
                f"data pipeline worker failed at step {step}")
            self._stop.set()
            raise self._failed from batch.error
        return step, batch

    def peek(self, i: int = 0) -> dict[str, np.ndarray] | None:
        """The i-th upcoming batch (0 = what the next `__next__` returns)
        WITHOUT consuming it. Returns None once the stream has failed or
        closed at or before that position — the error itself is raised by
        the next `__next__`, so a prefetching trainer degrades to the
        strict-sync path for its final step instead of crashing early."""
        if self._stop.is_set():
            return None
        while len(self._buf) <= i:
            if self._buf and isinstance(self._buf[-1][1], _WorkerError):
                return None                    # stream already known-dead
            try:
                self._buf.append(self._pull())
            except (StopIteration, RuntimeError):
                return None
        batch = self._buf[i][1]
        return None if isinstance(batch, _WorkerError) else batch

    def close(self):
        self._stop.set()
        self._failed = None                 # explicit shutdown is not failure
        self._buf.clear()
        # drain so a worker blocked in put() unblocks promptly
        with contextlib.suppress(queue.Empty):
            while True:
                self._q.get_nowait()
        self._thread.join(timeout=2)


class ShardedLoader:
    """Deterministic per-host slicing of global batches.

    host_index / num_hosts follow jax.process_index()/count() in a real
    deployment; injectable here for tests.
    """

    def __init__(self, gen: Callable[[int, int], dict[str, np.ndarray]],
                 global_batch: int, host_index: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        assert global_batch % num_hosts == 0
        self.global_batch = global_batch
        self.host_batch = global_batch // num_hosts
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.seed = seed
        self._gen = gen

    def host_slice(self, step: int) -> dict[str, np.ndarray]:
        """Generate ONLY this host's rows (readers scale out per host)."""
        full = self._gen(step, self.seed)
        lo = self.host_index * self.host_batch
        hi = lo + self.host_batch
        return {k: v[lo:hi] for k, v in full.items()}

    def pipeline(self, prefetch: int = 2, start_step: int = 0,
                 transform: Callable | None = None,
                 injector=None) -> DataPipeline:
        return DataPipeline(self.host_slice, prefetch, start_step, transform,
                            injector=injector)


def dedup_indices_hook(table_offsets: Sequence[int], key: str = "idx",
                       out_key: str = "uniq_rows",
                       row_remap: np.ndarray | None = None
                       ) -> Callable[[dict[str, np.ndarray]],
                                     dict[str, np.ndarray]]:
    """Prefetch hook for the cached embedding tier (core/cache.py).

    Returns a transform that REWRITES batch[key] from (B, F, L) per-table
    indices to OFFSET global mega-table rows (what every EmbeddingBag lookup
    and the cached train step consume — no second offset_indices pass
    downstream) and attaches the DEDUPLICATED row set as batch[out_key].
    Both run in the pipeline worker thread, so when the trainer calls
    `CachedEmbeddingBagCollection.prefetch(state, batch["uniq_rows"])` the
    capacity-tier fetch overlaps the previous step's device compute instead
    of serializing with it.

    `row_remap` (from `core.placement.frequency_reorder`) is an optional
    (total_rows,) permutation applied to the offset global rows — the
    ids-by-frequency reorder that makes the Zipf head contiguous so
    chunk-granular fetches (`fetch_chunk > 1`) stay dense. It runs here, in
    the reader thread, next to plan building, so no downstream consumer
    ever sees un-remapped ids.
    """
    offsets = np.asarray(table_offsets, np.int64)
    remap = None if row_remap is None else np.asarray(row_remap, np.int64)

    def hook(batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        idx = batch[key]
        valid = idx >= 0
        glob = np.where(valid, idx + offsets[None, :, None], -1)
        if remap is not None:
            glob = np.where(valid, remap[glob], -1)
        glob = glob.astype(np.int32)
        out = dict(batch)
        out[key] = glob
        out[out_key] = np.unique(glob[glob >= 0]).astype(np.int64)
        return out

    return hook


def sparse_plan_hook(table_offsets: Sequence[int], key: str = "idx",
                     out_key: str = "uniq_rows",
                     capacity: int | None = None,
                     n_hosts: int | None = None,
                     row_remap: np.ndarray | None = None
                     ) -> Callable[[dict[str, np.ndarray]],
                                   dict[str, np.ndarray]]:
    """`dedup_indices_hook` + the shared sparse bucketing plan.

    On top of the dedup hook's rewrite (batch[key] -> offset global rows,
    batch[out_key] = unique row set), attaches the CSR bucketing layout of
    kernels/sparse_plan.py as batch["plan_rows"/"plan_offsets"/"plan_bags"].
    The sort runs in the pipeline worker thread, so by the time the train
    step consumes batch k its plan was built while batch k-1 computed — the
    same fetch/compute overlap the cached tier gets from `prefetch`. The
    plan is built ONCE here and consumed THRICE downstream
    (docs/embedding_forward.md): the forward's dedup'd gather
    (`dlrm_grads` -> `ebc.lookup(plan=...)`), the fused sparse backward
    (`kernels.plan_from_batch`), and the cached tiers' miss planning
    (`kernels.host_plan_from_batch` -> `prepare`/`take_async`; the cached
    steps also relabel it to slot space with `plan_to_slots`).

    `capacity` trims the plan's unique arrays to a static budget (smaller
    forward gathers and backward grids); batches whose unique count
    overflows it fail loudly in the reader thread.

    `n_hosts` additionally splits the plan into per-host sub-plans
    (`kernels.sparse_plan.split_plan_by_host` — the data-parallel batch
    split of the multi-host cached tier, docs/cache.md), stacked under
    batch["hplan_rows"/"hplan_offsets"/"hplan_bags"] with shape (H, ...):
    the split, too, runs in the reader thread, so each host's miss
    planning consumes a ready-made sorted unique row set.

    `row_remap` is forwarded to `dedup_indices_hook`: the frequency reorder
    is applied BEFORE the plan is built, so the plan's sorted unique rows —
    and the per-host sub-plans' all-to-all messages — chunk over the
    remapped (hot-head-contiguous) row space.
    """
    from repro.kernels.sparse_plan import (build_sparse_plan_host,
                                           split_plan_by_host)
    base = dedup_indices_hook(table_offsets, key, out_key, row_remap)

    def hook(batch: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
        out = base(batch)
        plan = build_sparse_plan_host(out[key], capacity=capacity)
        out.update(plan.to_batch())
        if n_hosts is not None and n_hosts > 1:
            b, f, _ = out[key].shape
            subs = split_plan_by_host(plan, n_hosts, b // n_hosts * f)
            out["hplan_rows"] = np.stack(
                [np.asarray(p.unique_rows) for p in subs])
            out["hplan_offsets"] = np.stack(
                [np.asarray(p.bag_offsets) for p in subs])
            out["hplan_bags"] = np.stack(
                [np.asarray(p.bag_ids) for p in subs])
        return out

    return hook


def lookahead_rows(pipe: DataPipeline, k: int,
                   key: str = "uniq_rows") -> np.ndarray:
    """K-step lookahead for the async fetch stream: the union of the next
    `k` upcoming batches' deduplicated row sets (attached per batch by
    `dedup_indices_hook`), peeked without consuming. Feed the result to the
    overlapped cached train step's `prefetch_rows` (or directly to
    `CachedEmbeddingBagCollection.stage_rows`) so rows needed several steps
    out start their capacity-tier fetch behind the current batch's compute.

    Stops early (returning the union so far) when the stream ends or fails
    before position k — the failure itself surfaces on the next `next()`.
    """
    rows = []
    for i in range(k):
        batch = pipe.peek(i)
        if batch is None or key not in batch:
            break
        rows.append(np.asarray(batch[key]).ravel())
    if not rows:
        return np.empty((0,), np.int64)
    cat = np.concatenate(rows)
    return np.unique(cat[cat >= 0]).astype(np.int64)
