"""Host-side data pipeline: the paper's reader-server tier (section IV-B.2).

Readers are decoupled from trainers so data loading never stalls training:
`DataPipeline` runs generator workers in a background thread pool feeding a
bounded queue (double buffering by default), and `ShardedLoader` slices each
global batch into this host's shard (the `(pod, data)` axes of the mesh) with
deterministic per-step seeds — any host can regenerate any shard of any step,
which is also what makes elastic restart (train/elastic.py) possible without
data-state checkpoints.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class DataPipeline:
    """Prefetching wrapper: gen(step) -> batch, produced ahead of use."""

    def __init__(self, gen: Callable[[int], Dict[str, np.ndarray]],
                 prefetch: int = 2, start_step: int = 0):
        self._gen = gen
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._gen(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return self._q.get()

    def close(self):
        self._stop.set()
        # drain so the worker unblocks
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


class ShardedLoader:
    """Deterministic per-host slicing of global batches.

    host_index / num_hosts follow jax.process_index()/count() in a real
    deployment; injectable here for tests.
    """

    def __init__(self, gen: Callable[[int, int], Dict[str, np.ndarray]],
                 global_batch: int, host_index: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        assert global_batch % num_hosts == 0
        self.global_batch = global_batch
        self.host_batch = global_batch // num_hosts
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.seed = seed
        self._gen = gen

    def host_slice(self, step: int) -> Dict[str, np.ndarray]:
        """Generate ONLY this host's rows (readers scale out per host)."""
        full = self._gen(step, self.seed)
        lo = self.host_index * self.host_batch
        hi = lo + self.host_batch
        return {k: v[lo:hi] for k, v in full.items()}

    def pipeline(self, prefetch: int = 2, start_step: int = 0) -> DataPipeline:
        return DataPipeline(self.host_slice, prefetch, start_step)
