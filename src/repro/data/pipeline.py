"""Host-side data pipeline: the paper's reader-server tier (section IV-B.2).

Readers are decoupled from trainers so data loading never stalls training:
`DataPipeline` runs generator workers in a background thread pool feeding a
bounded queue (double buffering by default), and `ShardedLoader` slices each
global batch into this host's shard (the `(pod, data)` axes of the mesh) with
deterministic per-step seeds — any host can regenerate any shard of any step,
which is also what makes elastic restart (train/elastic.py) possible without
data-state checkpoints.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional, Sequence

import numpy as np


class _WorkerError:
    """Marker riding the batch queue: the generator/transform raised."""

    def __init__(self, error: BaseException):
        self.error = error


class DataPipeline:
    """Prefetching wrapper: gen(step) -> batch, produced ahead of use.

    `transform` runs on each batch INSIDE the worker thread — host-side
    preprocessing (e.g. the cached-tier dedup hook below) overlaps device
    compute for free, the reader-tier decoupling of section IV-B.2.
    """

    def __init__(self, gen: Callable[[int], Dict[str, np.ndarray]],
                 prefetch: int = 2, start_step: int = 0,
                 transform: Optional[Callable[[Dict[str, np.ndarray]],
                                              Dict[str, np.ndarray]]] = None):
        self._gen = gen
        self._transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        try:
            while not self._stop.is_set():
                batch = self._gen(step)
                if self._transform is not None:
                    batch = self._transform(batch)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                step += 1
        except Exception as e:  # noqa: BLE001 — surface in the consumer
            # a dead reader must fail the trainer loudly, not starve it:
            # park the error where __next__ will re-raise it
            while not self._stop.is_set():
                try:
                    self._q.put((step, _WorkerError(e)), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        step, batch = self._q.get()
        if isinstance(batch, _WorkerError):
            self._stop.set()
            raise RuntimeError(
                f"data pipeline worker failed at step {step}"
            ) from batch.error
        return step, batch

    def close(self):
        self._stop.set()
        # drain so the worker unblocks
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


class ShardedLoader:
    """Deterministic per-host slicing of global batches.

    host_index / num_hosts follow jax.process_index()/count() in a real
    deployment; injectable here for tests.
    """

    def __init__(self, gen: Callable[[int, int], Dict[str, np.ndarray]],
                 global_batch: int, host_index: int = 0, num_hosts: int = 1,
                 seed: int = 0):
        assert global_batch % num_hosts == 0
        self.global_batch = global_batch
        self.host_batch = global_batch // num_hosts
        self.host_index = host_index
        self.num_hosts = num_hosts
        self.seed = seed
        self._gen = gen

    def host_slice(self, step: int) -> Dict[str, np.ndarray]:
        """Generate ONLY this host's rows (readers scale out per host)."""
        full = self._gen(step, self.seed)
        lo = self.host_index * self.host_batch
        hi = lo + self.host_batch
        return {k: v[lo:hi] for k, v in full.items()}

    def pipeline(self, prefetch: int = 2, start_step: int = 0,
                 transform: Optional[Callable] = None) -> DataPipeline:
        return DataPipeline(self.host_slice, prefetch, start_step, transform)


def dedup_indices_hook(table_offsets: Sequence[int], key: str = "idx",
                       out_key: str = "uniq_rows"
                       ) -> Callable[[Dict[str, np.ndarray]],
                                     Dict[str, np.ndarray]]:
    """Prefetch hook for the cached embedding tier (core/cache.py).

    Returns a transform that REWRITES batch[key] from (B, F, L) per-table
    indices to OFFSET global mega-table rows (what every EmbeddingBag lookup
    and the cached train step consume — no second offset_indices pass
    downstream) and attaches the DEDUPLICATED row set as batch[out_key].
    Both run in the pipeline worker thread, so when the trainer calls
    `CachedEmbeddingBagCollection.prefetch(state, batch["uniq_rows"])` the
    capacity-tier fetch overlaps the previous step's device compute instead
    of serializing with it.
    """
    offsets = np.asarray(table_offsets, np.int64)

    def hook(batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        idx = batch[key]
        glob = np.where(idx >= 0, idx + offsets[None, :, None],
                        -1).astype(np.int32)
        out = dict(batch)
        out[key] = glob
        out[out_key] = np.unique(glob[glob >= 0]).astype(np.int64)
        return out

    return hook
