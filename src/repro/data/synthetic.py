"""Synthetic data generators.

DLRM click logs: per-table multi-hot index lists whose LENGTHS follow the
paper's power-law (Fig. 7 KDE shapes — a few hot tables with many lookups)
and whose INDEX values follow a Zipf over the hash space (hot rows exist,
motivating the caching observations of section III-A.2). Labels are generated
from a planted logistic model so training has signal and loss can decrease.

LM token streams: uniform random tokens (throughput benchmarking needs
shape-realistic, not linguistically-real, data) with deterministic per-step
seeds so every data shard regenerates its slice independently — the
reader-server decoupling of section IV-B.2 without materializing storage.
"""
from __future__ import annotations


import jax
import numpy as np

from repro.configs.base import DLRMConfig, ModelConfig

# ---------------------------------------------------------------------------
# DLRM
# ---------------------------------------------------------------------------


def _zipf_indices(rng: np.random.RandomState, hash_size: int, n: int,
                  a: float = 1.3) -> np.ndarray:
    """Zipf-ish draws clipped into [0, hash_size)."""
    raw = rng.zipf(a, size=n) - 1
    return (raw % max(hash_size, 1)).astype(np.int32)


_ZIPF_CDF_CACHE: dict = {}


def _bounded_zipf_cdf(hash_size: int, alpha: float) -> np.ndarray:
    """CDF of the rank-probability Zipf p(r) ∝ (r+1)^-alpha over
    [0, hash_size) — unlike numpy's unbounded rng.zipf + mod-wrap, the head
    stays hot and the tail mass is NOT folded back uniformly, so measured
    cache hit rates reflect the true skew (paper Fig. 6)."""
    key = (hash_size, round(alpha, 6))
    cdf = _ZIPF_CDF_CACHE.get(key)
    if cdf is None:
        p = (np.arange(1, hash_size + 1, dtype=np.float64)) ** (-alpha)
        cdf = np.cumsum(p / p.sum())
        _ZIPF_CDF_CACHE[key] = cdf
    return cdf


def bounded_zipf_rows(rng: np.random.RandomState, hash_size: int, n: int,
                      alpha: float) -> np.ndarray:
    """n draws from the bounded Zipf(alpha) over [0, hash_size): row 0 is
    the hottest. Inverse-CDF sampling; the CDF is cached per (size, alpha)."""
    cdf = _bounded_zipf_cdf(hash_size, alpha)
    return np.searchsorted(cdf, rng.rand(n)).astype(np.int32)


def make_dlrm_batch(cfg: DLRMConfig, batch: int, step: int = 0,
                    seed: int = 0,
                    zipf_alpha: float | None = None
                    ) -> dict[str, np.ndarray]:
    """Returns {dense (B, n_dense) f32, idx (B, F, L) i32 (-1 pads, already
    in-table — NOT offset), label (B,) f32}.

    zipf_alpha=None keeps the historical per-example rng.zipf(1.3) draw
    (bitwise-stable for existing tests); setting it switches index values to
    the bounded Zipf above — the knob benchmarks/cache_bench.py sweeps."""
    rng = np.random.RandomState(seed * 1_000_003 + step)
    f, trunc = cfg.n_sparse_features, cfg.truncation
    dense = rng.randn(batch, cfg.n_dense_features).astype(np.float32)

    idx = np.full((batch, f, trunc), -1, np.int32)
    planted = 0.0
    for t in range(f):
        mean_len = min(cfg.mean_lookups[t], trunc)
        lens = np.clip(rng.poisson(mean_len, size=batch), 1, trunc)
        if zipf_alpha is not None:
            vals = bounded_zipf_rows(rng, cfg.hash_sizes[t], batch * trunc,
                                     zipf_alpha).reshape(batch, trunc)
            mask = np.arange(trunc)[None, :] < lens[:, None]
            idx[:, t, :] = np.where(mask, vals, -1)
        else:
            for b in range(batch):
                vals = _zipf_indices(rng, cfg.hash_sizes[t], lens[b])
                idx[b, t, :lens[b]] = vals
        planted = planted + (idx[:, t, 0] % 7 - 3)

    # planted logistic labels: depend on dense mean + a hash of first indices
    score = dense[:, :8].mean(axis=1) * 2.0 + planted * 0.3
    prob = 1.0 / (1.0 + np.exp(-score))
    label = (rng.rand(batch) < prob).astype(np.float32)
    return {"dense": dense, "idx": idx, "label": label}


def dlrm_batch_specs(cfg: DLRMConfig, batch: int) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (indices already offset)."""
    import jax.numpy as jnp
    return {
        "dense": jax.ShapeDtypeStruct((batch, cfg.n_dense_features),
                                      jnp.float32),
        "idx": jax.ShapeDtypeStruct(
            (batch, cfg.n_sparse_features, cfg.truncation), jnp.int32),
        "label": jax.ShapeDtypeStruct((batch,), jnp.float32),
    }

# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def vlm_prefix(seq_len: int) -> int:
    """Image-prefix length for VLM archs (patch embeddings from the stub
    frontend): 256 patches, bounded for tiny smoke sequences."""
    return min(256, max(4, seq_len // 8))


def make_lm_batch(cfg: ModelConfig, batch: int, seq_len: int, step: int = 0,
                  seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.RandomState(seed * 7_777_777 + step + 1)
    out: dict[str, np.ndarray] = {}
    if cfg.frontend == "vision":
        prefix = vlm_prefix(seq_len)
        text = seq_len - prefix
        out["embeds"] = rng.randn(batch, prefix,
                                  cfg.d_model).astype(np.float32) * 0.02
        out["tokens"] = rng.randint(0, cfg.vocab_size,
                                    size=(batch, text)).astype(np.int32)
        out["targets"] = rng.randint(0, cfg.vocab_size,
                                     size=(batch, seq_len)).astype(np.int32)
        # image positions don't contribute to the loss
        out["loss_mask"] = np.concatenate(
            [np.zeros((batch, prefix), np.float32),
             np.ones((batch, text), np.float32)], axis=1)
    elif cfg.frontend == "audio":
        out["embeds"] = rng.randn(batch, seq_len,
                                  cfg.d_model).astype(np.float32) * 0.02
        out["targets"] = rng.randint(
            0, cfg.vocab_size,
            size=(batch, seq_len, cfg.n_codebooks)).astype(np.int32)
        out["loss_mask"] = np.ones((batch, seq_len), np.float32)
    else:
        out["tokens"] = rng.randint(0, cfg.vocab_size,
                                    size=(batch, seq_len)).astype(np.int32)
        out["targets"] = np.concatenate(
            [out["tokens"][:, 1:],
             rng.randint(0, cfg.vocab_size, size=(batch, 1))],
            axis=1).astype(np.int32)
        out["loss_mask"] = np.ones((batch, seq_len), np.float32)
    return out


def lm_batch_specs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    import jax.numpy as jnp
    out: dict = {}
    if cfg.frontend == "vision":
        prefix = vlm_prefix(seq_len)
        text = seq_len - prefix
        out["embeds"] = jax.ShapeDtypeStruct((batch, prefix, cfg.d_model),
                                             jnp.float32)
        out["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
        out["targets"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        out["loss_mask"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.float32)
    elif cfg.frontend == "audio":
        out["embeds"] = jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model),
                                             jnp.float32)
        out["targets"] = jax.ShapeDtypeStruct(
            (batch, seq_len, cfg.n_codebooks), jnp.int32)
        out["loss_mask"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.float32)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        out["targets"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)
        out["loss_mask"] = jax.ShapeDtypeStruct((batch, seq_len), jnp.float32)
    return out
