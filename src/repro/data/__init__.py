from repro.data.synthetic import (  # noqa: F401
    dlrm_batch_specs,
    lm_batch_specs,
    make_dlrm_batch,
    make_lm_batch,
)
from repro.data.pipeline import DataPipeline, ShardedLoader  # noqa: F401
