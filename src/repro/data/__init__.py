from repro.data.pipeline import (  # noqa: F401
    DataPipeline,
    ShardedLoader,
    dedup_indices_hook,
    lookahead_rows,
    sparse_plan_hook,
)
from repro.data.synthetic import (  # noqa: F401
    bounded_zipf_rows,
    dlrm_batch_specs,
    lm_batch_specs,
    make_dlrm_batch,
    make_lm_batch,
)
