"""Table-wise hybrid parallelism (core/placement.py `table_wise`,
train/steps.py `build_tablewise_train_step`, docs/parallelism.md).

Covers the acceptance contract of the hybrid placement: the priced greedy
bin-pack (whole tables on owners, oversized tables flagged column_wise),
the per-owner/per-table plan splits over the general range core, the
analytic pooled-exchange traffic model + `recommend_placement`'s regime
picks, and the train step's BIT-EXACTNESS vs the dense single-host oracle
— sync and overlap, single-host and on a real (data, model) mesh of 8
fake devices (subprocess, shard_map owner update over genuinely
table-sharded params).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.core.placement import plan_placement
from repro.data.synthetic import make_dlrm_batch
from repro.kernels.sparse_plan import (build_sparse_plan_host,
                                       split_plan_by_owner,
                                       split_plan_by_ranges,
                                       split_plan_by_table)
from repro.launch.analysis import (recommend_placement,
                                   tablewise_exchange_traffic)
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_dlrm_train_step,
                               build_tablewise_train_step, dlrm_init_state)

pytestmark = pytest.mark.compat

# ---------------------------------------------------------------------------
# placement: priced bin-pack
# ---------------------------------------------------------------------------


def test_table_wise_plan_shape_and_owners():
    plan = plan_placement([1000, 500, 800, 300], [4.0, 1.0, 3.0, 2.0], 16,
                          2, 1e9, strategy="table_wise")
    assert plan.strategy == "table_wise"
    assert plan.capacity_shards == 2 and plan.shard_rows > 0
    assert plan.total_rows == 2 * plan.shard_rows
    assert plan.pspec == jax.sharding.PartitionSpec("model", None)
    assert plan.column_shards == (1, 1, 1, 1)
    owners = np.asarray(plan.table_offsets) // plan.shard_rows
    # every table sits whole inside its owner's row block
    rows_of = [-(-h // 8) * 8 for h in [1000, 500, 800, 300]]
    for t, off in enumerate(plan.table_offsets):
        assert off + rows_of[t] <= (owners[t] + 1) * plan.shard_rows
    # LPT on cost: the two priciest tables (0 and 2) land on DIFFERENT
    # owners, so neither shard carries both heavy hitters
    assert owners[0] != owners[2]


def test_table_wise_priced_costs_override_loads():
    """With costs inverting the load order, the bin-pack must separate the
    tables the COSTS call heavy, not the ones the loads do."""
    sizes, loads = [400, 400, 400, 400], [10.0, 10.0, 1.0, 1.0]
    by_load = plan_placement(sizes, loads, 16, 2, 1e9,
                             strategy="table_wise")
    by_cost = plan_placement(sizes, loads, 16, 2, 1e9,
                             strategy="table_wise",
                             table_costs=[1.0, 1.0, 10.0, 10.0])
    o_load = np.asarray(by_load.table_offsets) // by_load.shard_rows
    o_cost = np.asarray(by_cost.table_offsets) // by_cost.shard_rows
    assert o_load[0] != o_load[1]          # loads split 0 and 1 ...
    assert o_cost[2] != o_cost[3]          # ... costs split 2 and 3
    # cost balance: per-shard summed cost is even
    assert by_cost.load_per_shard[0] == by_cost.load_per_shard[1]


def test_table_wise_oversized_table_flagged_column_wise():
    d, itemsize = 16, 4
    budget = 100 * d * itemsize            # one shard holds 100 rows
    plan = plan_placement([350, 40], [1.0, 1.0], d, 4, budget,
                          strategy="table_wise")
    # 350-row table needs ceil(350/100) = 4 slices; the small one is whole
    assert plan.column_shards[0] == 4
    assert plan.column_shards[1] == 1


def test_column_wise_requires_divisible_dim():
    plan = plan_placement([100, 50], [1.0, 1.0], 64, 4, 1e9,
                          strategy="column_wise")
    assert plan.column_shards == (4, 4)
    assert plan.pspec == jax.sharding.PartitionSpec(None, "model")
    with pytest.raises(ValueError, match="divisible"):
        plan_placement([100, 50], [1.0, 1.0], 30, 4, 1e9,
                       strategy="column_wise")


def test_tablewise_step_rejects_wrong_plans():
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    with pytest.raises(ValueError, match="table_wise"):
        build_tablewise_train_step(cfg, ebc, adagrad(0.01))

# ---------------------------------------------------------------------------
# plan splitting: ranges core, owner special case, per-table recovery
# ---------------------------------------------------------------------------


def _live_rows(plan):
    rows = np.asarray(plan.unique_rows)
    return rows[: int((rows >= 0).sum())].astype(np.int64)


def test_split_by_ranges_equals_owner_split():
    rng = np.random.RandomState(0)
    idx = rng.randint(-1, 48, size=(8, 3, 5)).astype(np.int32)
    plan = build_sparse_plan_host(idx)
    starts = np.arange(4, dtype=np.int64) * 12
    a = split_plan_by_ranges(plan, starts, starts + 12)
    b = split_plan_by_owner(plan, 12, 4)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_split_by_ranges_skips_unclaimed_gaps():
    """Rows between ranges (per-shard tail padding in a table_wise mega)
    belong to NO segment."""
    rng = np.random.RandomState(1)
    idx = rng.randint(0, 30, size=(6, 2, 4)).astype(np.int32)
    plan = build_sparse_plan_host(idx)
    seg_rows, _, seg_base = split_plan_by_ranges(plan, [0, 20], [10, 30])
    live = _live_rows(plan)
    claimed = sorted(r + seg_base[s] for s in range(2)
                     for r in seg_rows[s][seg_rows[s] >= 0])
    want = sorted(int(r) for r in live if r < 10 or r >= 20)
    assert claimed == want


def test_split_by_ranges_rejects_overlapping():
    plan = build_sparse_plan_host(np.zeros((2, 1, 1), np.int32))
    with pytest.raises(AssertionError, match="ascending and disjoint"):
        split_plan_by_ranges(plan, [0, 5], [10, 15])


def test_split_by_table_recovers_per_table_footprints():
    """Under a table_wise layout (tables at arbitrary offsets, row order
    != table order), the per-table segments' local rows + base reconstruct
    exactly the global live rows falling in each table's span, in TABLE
    order."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=2,
                                       strategy="table_wise")
    raw = make_dlrm_batch(cfg, 8, step=0)
    idx = np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))
    plan = build_sparse_plan_host(idx)
    offs = np.asarray(ebc.plan.table_offsets, np.int64)
    rows_of = np.asarray([-(-h // 8) * 8 for h in cfg.hash_sizes], np.int64)
    seg_rows, seg_offs, seg_base = split_plan_by_table(plan, offs, rows_of)
    assert np.array_equal(seg_base, offs.astype(np.int32))
    live = _live_rows(plan)
    for t in range(len(offs)):
        mine = seg_rows[t][seg_rows[t] >= 0] + offs[t]
        want = live[(live >= offs[t]) & (live < offs[t] + rows_of[t])]
        assert np.array_equal(mine, want)
        # per-table unique footprint = the pricing quantity
        assert len(mine) == len(np.unique(idx[(idx >= offs[t]) &
                                              (idx < offs[t] + rows_of[t])]))


def test_split_overflow_message_names_cap():
    rng = np.random.RandomState(2)
    idx = rng.randint(0, 40, size=(8, 2, 4)).astype(np.int32)
    plan = build_sparse_plan_host(idx)
    with pytest.raises(ValueError, match="segment overflow"):
        split_plan_by_owner(plan, 40, 1, seg_cap=2)

# ---------------------------------------------------------------------------
# analytic exchange model + placement recommendation
# ---------------------------------------------------------------------------


def test_tablewise_exchange_traffic_math():
    b, f, lk, d, h = 8192, 16, 32, 64, 16
    t = tablewise_exchange_traffic(b, f, lk, d, h)
    assert t["fwd_bytes"] == t["bwd_bytes"]
    assert t["total_bytes"] == 2 * t["fwd_bytes"]
    assert t["fwd_bytes"] == (h - 1) / h * b * f * d * 4
    # pooling removes exactly the bag length L vs un-pooled row shipping
    assert t["pooling_reduction"] == lk
    # the per-pair leg stays under the B*F*d*itemsize ceiling
    assert t["pair_leg_bytes"] <= b * f * d * 4
    # a real (imbalanced) owner histogram sharpens the leg: the widest
    # owner, not the uniform ceil(F/H), sets the pair maximum
    t2 = tablewise_exchange_traffic(b, f, lk, d, h,
                                    features_per_owner=[f // 2] + [1] *
                                    (h - 1))
    assert t2["pair_leg_bytes"] == (f // 2) * -(-b // h) * d * 4
    assert t2["pair_leg_bytes"] > t["pair_leg_bytes"]
    # one host: nothing crosses
    assert tablewise_exchange_traffic(b, f, lk, d, 1)["total_bytes"] == 0.0


def test_recommend_placement_three_regimes():
    kw = dict(embed_dim=64, batch=8192, truncation=32, n_hosts=16)
    small = [10_000] * 8
    # everything fits one host -> replicated, zero exchange
    rec = recommend_placement(small, [8.0] * 8, **kw,
                              hbm_budget_bytes=1e12)
    assert rec["pick"] == "replicated" and rec["fits_one_host"]
    assert all(t["strategy"] == "replicated" for t in rec["per_table"])
    # doesn't fit one host, long bags -> pooled tablewise wins
    big = [40_000_000] * 8
    rec = recommend_placement(big, [30.0] * 8, **kw,
                              hbm_budget_bytes=32e9)
    assert rec["pick"] == "table_wise" and not rec["fits_one_host"]
    assert rec["plan"].strategy == "table_wise"
    assert rec["tablewise"]["total_bytes"] <= rec["rowshard"]["total_bytes"]
    # hot skewed traffic with a high hit rate -> the cached tier's
    # unique-row exchange undercuts the pooled all-to-all
    rec = recommend_placement(big, [1.0] * 8, **kw, hbm_budget_bytes=32e9,
                              hit_rate=0.99, alpha=1.2)
    assert rec["pick"] == "cached_host"
    # a table too big for any single host is flagged column_wise
    rec = recommend_placement([4_000_000_000, 1000], [8.0, 8.0], **kw,
                              hbm_budget_bytes=32e9)
    per = rec["per_table"]
    assert per[0]["strategy"] == "column_wise"
    assert per[0]["column_shards"] > 1
    assert per[1]["strategy"] == "table_wise"

# ---------------------------------------------------------------------------
# train-step bit-exactness: single host
# ---------------------------------------------------------------------------


def _batches(cfg, ebc, n, b):
    out = []
    for t in range(n):
        raw = make_dlrm_batch(cfg, b, step=t)
        out.append({"dense": jnp.asarray(raw["dense"]),
                    "idx": np.asarray(
                        ebc.offset_indices(jnp.asarray(raw["idx"]))),
                    "label": jnp.asarray(raw["label"])})
    return out


def _run_oracle(cfg, ebc, params, batches):
    opt = adagrad(0.01)
    p = dict(params)
    state = dlrm_init_state(ebc, opt, p)
    step = jax.jit(build_dlrm_train_step(cfg, ebc, opt,
                                         sparse_apply="sparse"))
    losses = []
    for t, b in enumerate(batches):
        bb = dict(b)
        bb["idx"] = jnp.asarray(bb["idx"])
        p, state, m = step(p, state, bb, jnp.asarray(t, jnp.int32))
        losses.append(float(m["loss"]))
    return losses, p, state


@pytest.mark.parametrize("overlap", [False, True])
def test_tablewise_step_bitexact_vs_oracle_single_host(overlap):
    """The owner-routed segmented update (and the staged pooled forward
    under overlap) must reproduce the dense single-host oracle BIT FOR
    BIT: same losses, same mega, same accumulator, same dense params."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=4,
                                       strategy="table_wise")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    batches = _batches(cfg, ebc, 4, 16)
    want_l, want_p, want_s = _run_oracle(cfg, ebc, params, batches)

    opt = adagrad(0.01)
    p = dict(params)
    state = dlrm_init_state(ebc, opt, p)
    step = build_tablewise_train_step(cfg, ebc, opt, overlap=overlap)
    got_l = []
    for t, b in enumerate(batches):
        nxt = batches[t + 1] if t + 1 < len(batches) else None
        p, state, m = step(p, state, b, jnp.asarray(t, jnp.int32),
                           next_batch=nxt)
        got_l.append(float(m["loss"]))
        assert m["exchange_pooled_fwd_bytes"] == \
            m["exchange_pooled_bwd_bytes"]
        assert m["exchange_pair_leg_bytes"] > 0
    assert got_l == want_l
    assert np.array_equal(np.asarray(p["emb"]["mega"]),
                          np.asarray(want_p["emb"]["mega"]))
    assert np.array_equal(np.asarray(state["accum"]),
                          np.asarray(want_s["accum"]))
    for a, b in zip(jax.tree.leaves({"bottom": p["bottom"],
                                     "top": p["top"]}),
                    jax.tree.leaves({"bottom": want_p["bottom"],
                                     "top": want_p["top"]})):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_tablewise_step_metrics_match_traffic_model():
    """The step's host-computed exchange metrics must equal the analytic
    model exactly (the invariant the deterministic bench row gates)."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=4,
                                       strategy="table_wise")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)
    state = dlrm_init_state(ebc, opt, params)
    step = build_tablewise_train_step(cfg, ebc, opt)
    b = _batches(cfg, ebc, 1, 16)[0]
    _, _, m = step(dict(params), state, b, jnp.asarray(0, jnp.int32))
    owners = np.asarray(ebc.plan.table_offsets) // ebc.plan.shard_rows
    t = tablewise_exchange_traffic(
        16, cfg.n_sparse_features, b["idx"].shape[2], cfg.embed_dim, 4,
        features_per_owner=np.bincount(owners, minlength=4))
    assert m["exchange_pooled_fwd_bytes"] == t["fwd_bytes"]
    assert m["exchange_pooled_bwd_bytes"] == t["bwd_bytes"]
    assert m["exchange_pair_leg_bytes"] == t["pair_leg_bytes"]

# ---------------------------------------------------------------------------
# 8 fake devices: pooled psum forward + shard_map owner update
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_tablewise_step_on_mesh_bitexact_vs_oracle():
    """The acceptance test, on a mesh of 8 fake devices. Two meshes:

    (data=1, model=8): the mega table genuinely table-sharded over all 8
    devices, pooled (B, F, d) psum exchange forward, shard_map per-owner
    fused update backward — sync AND overlap runs must equal the dense
    single-host oracle BIT FOR BIT (the model-parallel machinery adds no
    numerics of its own: other owners contribute exact fp32 zeros to the
    psum, and the routed segments reduce in flat-batch order).

    (data=2, model=4): the full hybrid. Batch-sharding the MLPs splits the
    dense-gradient reductions 8+8, so dense params drift by reduction
    order (standard data-parallel numerics, ~1 ulp) — losses must still
    match bit for bit and every array to 1e-6."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n" + """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_dlrm_train_step, dlrm_init_state,
                               build_tablewise_train_step)

cfg = get_smoke_config("dlrm-m1")
N, B = 4, 16


def run(n_shards, mesh_shape, overlap):
    ebc = EmbeddingBagCollection.build(cfg, n_shards=n_shards,
                                      strategy="table_wise")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    batches = []
    for t in range(N):
        raw = make_dlrm_batch(cfg, B, step=t)
        batches.append({"dense": jnp.asarray(raw["dense"]),
                        "idx": np.asarray(
                            ebc.offset_indices(jnp.asarray(raw["idx"]))),
                        "label": jnp.asarray(raw["label"])})
    opt = adagrad(0.01)
    p = dict(params)
    state = dlrm_init_state(ebc, opt, p)
    step_o = jax.jit(build_dlrm_train_step(cfg, ebc, opt,
                                           sparse_apply="sparse"))
    losses_o = []
    for t in range(N):
        b = dict(batches[t]); b["idx"] = jnp.asarray(b["idx"])
        p, state, m = step_o(p, state, b, jnp.asarray(t, jnp.int32))
        losses_o.append(float(m["loss"]))
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(*mesh_shape),
                             ("data", "model"))
    p2 = dict(params)
    state2 = dlrm_init_state(ebc, opt, p2)
    step_t = build_tablewise_train_step(cfg, ebc, opt, mesh=mesh,
                                        overlap=overlap)
    losses_t = []
    for t in range(N):
        nxt = batches[t + 1] if t + 1 < N else None
        with mesh:
            p2, state2, m = step_t(p2, state2, batches[t],
                                   jnp.asarray(t, jnp.int32),
                                   next_batch=nxt)
        losses_t.append(float(m["loss"]))
    assert losses_t == losses_o, (mesh_shape, overlap, losses_t, losses_o)
    pairs = [(p2["emb"]["mega"], p["emb"]["mega"]),
             (state2["accum"], state["accum"])]
    pairs += list(zip(
        jax.tree.leaves({"bottom": p2["bottom"], "top": p2["top"]}),
        jax.tree.leaves({"bottom": p["bottom"], "top": p["top"]})))
    return [(np.asarray(a), np.asarray(b)) for a, b in pairs]


for overlap in (False, True):
    # model-parallel only: bit-exact, all 8 devices own tables
    for a, b in run(8, (1, 8), overlap):
        assert np.array_equal(a, b), overlap
    # hybrid data x model: dense grads reduce 8+8, 1-ulp drift allowed
    for a, b in run(4, (2, 4), overlap):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
print("TABLEWISE_MESH_OK")
""")
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "TABLEWISE_MESH_OK" in out.stdout
