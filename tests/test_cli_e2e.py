"""End-to-end CLI smoke tests: the launch drivers must run as real
processes (isolated from this test process's jax state)."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=500):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-m"] + args, env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-2000:])
    return out.stdout


def test_train_cli_lm(tmp_path):
    out = _run(["repro.launch.train", "--arch", "stablelm-1.6b", "--smoke",
                "--steps", "12", "--batch", "4", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
                "--log-every", "5"])
    assert "done at step 12" in out


def test_train_cli_dlrm_resume(tmp_path):
    _run(["repro.launch.train", "--arch", "dlrm-m2", "--smoke",
          "--steps", "8", "--batch", "16",
          "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    out = _run(["repro.launch.train", "--arch", "dlrm-m2", "--smoke",
                "--steps", "12", "--batch", "16", "--resume",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    assert "resumed from step 8" in out
    assert "done at step 12" in out


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "stablelm-1.6b", "--smoke",
                "--requests", "3", "--slots", "2", "--new-tokens", "4"])
    assert "served 3 requests" in out


def _parse_serve_summary(out):
    line = next(ln for ln in out.splitlines()
                if ln.startswith("serve[dlrm]:"))
    return dict(part.split("=", 1) for part in line.split()[1:])


def test_serve_cli_dlrm():
    out = _run(["repro.launch.serve", "--arch", "dlrm-m1", "--smoke",
                "--requests", "12", "--batch", "2", "--max-batch", "8",
                "--burst", "3"])
    kv = _parse_serve_summary(out)
    assert int(kv["served"]) + int(kv["shed"]) == 12
    assert 0.0 <= float(kv["hit_rate"]) <= 1.0
    assert 0.0 <= float(kv["shed_rate"]) <= 1.0
    assert float(kv["p99_ms"]) >= float(kv["p50_ms"]) >= 0.0
    assert kv["breaker"] in ("healthy", "shedding", "stale_only")


def test_serve_cli_dlrm_chaos():
    out = _run(["repro.launch.serve", "--arch", "dlrm-m1", "--smoke",
                "--requests", "12", "--batch", "2", "--max-batch", "8",
                "--burst", "3", "--chaos", "--chaos-seed", "13"])
    kv = _parse_serve_summary(out)
    # degrade-don't-die: the chaos replay still resolves every request
    assert int(kv["served"]) + int(kv["shed"]) == 12
    assert 0.0 <= float(kv["degraded_fraction"]) <= 1.0
    assert "chaos: fired=" in out
