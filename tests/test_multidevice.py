"""Multi-device integration tests. The main test process pins ONE CPU
device (smoke tests must see a single device), so these spawn
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8 and
assert on their output — the same isolation discipline as launch/dryrun.
"""
import os
import subprocess
import sys

import pytest

# exercised on BOTH jax floors: these subprocess tests drive shard_map
# and mesh construction through the compat shims — see pyproject markers
# and the CI jax-floor leg
pytestmark = pytest.mark.compat


SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str) -> str:
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n" + body)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


def test_psum_lookup_matches_gather_on_mesh():
    print(_run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_config
from repro.core.embedding import EmbeddingBagCollection
from repro.nn.params import init_params
cfg = dataclasses.replace(get_smoke_config("dlrm-m1"), placement="row_wise")
mesh = jax.make_mesh((2, 4), ("data", "model"))
ebc = EmbeddingBagCollection.build(cfg, n_shards=4)
params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
idx = ebc.offset_indices(jnp.asarray(
    rng.randint(-1, 90, size=(8, cfg.n_sparse_features, 4)), jnp.int32))
with mesh:
    ref = ebc.lookup(params, idx)
    out = jax.jit(lambda p, i: ebc.lookup_pooled_psum(p, i, mesh))(params, idx)
np.testing.assert_allclose(np.asarray(out, np.float32),
                           np.asarray(ref, np.float32), rtol=1e-5, atol=1e-5)
print("PSUM_OK")
"""))


def test_shardmap_sparse_update_matches_pjit():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_config
from repro.core.embedding import EmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.nn.params import init_params
from repro.optim import adagrad
from repro.train.steps import build_dlrm_train_step, dlrm_init_state
from repro.data import make_dlrm_batch
cfg = dataclasses.replace(get_smoke_config("dlrm-m1"),
                          placement="row_wise", lookup_impl="psum")
cfg_ref = dataclasses.replace(cfg, lookup_impl="gather")
mesh = jax.make_mesh((2, 4), ("data", "model"))
ebc = EmbeddingBagCollection.build(cfg, n_shards=4)
params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
opt = adagrad(0.05)
state = dlrm_init_state(ebc, opt, params)
raw = make_dlrm_batch(cfg, 16)
batch = {"dense": jnp.asarray(raw["dense"]),
         "idx": ebc.offset_indices(jnp.asarray(raw["idx"])),
         "label": jnp.asarray(raw["label"])}
with mesh:
    p1, s1, m1 = jax.jit(build_dlrm_train_step(cfg, ebc, opt))(
        params, state, batch, jnp.asarray(0, jnp.int32))
    p2, s2, m2 = jax.jit(build_dlrm_train_step(cfg_ref, ebc, opt))(
        params, state, batch, jnp.asarray(0, jnp.int32))
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
np.testing.assert_allclose(np.asarray(p1["emb"]["mega"]),
                           np.asarray(p2["emb"]["mega"]),
                           rtol=1e-4, atol=1e-5)
print("SHARDMAP_OK")
""")
    assert "SHARDMAP_OK" in out


def test_lm_train_step_lowers_on_mesh_with_all_rule_tables():
    """Every rules table must produce a lowerable, compilable train step on
    a small mesh (the dry-run in miniature)."""
    out = _run("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.lm import lm_param_specs
from repro.nn.params import abstract_params, specs_to_pspecs
from repro.nn.sharding import FSDP_RULES, TRAIN_RULES, ZERO_DP_RULES
from repro.optim import adamw
from repro.train.steps import build_lm_train_step
from repro.data.synthetic import lm_batch_specs

cfg = get_smoke_config("stablelm-1.6b")
mesh = jax.make_mesh((2, 4), ("data", "model"))
for name, rules in [("train", TRAIN_RULES), ("fsdp", FSDP_RULES),
                    ("zero_dp", ZERO_DP_RULES)]:
    specs = lm_param_specs(cfg)
    params_abs = abstract_params(specs)
    psh = jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                       specs_to_pspecs(specs, rules, mesh=mesh),
                       is_leaf=lambda x: isinstance(x, P))
    opt = adamw(1e-3)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    batch = lm_batch_specs(cfg, 8, 32)
    step = build_lm_train_step(cfg, opt, rules)
    with mesh:
        compiled = jax.jit(step, in_shardings=(
            psh, {"m": psh, "v": psh}, None, None)).lower(
            params_abs, opt_abs, batch,
            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    assert compiled.memory_analysis() is not None
    print(name, "LOWER_OK")
""")
    assert out.count("LOWER_OK") == 3


def test_easgd_pod_axis_semantics():
    """EASGD replicas sharded over a mesh axis: elastic sync must produce
    the same result as the single-host reference math."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.optim.easgd import easgd_init, easgd_sync
mesh = jax.make_mesh((4, 2), ("pod", "model"))
state = easgd_init({"w": jnp.arange(6.0)}, n_replicas=4)
state = state._replace(replicas={"w": jnp.stack(
    [jnp.arange(6.0) + i for i in range(4)])})
ref = easgd_sync(state, 0.3, 0.3)
sh = NamedSharding(mesh, P("pod", None))
state_sharded = state._replace(
    replicas={"w": jax.device_put(state.replicas["w"], sh)})
with mesh:
    got = jax.jit(lambda s: easgd_sync(s, 0.3, 0.3))(state_sharded)
np.testing.assert_allclose(np.asarray(got.center["w"]),
                           np.asarray(ref.center["w"]), rtol=1e-6)
np.testing.assert_allclose(np.asarray(got.replicas["w"]),
                           np.asarray(ref.replicas["w"]), rtol=1e-6)
print("EASGD_OK")
""")
    assert "EASGD_OK" in out


def test_elastic_remesh_restore():
    """Checkpoint written under one mesh restores onto a DIFFERENT mesh
    shape with new shardings — the elastic-downscale path."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager

tmp = tempfile.mkdtemp()
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
w = jnp.arange(64.0).reshape(8, 8)
tree = {"w": jax.device_put(w, NamedSharding(mesh_a, P("data", "model"))),
        "b": jnp.arange(8.0, dtype=jnp.bfloat16)}
mgr = CheckpointManager(tmp)
mgr.save(7, tree)
# restore under the re-shaped mesh
new_sh = {"w": NamedSharding(mesh_b, P("data", "model")),
          "b": NamedSharding(mesh_b, P())}
out = mgr.restore(jax.tree.map(jnp.zeros_like, tree), shardings=new_sh)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
np.testing.assert_array_equal(np.asarray(out["b"], np.float32),
                              np.arange(8.0, dtype=np.float32))
assert out["w"].sharding.mesh.shape["data"] == 2   # lives on the NEW mesh
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


def test_async_cached_step_on_data_mesh_routes_shared_rows():
    """The overlapped cached train step on the 8-fake-device mesh: the
    batch is sharded over the data axis with the SAME global row planted on
    every replica's shard, so gradient aggregation + dirty writeback must
    route duplicate-row contributions across replicas. The materialized
    capacity tier must match the single-device run exactly."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.nn.params import init_params
from repro.optim import adagrad
from repro.train.steps import (build_async_cached_dlrm_train_step,
                               cached_dlrm_init_state)

cfg = get_smoke_config("dlrm-m1")
ebc = EmbeddingBagCollection.build(cfg, n_shards=1, strategy="replicated")
params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
opt = adagrad(0.01)
mesh = jax.make_mesh((8,), ("data",))
N, B = 4, 16
batches = []
for t in range(N):
    raw = make_dlrm_batch(cfg, B, step=t)
    idx = np.array(ebc.offset_indices(jnp.asarray(raw["idx"])))
    hot = int(idx[idx >= 0][0])
    idx[:, 0, 0] = hot          # same row on every data-parallel replica
    batches.append({"dense": jnp.asarray(raw["dense"]), "idx": idx,
                    "label": jnp.asarray(raw["label"])})

def run(sharded):
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=512)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    state = cached_dlrm_init_state(cc, opt, params)
    astate = cc.init_async_state(params["emb"]["mega"])
    step = build_async_cached_dlrm_train_step(cfg, cc, opt)
    losses = []
    for t in range(N):
        b = dict(batches[t])
        if sharded:
            b["dense"] = jax.device_put(
                b["dense"], NamedSharding(mesh, P("data", None)))
            b["label"] = jax.device_put(
                b["label"], NamedSharding(mesh, P("data")))
        nxt = batches[t + 1] if t + 1 < N else None
        with mesh:
            dense, state, m = step(dense, state, astate, b,
                                   jnp.asarray(t, jnp.int32),
                                   next_batch=nxt)
        losses.append(float(m["loss"]))
    mega, accum = cc.materialize_async(astate)
    return losses, np.asarray(mega), np.asarray(accum)

l1, m1, a1 = run(False)
l2, m2, a2 = run(True)
np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-7)
np.testing.assert_allclose(m1, m2, rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(a1, a2, rtol=1e-6, atol=1e-6)
print("ASYNC_MESH_OK")
""")
    assert "ASYNC_MESH_OK" in out


def test_pallas_embedding_bag_inside_shard_map():
    """The Pallas kernel body (interpret mode) composes with shard_map —
    the per-shard PS lookup path on real TPUs."""
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.kernels import ops, ref

mesh = jax.make_mesh((4,), ("model",))
H, D, B, L = 64, 16, 8, 5          # 16 rows per shard
rng = np.random.RandomState(0)
table = jnp.asarray(rng.randn(H, D), jnp.float32)
idx = jnp.asarray(rng.randint(-1, H, size=(B, L)), jnp.int32)

def local(table_sh, idx_rep):
    shard = jax.lax.axis_index("model")
    lo = shard * (H // 4)
    loc = jnp.where((idx_rep >= lo) & (idx_rep < lo + H // 4),
                    idx_rep - lo, -1)
    part = ops.embedding_bag(table_sh, loc, "sum", None, True)
    return jax.lax.psum(part, "model")

with mesh:
    # check_vma=False: pallas_call's out_shape carries no varying-axes
    # metadata (kernel outputs are shard-local by construction)
    got = jax.jit(shard_map(local, mesh=mesh,
                            in_specs=(P("model", None), P(None, None)),
                            out_specs=P(None, None),
                            check_vma=False))(table, idx)
want = ref.embedding_bag_ref(table, idx, "sum")
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
print("KERNEL_SHARDMAP_OK")
""")
    assert "KERNEL_SHARDMAP_OK" in out
