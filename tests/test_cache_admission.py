"""Frequency-aware cache management (docs/cache.md "EMA admission"):
EMA-seeded admission, the adaptive admission gate, the ids-by-frequency
reorder, and chunk-granular capacity<->cache transfers.

Covers the PR's contracts: admission is MONOTONE in a row's access
frequency (hypothesis property over `_gate_admission`), a one-off cold
burst cannot evict the Zipf head (the thrash scenario first-touch loses),
and chunked transfers are bit-exact vs per-row transfers (admission
changes *which* rows are cached, never lookup values).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAS_HYPOTHESIS, requires_hypothesis
from repro.configs import get_smoke_config
from repro.core.cache import (CachedEmbeddingBagCollection, _chunk_min_fill,
                              _gate_admission)
from repro.core.embedding import EmbeddingBagCollection
from repro.core.placement import frequency_reorder
from repro.data.pipeline import dedup_indices_hook, sparse_plan_hook
from repro.kernels.sparse_plan import coalesce_rows

if HAS_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st

# exercised on BOTH jax floors (the CI 0.4.37 leg runs `-m compat`): the
# chunked transfer path drives the kernels/compat.py shim surfaces
pytestmark = pytest.mark.compat


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("dlrm-m1")


@pytest.fixture(scope="module")
def ebc(cfg):
    return EmbeddingBagCollection.build(cfg, n_shards=1,
                                        strategy="replicated")


def _rand_mega(cfg, ebc, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(ebc.plan.total_rows, cfg.embed_dim)
                       .astype(np.float32))


def _rand_idx(rng, total, shape=(2, 3, 4)):
    idx = rng.randint(0, total, size=shape).astype(np.int64)
    idx[rng.rand(*shape) < 0.1] = -1           # pads
    return idx


# ---------------------------------------------------------------------------
# admission gate: monotone in access frequency
# ---------------------------------------------------------------------------


def _check_monotone(data):
    """If a row admits, every candidate with a STRICTLY higher EMA score
    admits too — admission is monotone in access frequency."""
    c = data.draw(st.integers(2, 24), label="cache_slots")
    n_res = data.draw(st.integers(0, c), label="residents")
    slot_row = np.full((c,), -1, np.int64)
    slot_row[:n_res] = np.arange(n_res)
    freq = np.array(data.draw(st.lists(
        st.floats(0.0, 50.0), min_size=c, max_size=c)), np.float32)
    protect = np.zeros((c,), bool)
    prot_ix = data.draw(st.lists(st.integers(0, c - 1), max_size=c),
                        label="protect")
    protect[prot_ix] = True
    n = data.draw(st.integers(1, 16), label="candidates")
    missing = 1000 + np.arange(n)
    scores = np.array(data.draw(st.lists(
        st.floats(0.0, 50.0), min_size=n, max_size=n)), np.float32)
    admit = _gate_admission(slot_row, freq, protect, missing, scores)
    for a in range(n):
        for b in range(n):
            if admit[b] and scores[a] > scores[b]:
                assert admit[a], (scores, admit)


if HAS_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(data=st.data())
    def test_admission_monotone_in_frequency(data):
        _check_monotone(data)
else:
    @requires_hypothesis
    def test_admission_monotone_in_frequency():
        """Placeholder so the property shows as SKIPPED, not absent."""


def test_admission_gate_prefers_hot_candidates():
    """With 2 free slots and 3 candidates, the two hottest admit; beyond
    the free slots a candidate admits only by strictly beating the coldest
    unprotected resident."""
    c = 4
    slot_row = np.array([7, 8, -1, -1], np.int64)   # 2 residents, 2 free
    freq = np.array([5.0, 1.0, 0.0, 0.0], np.float32)
    protect = np.zeros((c,), bool)
    missing = np.array([100, 101, 102])
    scores = np.array([0.5, 9.0, 3.0], np.float32)
    admit = _gate_admission(slot_row, freq, protect, missing, scores)
    # top-2 by score fill the free slots; 0.5 does not beat resident 1.0
    assert admit.tolist() == [False, True, True]
    # raise the cold candidate above the coldest resident: now it admits
    scores = np.array([1.5, 9.0, 3.0], np.float32)
    admit = _gate_admission(slot_row, freq, protect, missing, scores)
    assert admit.tolist() == [True, True, True]
    # protected residents are not evictable: only the freq-5.0 slot
    # remains a victim, and 1.5 does not beat it
    protect = np.array([False, True, False, False])
    scores = np.array([1.5, 9.0, 3.0], np.float32)
    admit = _gate_admission(slot_row, freq, protect, missing, scores)
    assert admit.tolist() == [False, True, True]


def test_cold_burst_cannot_evict_zipf_head(cfg, ebc):
    """The thrash scenario the EMA gate exists for: a one-off cold burst
    (every row EMA ~1) prefetched with gate=True admits nothing over the
    established head, while the ungated legacy path would churn the whole
    cache."""
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=32)
    st_ = cc.init_state(_rand_mega(cfg, ebc))
    head = np.arange(16)
    mid = np.arange(100, 116)
    for _ in range(6):                         # establish the hot head
        cc.prepare(st_, np.tile(head, 3).reshape(1, 1, 48), train=False)
    # fill the remaining slots; cache is now full, every resident freq >= 1
    cc.prepare(st_, np.concatenate([head, mid]).reshape(1, 1, 32),
               train=False)
    assert (st_.row_slot[head] >= 0).all()
    assert (st_.row_slot[mid] >= 0).all()
    cold = np.arange(500, 564)                 # one-off burst, 2x the cache
    admitted = cc.prefetch(st_, cold, gate=True)
    assert admitted == 0                       # seed 1.0 beats no resident
    assert (st_.row_slot[head] >= 0).all()
    assert (st_.row_slot[mid] >= 0).all()
    # the ungated path (pre-EMA behaviour) would have churned the head
    admitted = cc.prefetch(st_, cold, gate=False)
    assert admitted == 32
    assert (st_.row_slot[head] < 0).all()


def test_strict_planned_batches_never_gate(cfg, ebc):
    """Bit-exactness contract: every row of a PLANNED batch becomes
    resident regardless of its EMA score (the gate is best-effort only)."""
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=32)
    st_ = cc.init_state(_rand_mega(cfg, ebc))
    for _ in range(4):
        cc.prepare(st_, np.arange(16).reshape(1, 1, 16), train=False)
    cold = np.arange(500, 532)
    local = cc.prepare(st_, cold.reshape(1, 1, 32), train=False)
    assert (st_.row_slot[cold] >= 0).all()
    assert (local >= 0).all()


def test_ema_readmission_outlives_cold_burst(cfg, ebc):
    """A periodically-returning row re-admits at its HISTORICAL frequency
    under EMA seeding, but at ~its batch count under first-touch — the
    seed difference the admission bench measures."""
    out = {}
    for ema in (True, False):
        cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=32,
                                                ema_admission=ema)
        st_ = cc.init_state(_rand_mega(cfg, ebc))
        hot = np.arange(8)
        for _ in range(8):                     # hot rows, count 4 per step
            cc.prepare(st_, np.tile(hot, 4).reshape(1, 1, 32), train=False)
        # evict the hot rows via a full-cache batch of strangers
        cc.prepare(st_, np.arange(200, 232).reshape(1, 1, 32), train=False)
        assert (st_.row_slot[hot] < 0).all()
        # hot rows return ONCE each: EMA re-seeds them near their
        # historical rate, first-touch at their in-batch count (1)
        cc.prepare(st_, hot.reshape(1, 1, 8), train=False)
        out[ema] = np.asarray(st_.freq)[st_.row_slot[hot]].copy()
    assert (out[True] > 3.0).all()             # ~steady EMA of count-4 rows
    assert (out[False] == 1.0).all()           # in-batch count


# ---------------------------------------------------------------------------
# chunk-granular transfers: coalescing + bit-exactness
# ---------------------------------------------------------------------------


def test_coalesce_rows_min_fill_drops_sparse_blocks():
    rows = np.array([0, 1, 2, 3, 100, 200, 201, 202, 203], np.int64)
    starts, pos = coalesce_rows(rows, 4, 1000, min_fill=3)
    assert starts.tolist() == [0, 200]
    # dense runs keep their in-block positions; the isolated row drops
    assert pos.tolist() == [0, 1, 2, 3, -1, 4, 5, 6, 7]
    # min_fill=1 keeps every block (pure fixed-chunk coverage)
    starts, pos = coalesce_rows(rows, 4, 1000, min_fill=1)
    assert starts.tolist() == [0, 100, 200]
    assert (pos >= 0).all()


def test_coalesce_rows_clamps_trailing_block():
    rows = np.array([998, 999], np.int64)
    starts, pos = coalesce_rows(rows, 4, 1000, min_fill=2)
    assert starts.tolist() == [996]            # start+chunk <= total_rows
    assert pos.tolist() == [2, 3]


def test_chunk_min_fill_floor():
    assert _chunk_min_fill(2) == 2
    assert _chunk_min_fill(8) == 6             # ~3/4 full
    assert _chunk_min_fill(16) == 12


@pytest.mark.parametrize("interpret", [False, True])
def test_chunked_transfers_bit_exact_sync(cfg, ebc, interpret):
    """fetch_chunk>1 changes the transfer SHAPE, never lookup values:
    per-step outputs equal the per-row collection's bit-for-bit, on mixed
    dense-run + scattered traffic."""
    mega = _rand_mega(cfg, ebc)
    ccs = [CachedEmbeddingBagCollection.build(cfg, cache_rows=64,
                                              fetch_chunk=chunk,
                                              interpret=interpret)
           for chunk in (1, 8)]
    states = [cc.init_state(mega) for cc in ccs]
    rng = np.random.RandomState(3)
    total = ebc.plan.total_rows
    for step in range(4):
        idx = _rand_idx(rng, total)
        if step % 2 == 0:                      # dense contiguous run
            idx[0, 0, :] = np.arange(40, 44)
        outs = [cc.lookup(st_, idx, train=False)
                for cc, st_ in zip(ccs, states)]
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]))
    assert states[1].stats.fetch_chunks > 0
    assert states[1].stats.fetch_chunks <= states[1].stats.fetches
    assert states[0].stats.fetch_chunks == 0


def test_chunked_transfers_bit_exact_async(cfg, ebc):
    """The async stream's chunked shadow fetch commits bit-identically."""
    mega = _rand_mega(cfg, ebc)
    ccs = [CachedEmbeddingBagCollection.build(cfg, cache_rows=64,
                                              fetch_chunk=chunk)
           for chunk in (1, 8)]
    states = [cc.init_async_state(mega) for cc in ccs]
    rng = np.random.RandomState(4)
    total = ebc.plan.total_rows
    batches = [_rand_idx(rng, total) for _ in range(4)]
    batches[0][0, 0, :] = np.arange(8, 12)
    for b in batches:
        outs = [cc.lookup_async(st_, b, train=False)
                for cc, st_ in zip(ccs, states)]
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      np.asarray(outs[1]))
    assert states[1].stats.fetch_chunks > 0


def test_chunked_overfetch_bounded(cfg, ebc):
    """The density-adaptive fallback keeps block padding below 1/3 of the
    fetched rows (the _chunk_min_fill contract) on scattered traffic."""
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=64,
                                            fetch_chunk=8)
    st_ = cc.init_state(_rand_mega(cfg, ebc))
    rng = np.random.RandomState(5)
    for _ in range(6):
        cc.lookup(st_, _rand_idx(rng, ebc.plan.total_rows), train=False)
    assert st_.stats.overfetch_rows <= st_.stats.fetches / 3 + 8


# ---------------------------------------------------------------------------
# ids-by-frequency reorder + pipeline remap
# ---------------------------------------------------------------------------


def test_frequency_reorder_head_contiguous():
    offs, sizes = [0, 10], [10, 6]
    freq = np.zeros((16,))
    freq[[3, 7, 9]] = [5, 9, 2]                # table 0 head
    freq[[12, 15]] = [4, 1]                    # table 1 head
    remap, inverse = frequency_reorder(offs, sizes, freq, 16)
    # hottest ids land at each table's row 0, in descending order
    assert remap[7] == 0 and remap[3] == 1 and remap[9] == 2
    assert remap[12] == 10 and remap[15] == 11
    # per-table bijection: each table's span maps onto itself
    assert sorted(remap[:10].tolist()) == list(range(10))
    assert sorted(remap[10:].tolist()) == list(range(10, 16))
    # inverse really inverts (the weight-permutation side)
    assert (inverse[remap] == np.arange(16)).all()
    # stable: untouched ids keep their relative order
    rest = [int(remap[i]) for i in [0, 1, 2, 4, 5, 6, 8]]
    assert rest == sorted(rest)


def test_frequency_reorder_validates_shape():
    with pytest.raises(ValueError):
        frequency_reorder([0], [4], np.zeros((3,)), 4)


def test_dedup_hook_row_remap(cfg, ebc):
    """The reader-thread remap: global rows permute BEFORE dedup/plan
    building, pads survive, and the remapped ids equal remap[original]."""
    offs = ebc.plan.table_offsets
    total = ebc.plan.total_rows
    rng = np.random.RandomState(6)
    freq = rng.rand(total)
    remap, _ = frequency_reorder(offs, cfg.hash_sizes, freq, total)
    raw = rng.randint(0, min(cfg.hash_sizes), size=(2, len(offs), 3))
    raw[0, 0, 0] = -1
    plain = dedup_indices_hook(offs)({"idx": raw.copy()})
    mapped = dedup_indices_hook(offs, row_remap=remap)({"idx": raw.copy()})
    valid = plain["idx"] >= 0
    assert (mapped["idx"][valid] == remap[plain["idx"][valid]]).all()
    assert (mapped["idx"][~valid] == -1).all()
    assert (mapped["uniq_rows"]
            == np.unique(remap[plain["idx"][valid]])).all()
    # the plan hook builds its SparsePlan over the REMAPPED row space
    planned = sparse_plan_hook(offs, row_remap=remap)({"idx": raw.copy()})
    prows = np.asarray(planned["plan_rows"])
    live = prows[prows >= 0]
    assert (live == np.unique(remap[plain["idx"][valid]])).all()
