"""Async cache-exchange stream (core/cache.py AsyncCacheState +
kernels/cache_ops.py fetch/commit pair + train/steps.py overlapped step).

The contract under test: the overlapped schedule — batch k+1's miss rows
fetched into a shadow slab while batch k computes, committed at the step
boundary — is BIT-IDENTICAL to the synchronous cache_exchange path: same
indices, same AdaGrad state, identical outputs (losses, dense params,
materialized capacity tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.kernels import cache_ops, ref
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_async_cached_dlrm_train_step,
                               build_cached_dlrm_train_step,
                               cached_dlrm_init_state)

# exercised on BOTH jax floors: this module drives the compat-shim surfaces
# (Pallas memory spaces, shard_map, kernel interpret paths) — see pyproject
# markers and the CI jax-floor leg
pytestmark = pytest.mark.compat


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("dlrm-m1")


@pytest.fixture(scope="module")
def ebc(cfg):
    return EmbeddingBagCollection.build(cfg, n_shards=1,
                                        strategy="replicated")


def _batch_idx(cfg, ebc, step, batch=8):
    raw = make_dlrm_batch(cfg, batch, step=step)
    return np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))


def _worklist(rng):
    """A hand worklist exercising every entry kind: writeback+fetch,
    fetch-only, writeback-only (fetch=-1 keeps the slot), full pad."""
    capacity = jnp.asarray(rng.randn(40, 48), jnp.float32)
    cache = jnp.asarray(rng.randn(8, 48), jnp.float32)
    cap_acc = jnp.asarray(rng.rand(40), jnp.float32)
    cache_acc = jnp.asarray(rng.rand(8), jnp.float32)
    freq = jnp.asarray(rng.rand(8), jnp.float32)
    slots = jnp.asarray([0, 2, 3, -1, 5, 7], jnp.int32)
    evict = jnp.asarray([10, -1, 12, -1, -1, 13], jnp.int32)
    fetch = jnp.asarray([20, 21, -1, -1, 22, 23], jnp.int32)
    counts = jnp.asarray([3, 1, 0, 0, 2, 5], jnp.float32)
    return capacity, cache, cap_acc, cache_acc, freq, slots, evict, fetch, \
        counts


def _cp(x):
    return jnp.array(x, copy=True)


# ---------------------------------------------------------------------------
# split kernels vs oracle / vs the fused exchange
# ---------------------------------------------------------------------------


def test_fetch_then_commit_equals_fused_exchange(rng):
    (capacity, cache, cap_acc, cache_acc, freq, slots, evict, fetch,
     counts) = _worklist(rng)
    want = ref.cache_exchange_ref(capacity, cache, cap_acc, cache_acc, freq,
                                  slots, evict, fetch, counts)
    shadow, shadow_acc = cache_ops.cache_fetch(capacity, cap_acc, fetch)
    got = cache_ops.cache_commit(_cp(capacity), _cp(cache), _cp(cap_acc),
                                 _cp(cache_acc), shadow, shadow_acc,
                                 slots, evict, fetch)
    for w, g in zip(want[:4], got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_fetch_kernel_matches_ref_interpret(rng):
    capacity, _, cap_acc, _, _, _, _, fetch, _ = _worklist(rng)
    want_s, want_a = ref.cache_fetch_ref(capacity, cap_acc, fetch)
    got_s, got_a = cache_ops.cache_fetch(capacity, cap_acc, fetch,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(want_s), np.asarray(got_s))
    np.testing.assert_array_equal(np.asarray(want_a), np.asarray(got_a))
    # -1 pad rows come back zeroed, not garbage
    np.testing.assert_array_equal(np.asarray(got_s)[2], 0.0)
    np.testing.assert_array_equal(np.asarray(got_s)[3], 0.0)


def test_commit_kernel_matches_ref_interpret(rng):
    (capacity, cache, cap_acc, cache_acc, _, slots, evict, fetch,
     _) = _worklist(rng)
    shadow, shadow_acc = ref.cache_fetch_ref(capacity, cap_acc, fetch)
    want = ref.cache_commit_ref(capacity, cache, cap_acc, cache_acc,
                                shadow, shadow_acc, slots, evict, fetch)
    got = cache_ops.cache_commit(_cp(capacity), _cp(cache), _cp(cap_acc),
                                 _cp(cache_acc), shadow, shadow_acc,
                                 slots, evict, fetch, interpret=True)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_commit_writeback_only_entry_keeps_slot(rng):
    """fetch=-1 entries write the victim back WITHOUT clobbering the slot —
    the flush-shaped worklist."""
    capacity = jnp.zeros((10, 4), jnp.float32)
    cache = jnp.asarray(rng.randn(4, 4), jnp.float32)
    cap_acc = jnp.zeros((10,), jnp.float32)
    cache_acc = jnp.asarray(rng.rand(4), jnp.float32)
    shadow = jnp.zeros((1, 4), jnp.float32)
    shadow_acc = jnp.zeros((1,), jnp.float32)
    new_cap, new_cache, new_ca, new_cc = cache_ops.cache_commit(
        _cp(capacity), _cp(cache), _cp(cap_acc), _cp(cache_acc),
        shadow, shadow_acc, jnp.asarray([2], jnp.int32),
        jnp.asarray([7], jnp.int32), jnp.asarray([-1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(new_cap)[7],
                                  np.asarray(cache)[2])
    np.testing.assert_array_equal(np.asarray(new_cache), np.asarray(cache))
    assert float(new_ca[7]) == float(cache_acc[2])


# ---------------------------------------------------------------------------
# async manager: lookup equivalence on the overlapped schedule
# ---------------------------------------------------------------------------


def test_async_lookup_equals_uncached_exact(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=320)
    astate = cc.init_async_state(params["mega"])
    streams = [_batch_idx(cfg, ebc, s) for s in range(8)]
    local = cc.take_async(astate, streams[0], train=False)
    for k in range(8):
        want = ebc.lookup(params, jnp.asarray(streams[k]))
        got = cc.ebc.lookup({"mega": astate.cache}, jnp.asarray(local))
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
        if k + 1 < 8:
            # overlapped schedule: stage k+1 while k is "in flight"
            cc.stage_async(astate, streams[k + 1], train=False)
            local = cc.take_async(astate, streams[k + 1], train=False)
    assert astate.stats.evictions > 0          # the sweep really evicted
    assert astate.stats.writebacks == 0        # read-only: nothing dirty


def test_lookup_async_wrapper_matches_sync_manager(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=320)
    astate = cc.init_async_state(params["mega"])
    state = cc.init_state(params["mega"])
    for step in range(4):
        idx = _batch_idx(cfg, ebc, step)
        got = cc.lookup_async(astate, idx, train=False)
        want = cc.lookup(state, idx, train=False)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_take_async_with_mismatched_staged_plan_recovers(cfg, ebc):
    """A staged plan for a batch that never arrives degrades to a prefetch:
    take plans the actual batch on the spot and the lookup stays exact."""
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=320)
    astate = cc.init_async_state(params["mega"])
    cc.stage_async(astate, _batch_idx(cfg, ebc, 5), train=False)
    actual = _batch_idx(cfg, ebc, 6)
    local = cc.take_async(astate, actual, train=False)
    want = ebc.lookup(params, jnp.asarray(actual))
    got = cc.ebc.lookup({"mega": astate.cache}, jnp.asarray(local))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    assert astate.staged is None
    assert not astate.pending                  # take committed everything
    # the discarded plan is re-booked as a prefetch: only the real batch
    # counts toward steps/hits/misses (no phantom-step stat skew)
    assert astate.stats.steps == 1
    n_actual = len(np.unique(actual[actual >= 0]))
    assert astate.stats.misses <= n_actual     # some rows prefetched by
    assert astate.stats.prefetched > 0         # the mismatched plan
    accesses = int((actual >= 0).sum())
    assert astate.stats.hits + astate.stats.misses == accesses


# ---------------------------------------------------------------------------
# overlapped train step: bit-exact vs the synchronous path
# ---------------------------------------------------------------------------


def _run_cached_training(cfg, ebc, params, mode, n_steps=6):
    opt = adagrad(0.01)
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=320)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    state = cached_dlrm_init_state(cc, opt, params)
    batches = []
    for t in range(n_steps):
        raw = make_dlrm_batch(cfg, 8, step=t)
        batches.append({
            "dense": jnp.asarray(raw["dense"]),
            "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
            "label": jnp.asarray(raw["label"])})
    losses = []
    if mode == "sync":
        cs = cc.init_state(params["emb"]["mega"])
        step = build_cached_dlrm_train_step(cfg, cc, opt)
        for t in range(n_steps):
            dense, state, m = step(dense, state, cs, batches[t],
                                   jnp.asarray(t, jnp.int32))
            losses.append(float(m["loss"]))
        mega, accum = cc.materialize(cs)
        stats = cs.stats
    else:
        astate = cc.init_async_state(params["emb"]["mega"])
        step = build_async_cached_dlrm_train_step(
            cfg, cc, opt, strict_sync=(mode == "strict"))
        for t in range(n_steps):
            nxt = batches[t + 1] if t + 1 < n_steps else None
            dense, state, m = step(dense, state, astate, batches[t],
                                   jnp.asarray(t, jnp.int32), next_batch=nxt)
            losses.append(float(m["loss"]))
        mega, accum = cc.materialize_async(astate)
        stats = astate.stats
    return (losses, np.asarray(mega), np.asarray(accum),
            jax.tree.map(np.asarray, dense), stats)


def test_async_train_step_bit_exact_vs_sync(cfg, ebc):
    """The acceptance contract: overlapped and synchronous cached training
    produce bit-identical losses, dense params, capacity tier, and AdaGrad
    accumulators over a multi-step stream with evictions."""
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    l_s, m_s, a_s, d_s, st_s = _run_cached_training(cfg, ebc, params, "sync")
    l_a, m_a, a_a, d_a, st_a = _run_cached_training(cfg, ebc, params,
                                                    "async")
    assert st_s.evictions > 0                  # the stream really evicted
    np.testing.assert_array_equal(l_s, l_a)
    np.testing.assert_array_equal(m_s, m_a)
    np.testing.assert_array_equal(a_s, a_a)
    for k in ("bottom", "top"):
        for w, g in zip(jax.tree.leaves(d_s[k]), jax.tree.leaves(d_a[k])):
            np.testing.assert_array_equal(w, g)
    assert st_a.steps == st_s.steps


def test_strict_sync_fallback_flag_is_bit_exact_too(cfg, ebc):
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(1))
    l_s, m_s, a_s, _, _ = _run_cached_training(cfg, ebc, params, "sync")
    l_f, m_f, a_f, _, st_f = _run_cached_training(cfg, ebc, params, "strict")
    np.testing.assert_array_equal(l_s, l_f)
    np.testing.assert_array_equal(m_s, m_f)
    np.testing.assert_array_equal(a_s, a_f)
    assert st_f.prefetched == 0                # fallback never stages ahead


# ---------------------------------------------------------------------------
# planning invariants: thrash guard, protection, epochs, prefetch
# ---------------------------------------------------------------------------


def test_async_thrash_guard_raises(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=8)
    astate = cc.init_async_state(params["mega"])
    with pytest.raises(ValueError, match="cache_rows"):
        cc.take_async(astate, _batch_idx(cfg, ebc, 0))


def test_async_double_buffer_thrash_guard_mentions_lookahead(cfg, ebc):
    """Cache big enough for one working set but not two: the STAGED plan
    must refuse rather than evict in-flight rows."""
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    idx0, idx1 = _batch_idx(cfg, ebc, 0), _batch_idx(cfg, ebc, 1)
    ws = max(len(np.unique(idx0[idx0 >= 0])),
             len(np.unique(idx1[idx1 >= 0])))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=ws + 8)
    astate = cc.init_async_state(params["mega"])
    cc.take_async(astate, idx0, train=True)    # in-flight working set
    with pytest.raises(ValueError, match="in-flight"):
        cc.stage_async(astate, idx1, train=True)


def test_stage_rows_is_best_effort_and_drops_overflow(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=64)
    astate = cc.init_async_state(params["mega"])
    rows = np.arange(200, dtype=np.int64)      # 3x the cache
    admitted = cc.stage_rows(astate, rows)
    assert admitted == 64                      # fills the cache, drops rest
    assert astate.stats.prefetched == 64
    cc.commit_async(astate)
    assert astate.resident == 64
    # staged rows are protected until committed: a second best-effort call
    # right behind them admits nothing rather than evicting them
    astate2 = cc.init_async_state(params["mega"])
    cc.stage_rows(astate2, rows[:64])
    assert cc.stage_rows(astate2, rows[100:164]) == 0


def test_refetch_of_queued_dirty_victim_sees_fresh_value(cfg, ebc):
    """Two pipeline invariants of the lookahead (stage_rows) path:

    1. a row whose DIRTY eviction is still queued must not be re-fetched
       from the stale capacity tier — the planner drains the commit queue
       first so the writeback lands before the fetch reads;
    2. the drain clears the staged plan's queue entry, but the staged
       batch's slots must STAY protected (via astate.staged) — evicting
       one would silently invalidate its outstanding remap."""
    import dataclasses as dc
    tiny = dc.replace(cfg, n_sparse_features=1, hash_sizes=(64,),
                      mean_lookups=(4,), bottom_mlp=(8, 16), top_mlp=(8, 1))
    cc = CachedEmbeddingBagCollection.build(tiny, cache_rows=32)
    mega = jnp.zeros((cc.ebc.plan.total_rows, tiny.embed_dim), jnp.float32)
    astate = cc.init_async_state(mega)

    def batch_of(rows, rep=1):
        return np.repeat(np.asarray(rows, np.int32), rep).reshape(1, 1, -1)

    # train rows 0-7: their cached values become 1000.0, capacity stale 0.0
    local = cc.take_async(astate, batch_of(range(8)), train=True)
    cc.mark_updated(astate, astate.cache.at[np.unique(local)].set(1000.0),
                    astate.cache_accum)
    # rows 8-15 hot (count 4 per row) so the LFU never picks them before
    # rows 0-7; rows 16-23 become the in-flight working set
    cc.take_async(astate, batch_of(range(8, 16), rep=4), train=True)
    cc.take_async(astate, batch_of(range(16, 24)), train=True)
    # the staged plan needs 8 victims: the coldest unprotected slots are
    # dirty rows 0-7 — their writeback is now queued
    cc.stage_async(astate, batch_of(range(24, 40)), train=True)
    assert astate.pending, "plan should be queued"
    assert (astate.pending[-1].evict_rows >= 0).sum() == 8
    staged_slots_before = astate.row_slot[np.arange(24, 40)].copy()
    # lookahead prefetch of row 0 while its dirty writeback is still
    # queued: must drain (stale-fetch guard), then admit row 0 WITHOUT
    # touching the staged batch's slots (even though the drain just
    # removed their pending-queue protection)
    assert cc.stage_rows(astate, np.asarray([0])) == 1
    np.testing.assert_array_equal(astate.row_slot[np.arange(24, 40)],
                                  staged_slots_before)
    cc.take_async(astate, batch_of(range(24, 40)), train=True)
    # row 0's slot must hold the updated value, not the stale capacity row
    slot = astate.row_slot[0]
    assert slot >= 0
    np.testing.assert_array_equal(np.asarray(astate.cache[slot]), 1000.0)
    # and the capacity tier received the queued writeback (row 1 stays out)
    np.testing.assert_array_equal(np.asarray(astate.capacity[1]), 1000.0)


def test_epoch_tags_are_monotone_and_match_admissions(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=320)
    astate = cc.init_async_state(params["mega"])
    seen = []
    local = cc.take_async(astate, _batch_idx(cfg, ebc, 0), train=True)
    assert local is not None
    for k in range(1, 5):
        cc.stage_async(astate, _batch_idx(cfg, ebc, k), train=True)
        p = astate.pending[-1]
        assert p.epoch == astate.epoch
        # admitted slots carry this plan's epoch tag
        assert np.all(astate.slot_epoch[p.slots] == p.epoch)
        seen.append(p.epoch)
        cc.take_async(astate, _batch_idx(cfg, ebc, k), train=True)
    assert seen == sorted(seen)                # strictly advancing epochs


def test_staged_victims_never_in_flight(cfg, ebc):
    """The pipeline invariant behind bit-exactness: a slot admitted by the
    staged (epoch k+1) plan is never one the in-flight (epoch k) batch
    still reads or writes."""
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=240)
    astate = cc.init_async_state(params["mega"])
    cc.take_async(astate, _batch_idx(cfg, ebc, 0), train=True)
    evicting = 0
    for k in range(1, 8):
        inflight = astate.inflight_mask.copy()
        cc.stage_async(astate, _batch_idx(cfg, ebc, k), train=True)
        p = astate.pending[-1]
        evicting += len(p.victim_slots)
        assert not inflight[p.victim_slots].any()
        assert not inflight[p.slots].any()
        cc.take_async(astate, _batch_idx(cfg, ebc, k), train=True)
    assert evicting > 0                        # the invariant was exercised
