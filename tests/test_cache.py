"""Cached embedding tier (core/cache.py + kernels/cache_ops.py).

Covers the acceptance contract: cached lookup is EXACTLY equal to the
uncached mega-table lookup (fp32), hit/miss accounting is deterministic,
eviction-writeback round-trips training updates, and the cached_host
placement sizes the device cache from the HBM budget.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.core.placement import CACHED_ROW_META_BYTES, plan_placement
from repro.data.pipeline import DataPipeline, dedup_indices_hook
from repro.data.synthetic import bounded_zipf_rows, make_dlrm_batch
from repro.kernels import cache_ops, ops, ref
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_cached_dlrm_train_step,
                               cached_dlrm_init_state)

# exercised on BOTH jax floors: this module drives the compat-shim surfaces
# (Pallas memory spaces, shard_map, kernel interpret paths) — see pyproject
# markers and the CI jax-floor leg
pytestmark = pytest.mark.compat


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("dlrm-m1")


@pytest.fixture(scope="module")
def ebc(cfg):
    return EmbeddingBagCollection.build(cfg, n_shards=1,
                                        strategy="replicated")


def _batch_idx(cfg, ebc, step, batch=8):
    raw = make_dlrm_batch(cfg, batch, step=step)
    return np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))


# ---------------------------------------------------------------------------
# placement: cached_host capacity math
# ---------------------------------------------------------------------------


def test_plan_cached_host_capacity_math():
    d, itemsize = 64, 4
    budget = 1_000_000.0
    plan = plan_placement([5000, 7000, 100], [8, 2, 30], d, 4, budget,
                          itemsize=itemsize, strategy="cached_host")
    assert plan.strategy == "cached_host"
    assert plan.cache_rows % 8 == 0
    assert plan.cache_rows <= plan.total_rows
    row_bytes = d * itemsize + CACHED_ROW_META_BYTES
    assert plan.cache_rows * row_bytes <= budget
    # one more row row-group would overflow the budget
    assert (plan.cache_rows + 8) * row_bytes > budget
    # capacity tier is replicated (host-resident) — no model-axis sharding
    assert plan.pspec == jax.sharding.PartitionSpec(None, None)


def test_plan_cached_host_budget_covers_table():
    plan = plan_placement([100, 200], [1, 1], 16, 1, 1e12,
                          strategy="cached_host")
    assert plan.cache_rows == plan.total_rows     # degenerate: full cache


def test_host_offload_alias_maps_to_cached_host():
    plan = plan_placement([100, 200], [1, 1], 16, 1, 1e6,
                          strategy="host_offload")
    assert plan.strategy == "cached_host"
    assert plan.cache_rows > 0


# ---------------------------------------------------------------------------
# lookup equivalence + hit/miss accounting
# ---------------------------------------------------------------------------


def test_cached_lookup_equals_uncached_exact(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=160)
    state = cc.init_state(params["mega"])
    for step in range(6):   # cache (160) < working set churn -> evictions
        idx = _batch_idx(cfg, ebc, step)
        want = ebc.lookup(params, jnp.asarray(idx))
        got = cc.lookup(state, idx, train=False)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    assert state.stats.evictions > 0              # the sweep really evicted
    assert state.stats.writebacks == 0            # read-only: nothing dirty


def test_cold_then_hot_counters(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
    state = cc.init_state(params["mega"])
    idx = _batch_idx(cfg, ebc, 0)
    uniq = len(np.unique(idx[idx >= 0]))
    accesses = int((idx >= 0).sum())
    cc.prepare(state, idx, train=False)
    # cold: one miss (= one fetch) per unique row; duplicate accesses of a
    # fetched row are served from the just-filled slot
    assert state.stats.misses == uniq
    assert state.stats.fetches == uniq
    assert state.stats.hits == accesses - uniq
    cc.prepare(state, idx, train=False)
    # hot: the identical batch hits every access
    assert state.stats.misses == uniq
    assert state.stats.hits == 2 * accesses - uniq
    assert state.stats.hit_rate > 0.5


def test_lfu_evicts_the_cold_slot():
    cfg = dataclasses.replace(
        get_smoke_config("dlrm-m1"),
        n_sparse_features=1, hash_sizes=(64,), mean_lookups=(2,),
        bottom_mlp=(8, 16), top_mlp=(8, 1))
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                      strategy="replicated")
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=2)
    mega = jnp.arange(ebc.plan.total_rows * cfg.embed_dim,
                      dtype=jnp.float32).reshape(-1, cfg.embed_dim)
    state = cc.init_state(mega)

    def prep(rows):
        idx = np.asarray(rows, np.int32).reshape(1, 1, -1)
        cc.prepare(state, idx, train=False)

    prep([5, 9])            # fill both slots
    prep([5])               # row 5 is now hotter than row 9
    prep([7])               # needs a slot: must evict the cold row 9
    assert state.row_slot[5] >= 0
    assert state.row_slot[7] >= 0
    assert state.row_slot[9] < 0


# ---------------------------------------------------------------------------
# training: eviction-writeback round trip
# ---------------------------------------------------------------------------


def test_eviction_writeback_roundtrip_matches_uncached_training(cfg, ebc):
    """Sparse updates applied to cached rows, flushed through evictions +
    final flush, equal the same updates applied directly to the full table
    (and so the post-flush uncached lookup matches too)."""
    lr, steps = 0.05, 5
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(1))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=160)
    state = cc.init_state(params["mega"])

    mega_ref = params["mega"]
    accum_ref = jnp.zeros((ebc.plan.total_rows,), jnp.float32)
    rng = np.random.RandomState(0)
    for step in range(steps):
        idx = _batch_idx(cfg, ebc, step)
        g_pooled = jnp.asarray(
            rng.randn(*idx.shape[:2], cfg.embed_dim), jnp.float32)
        # cached: remap -> update cache rows (marked dirty by prepare)
        local = cc.prepare(state, idx, train=True)
        fi, fg = ebc.per_lookup_grads(jnp.asarray(local), g_pooled)
        new_cache, new_accum = ops.rowwise_adagrad_update(
            state.cache, state.cache_accum, fi, fg, lr)
        cc.mark_updated(state, new_cache, new_accum)
        # uncached reference: same math on the full table with global rows
        fi_r, fg_r = ebc.per_lookup_grads(jnp.asarray(idx), g_pooled)
        mega_ref, accum_ref = ops.rowwise_adagrad_update(
            mega_ref, accum_ref, fi_r, fg_r, lr)
    assert state.stats.writebacks > 0             # evictions flushed rows
    mega_c, accum_c = cc.materialize(state)
    np.testing.assert_allclose(np.asarray(mega_c), np.asarray(mega_ref),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(accum_c), np.asarray(accum_ref),
                               rtol=0, atol=1e-6)
    # idle flush: nothing dirty remains
    assert cc.flush(state) == 0


def test_cached_train_step_runs_and_reports_cache_metrics(cfg, ebc):
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
    opt = adagrad(0.01)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    cstate = cached_dlrm_init_state(cc, opt, params)
    cache_state = cc.init_state(params["emb"]["mega"])
    step = build_cached_dlrm_train_step(cfg, cc, opt)
    losses = []
    for t in range(4):
        raw = make_dlrm_batch(cfg, 8, step=t)
        b = {"dense": jnp.asarray(raw["dense"]),
             "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
             "label": jnp.asarray(raw["label"])}
        dense, cstate, m = step(dense, cstate, cache_state, b,
                                jnp.asarray(t, jnp.int32))
        losses.append(float(m["loss"]))
        assert 0.0 <= m["cache_hit_rate"] <= 1.0
    assert losses[-1] < losses[0]                 # planted signal learns
    assert cache_state.stats.steps == 4


def test_checkpoint_restore_resumes_bit_exact(cfg, ebc, tmp_path):
    """Interrupt a cached-tier run mid-stream, round-trip the WHOLE tier
    (device slabs + host slot maps + EMA + stats) through the real
    CheckpointManager, and resume: every later loss and the final
    materialized table must be BIT-EQUAL to the uninterrupted run. A
    params-only checkpoint cannot pass this — the accumulators of cached
    rows live per-slot, so losing row_slot/cache_accum changes the AdaGrad
    trajectory after restore."""
    from repro.train.checkpoint import CheckpointManager

    def fresh():
        params = init_params(dlrm_param_specs(cfg, ebc),
                             jax.random.PRNGKey(7))
        cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
        opt = adagrad(0.01)
        dense = {"bottom": params["bottom"], "top": params["top"]}
        return (cc, opt, dense, cached_dlrm_init_state(cc, opt, params),
                cc.init_state(params["emb"]["mega"]))

    def batch(t):
        raw = make_dlrm_batch(cfg, 8, step=t)
        return {"dense": jnp.asarray(raw["dense"]),
                "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
                "label": jnp.asarray(raw["label"])}

    total, cut = 6, 3

    # uninterrupted reference
    cc, opt, dense, cstate, cache_state = fresh()
    step = build_cached_dlrm_train_step(cfg, cc, opt)
    ref_losses = []
    for t in range(total):
        dense, cstate, m = step(dense, cstate, cache_state, batch(t),
                                jnp.asarray(t, jnp.int32))
        ref_losses.append(float(m["loss"]))
    ref_mega, ref_accum = cc.materialize(cache_state)
    ref_dense = dense

    # interrupted run: save at `cut`, restore into FRESH objects, resume
    cc, opt, dense, cstate, cache_state = fresh()
    step = build_cached_dlrm_train_step(cfg, cc, opt)
    for t in range(cut):
        dense, cstate, m = step(dense, cstate, cache_state, batch(t),
                                jnp.asarray(t, jnp.int32))
        assert float(m["loss"]) == ref_losses[t]
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(cut, {"dense": dense, "opt": cstate,
                   "cache": cc.state_dict(cache_state)})

    cc2, opt2, dense2, cstate2, cache2 = fresh()   # restart from scratch
    tree = mgr.restore({"dense": dense2, "opt": cstate2,
                        "cache": cc2.state_dict(cache2)}, step=cut)
    dense2, cstate2 = tree["dense"], tree["opt"]
    cache2 = cc2.load_state_dict(tree["cache"])
    assert dataclasses.asdict(cache2.stats) == \
        dataclasses.asdict(cache_state.stats)
    step2 = build_cached_dlrm_train_step(cfg, cc2, opt2)
    for t in range(cut, total):
        dense2, cstate2, m = step2(dense2, cstate2, cache2, batch(t),
                                   jnp.asarray(t, jnp.int32))
        assert float(m["loss"]) == ref_losses[t]
    mega2, accum2 = cc2.materialize(cache2)
    np.testing.assert_array_equal(np.asarray(mega2), np.asarray(ref_mega))
    np.testing.assert_array_equal(np.asarray(accum2), np.asarray(ref_accum))
    for a, b in zip(jax.tree.leaves(ref_dense), jax.tree.leaves(dense2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_state_dict_drains_staged_and_roundtrips(cfg, ebc):
    """Snapshotting an AsyncCacheState with a staged-but-unconsumed plan
    must drain the pending queue and unwind the staged stats (the plan
    degrades to a prefetch, as take_async does on an idx mismatch); the
    restored state then continues bit-identically to the mutated
    original."""
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(2))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
    astate = cc.init_async_state(params["mega"])
    idx0, idx1 = _batch_idx(cfg, ebc, 0), _batch_idx(cfg, ebc, 1)
    cc.take_async(astate, idx0, train=False)
    cc.stage_async(astate, idx1, train=False)
    assert astate.staged is not None and astate.pending

    d = cc.state_dict(astate)
    assert astate.staged is None and not astate.pending
    assert astate.stats.prefetched > 0            # staged -> prefetch
    restored = cc.load_state_dict(d)
    assert dataclasses.asdict(restored.stats) == \
        dataclasses.asdict(astate.stats)
    assert restored.epoch == astate.epoch

    # both continue with batch1: the staged rows are resident, so the
    # re-plan is all hits, and lookups/materialize stay bit-equal
    out_a = cc.lookup_async(astate, idx1, train=False)
    out_b = cc.lookup_async(restored, idx1, train=False)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))
    mega_a, acc_a = cc.materialize_async(astate)
    mega_b, acc_b = cc.materialize_async(restored)
    np.testing.assert_array_equal(np.asarray(mega_a), np.asarray(mega_b))
    np.testing.assert_array_equal(np.asarray(acc_a), np.asarray(acc_b))


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


def test_cache_exchange_kernel_matches_ref_interpret(rng):
    r, c, d, n = 40, 8, 48, 6                     # d pads 48 -> 128
    capacity = jnp.asarray(rng.randn(r, d), jnp.float32)
    cache = jnp.asarray(rng.randn(c, d), jnp.float32)
    cap_acc = jnp.asarray(rng.rand(r), jnp.float32)
    cache_acc = jnp.asarray(rng.rand(c), jnp.float32)
    freq = jnp.asarray(rng.rand(c), jnp.float32)
    slots = jnp.asarray([0, 2, 3, -1, 5, 7], jnp.int32)
    evict = jnp.asarray([10, -1, 12, -1, -1, 13], jnp.int32)
    fetch = jnp.asarray([20, 21, -1, -1, 22, 23], jnp.int32)
    counts = jnp.asarray([3, 1, 0, 0, 2, 5], jnp.float32)
    want = ref.cache_exchange_ref(capacity, cache, cap_acc, cache_acc, freq,
                                  slots, evict, fetch, counts)
    got = cache_ops.cache_exchange(capacity, cache, cap_acc, cache_acc, freq,
                                   slots, evict, fetch, counts,
                                   interpret=True)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_lfu_touch_decays_and_bumps():
    freq = jnp.asarray([4.0, 2.0, 0.0], jnp.float32)
    out = cache_ops.lfu_touch(freq, jnp.asarray([1, -1], jnp.int32),
                              jnp.asarray([3.0, 9.0], jnp.float32),
                              decay=0.5)
    np.testing.assert_allclose(np.asarray(out), [2.0, 4.0, 0.0])


def test_cached_manager_kernel_interpret_equals_jnp_path(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc_k = CachedEmbeddingBagCollection.build(cfg, cache_rows=160,
                                              interpret=True)
    cc_j = CachedEmbeddingBagCollection.build(cfg, cache_rows=160)
    st_k = cc_k.init_state(params["mega"])
    st_j = cc_j.init_state(params["mega"])
    for step in range(3):
        idx = _batch_idx(cfg, ebc, step, batch=4)
        out_k = cc_k.lookup(st_k, idx, train=False)
        out_j = cc_j.lookup(st_j, idx, train=False)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_j))
    assert st_k.stats.hits == st_j.stats.hits
    assert st_k.stats.misses == st_j.stats.misses


# ---------------------------------------------------------------------------
# pipeline prefetch hook + serving
# ---------------------------------------------------------------------------


def test_dedup_hook_and_prefetch_make_next_batch_all_hits(cfg, ebc):
    hook = dedup_indices_hook(ebc.plan.table_offsets)

    def gen(step):
        return make_dlrm_batch(cfg, 8, step=step)

    pipe = DataPipeline(gen, prefetch=2, transform=hook)
    _, b0 = next(pipe)
    _, b1 = next(pipe)
    pipe.close()
    # the hook rewrites "idx" to offset global rows + attaches the dedup set
    raw0 = make_dlrm_batch(cfg, 8, step=0)["idx"]
    glob0 = np.asarray(ebc.offset_indices(jnp.asarray(raw0)))
    np.testing.assert_array_equal(b0["idx"], glob0)
    np.testing.assert_array_equal(b0["uniq_rows"],
                                  np.unique(glob0[glob0 >= 0]))

    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=512)
    state = cc.init_state(params["mega"])
    admitted = cc.prefetch(state, b1["uniq_rows"])
    assert admitted == len(b1["uniq_rows"])
    misses_before = state.stats.misses
    cc.prepare(state, b1["idx"], train=False)
    assert state.stats.misses == misses_before    # fully prefetched -> hits
    assert state.stats.prefetched == admitted


def test_pipeline_worker_error_surfaces_in_consumer():
    def gen(step):
        if step >= 2:
            raise KeyError("boom")
        return {"x": np.asarray([step])}

    pipe = DataPipeline(gen, prefetch=1)
    assert next(pipe)[1]["x"][0] == 0
    assert next(pipe)[1]["x"][0] == 1
    with pytest.raises(RuntimeError, match="step 2"):
        next(pipe)
        next(pipe)
    pipe.close()


def test_serve_engine_readonly_matches_uncached_forward(cfg, ebc):
    from repro.core.dlrm import dlrm_forward
    from repro.serve.engine import DLRMEngine
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(2))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=160)
    engine = DLRMEngine(params, cfg, cc)
    cap_before = np.asarray(engine.state.capacity).copy()
    for step in range(3):
        raw = make_dlrm_batch(cfg, 8, step=step)
        b = {"dense": jnp.asarray(raw["dense"]),
             "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))}
        probs = engine.predict(b)
        want = jax.nn.sigmoid(dlrm_forward(
            params, {"dense": b["dense"], "idx": jnp.asarray(b["idx"])},
            cfg, ebc))
        np.testing.assert_allclose(probs, np.asarray(want), rtol=1e-6,
                                   atol=1e-6)
    # read-only: eviction never writes back and capacity is untouched
    assert engine.cache_stats.writebacks == 0
    np.testing.assert_array_equal(cap_before,
                                  np.asarray(engine.state.capacity))
    assert engine.requests_served == 24


def test_thrash_guard_raises(cfg, ebc):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=8)
    state = cc.init_state(params["mega"])
    with pytest.raises(ValueError, match="cache_rows"):
        cc.prepare(state, _batch_idx(cfg, ebc, 0))


def test_serve_engine_microbatches_when_cache_smaller_than_batch(cfg, ebc):
    """Read-only serving with a device cache SMALLER than one batch's
    working set: predict must micro-batch through the thrash guard instead
    of raising, every batch misses (capacity-bound regime), and the
    probabilities still match the dense uncached forward exactly."""
    from repro.core.dlrm import dlrm_forward
    from repro.serve.engine import DLRMEngine
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(2))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=48)
    engine = DLRMEngine(params, cfg, cc)
    # same compiled forward over a cache big enough to never split: the
    # bit-equality oracle for the splitting path
    big = DLRMEngine(params, cfg,
                     CachedEmbeddingBagCollection.build(cfg, cache_rows=2048))
    # the full batch working set must NOT fit (else the test is vacuous)
    n_batches = 3
    for step in range(n_batches):
        idx = _batch_idx(cfg, ebc, step)
        assert len(np.unique(idx[idx >= 0])) > 48
    cap_before = np.asarray(engine.state.capacity).copy()
    for step in range(n_batches):
        raw = make_dlrm_batch(cfg, 8, step=step)
        b = {"dense": jnp.asarray(raw["dense"]),
             "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))}
        misses_before = engine.cache_stats.misses
        probs = engine.predict(b)
        assert engine.cache_stats.misses > misses_before   # misses every batch
        np.testing.assert_array_equal(probs, big.predict(b))
        want = jax.nn.sigmoid(dlrm_forward(
            params, {"dense": b["dense"], "idx": jnp.asarray(b["idx"])},
            cfg, ebc))
        np.testing.assert_allclose(probs, np.asarray(want, np.float32),
                                   rtol=1e-6, atol=1e-6)
    # every batch split at least once: more planner steps than predicts
    assert engine.cache_stats.steps > n_batches
    assert engine.requests_served == 8 * n_batches
    # still read-only: nothing written back, capacity untouched
    assert engine.cache_stats.writebacks == 0
    np.testing.assert_array_equal(cap_before,
                                  np.asarray(engine.state.capacity))


def test_bounded_zipf_head_is_hot():
    rng = np.random.RandomState(0)
    draws = bounded_zipf_rows(rng, 10_000, 20_000, 1.05)
    assert draws.min() >= 0 and draws.max() < 10_000
    # top-10% ranks should carry well over half the mass at alpha ~ 1
    frac = (draws < 1000).mean()
    assert frac > 0.5


def test_serve_engine_split_covers_even_and_odd_batches(cfg, ebc):
    """The greedy prefix splitter must cover both parities (the old
    recursive-halving path only ever saw even halves): even and odd batch
    sizes through an undersized cache stay bit-equal to the no-split
    oracle."""
    from repro.serve.engine import DLRMEngine
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(2))
    engine = DLRMEngine(params, cfg,
                        CachedEmbeddingBagCollection.build(cfg,
                                                           cache_rows=48))
    big = DLRMEngine(params, cfg,
                     CachedEmbeddingBagCollection.build(cfg,
                                                        cache_rows=2048))
    for n in (8, 7):                           # even AND odd
        raw = make_dlrm_batch(cfg, n, step=n)
        b = {"dense": jnp.asarray(raw["dense"]),
             "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))}
        idx = b["idx"]
        assert len(np.unique(idx[idx >= 0])) > 48   # must actually split
        np.testing.assert_array_equal(engine.predict(b), big.predict(b))
    assert engine.requests_served == 15


def test_serve_engine_single_example_over_capacity_is_actionable(cfg, ebc):
    """One example whose OWN unique rows exceed the cache can never be
    split: the error must say so and name both sizes, not recurse or
    surface the raw thrash-guard message."""
    from repro.serve.engine import DLRMEngine
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(2))
    engine = DLRMEngine(params, cfg,
                        CachedEmbeddingBagCollection.build(cfg,
                                                           cache_rows=8))
    raw = make_dlrm_batch(cfg, 2, step=0)
    idx = np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))
    assert len(np.unique(idx[0][idx[0] >= 0])) > 8
    with pytest.raises(ValueError, match=r"cannot be split") as ei:
        engine.predict({"dense": jnp.asarray(raw["dense"]), "idx": idx})
    assert "cache_rows=8" in str(ei.value)
