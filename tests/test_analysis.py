"""Validate the compiled-artifact analyzers against XLA's own
cost_analysis on loop-free graphs, and their loop-trip correction on
scanned graphs. These parsers are the §Roofline measurement instrument;
wrong numbers here poison every table.

NOTE: builds its own tiny meshes from the default 1-CPU device (no
XLA_FLAGS here — see conftest).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.analysis import CollectiveAnalysis, StableHloAnalysis


def _matmul_chain(n, unroll=1):
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=n, unroll=unroll)
        return y
    return f


def test_stablehlo_flops_match_xla_loop_free():
    f = _matmul_chain(4, unroll=4)          # fully unrolled: XLA counts all
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32))
    ours = StableHloAnalysis(lowered.as_text()).cost()
    from repro.compat import cost_analysis_dict
    xla = cost_analysis_dict(lowered.compile())
    assert ours.mxu_flops == pytest.approx(xla["flops"], rel=0.01)


def test_stablehlo_loop_correction():
    """Scanned graph: XLA counts the body once; we must count trip times."""
    lowered1 = jax.jit(_matmul_chain(1)).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    lowered8 = jax.jit(_matmul_chain(8)).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    c1 = StableHloAnalysis(lowered1.as_text()).cost()
    c8 = StableHloAnalysis(lowered8.as_text()).cost()
    assert c8.mxu_flops == pytest.approx(8 * c1.mxu_flops, rel=0.01)
    expect = 2 * 64 * 128 * 128
    assert c1.mxu_flops == pytest.approx(expect, rel=0.01)


def test_stablehlo_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
        jax.ShapeDtypeStruct((4, 64, 16), jnp.float32))
    c = StableHloAnalysis(lowered.as_text()).cost()
    assert c.mxu_flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_collective_analysis_counts_sharded_matmul():
    """2x2 mesh over 4 host devices (spawned in a subprocess-safe way is
    overkill; we only need lowering, and the default test process has one
    device — so this test uses an abstract mesh via AbstractMesh where
    available, else skips)."""
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices; covered by launch/dryrun runs")


def test_collective_analysis_parses_known_hlo():
    """Parse a hand-written HLO module with a while loop + collectives."""
    hlo = """
HloModule test, num_partitions=8

%body (param: (s32[], f32[32,128])) -> (s32[], f32[32,128]) {
  %param = (s32[], f32[32,128]{1,0}) parameter(0)
  %gte = f32[32,128]{1,0} get-tuple-element(%param), index=1
  %ag = f32[32,512]{1,0} all-gather(%gte), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %c1 = s32[] constant(1)
  %i = s32[] get-tuple-element(%param), index=0
  %add = s32[] add(%i, %c1)
  ROOT %tuple = (s32[], f32[32,128]{1,0}) tuple(%add, %gte)
}

%cond (param.1: (s32[], f32[32,128])) -> pred[] {
  %param.1 = (s32[], f32[32,128]{1,0}) parameter(0)
  %i.1 = s32[] get-tuple-element(%param.1), index=0
  %c5 = s32[] constant(5)
  ROOT %lt = pred[] compare(%i.1, %c5), direction=LT
}

ENTRY %main (p0: f32[32,128]) -> f32[] {
  %p0 = f32[32,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[32,128]{1,0}) tuple(%c0, %p0)
  %w = (s32[], f32[32,128]{1,0}) while(%t), condition=%cond, body=%body
  %gte2 = f32[32,128]{1,0} get-tuple-element(%w), index=1
  %red = f32[] constant(0)
  ROOT %ar = f32[] all-reduce(%red), channel_id=2, replica_groups=[2,4]<=[8]
}
"""
    ca = CollectiveAnalysis(hlo)
    # all-gather: result 32*512*4 bytes * ring (3/4) * 5 trips
    expect_ag = 32 * 512 * 4 * (3 / 4) * 5
    assert ca.by_type["all-gather"] == pytest.approx(expect_ag, rel=0.01)
    assert ca.by_type["all-reduce"] == pytest.approx(
        2 * 4 * (3 / 4), rel=0.01)
    assert not ca.warnings


def test_collective_analysis_dot_flops():
    hlo = """
HloModule t, num_partitions=4

ENTRY %main (a: f32[16,32], b: f32[32,8]) -> f32[16,8] {
  %a = f32[16,32]{1,0} parameter(0)
  %b = f32[32,8]{1,0} parameter(1)
  ROOT %dot = f32[16,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    ca = CollectiveAnalysis(hlo)
    assert ca.dot_flops == pytest.approx(2 * 16 * 32 * 8)


def test_serve_replay_traffic_prices_shed_and_degraded():
    """Serving-path byte model (launch/analysis.py): shed requests never
    touch the capacity tier, degraded batches resolve misses from the
    local snapshot, and the read-only tier never writes back."""
    from repro.launch.analysis import serve_replay_traffic
    base = serve_replay_traffic(requests=100, examples=4, n_features=6,
                                truncation=8, embed_dim=16, hit_rate=0.8)
    assert base["accesses"] == 100 * 4 * 6 * 8
    assert base["fetched_rows"] == pytest.approx(base["accesses"] * 0.2)
    assert base["writeback_bytes"] == 0.0
    assert base["uncached_vs_cached"] > 1.0     # the cache tier must win
    shed = serve_replay_traffic(requests=100, examples=4, n_features=6,
                                truncation=8, embed_dim=16, hit_rate=0.8,
                                shed_rate=0.5)
    assert shed["fetch_bytes"] == pytest.approx(base["fetch_bytes"] * 0.5)
    deg = serve_replay_traffic(requests=100, examples=4, n_features=6,
                               truncation=8, embed_dim=16, hit_rate=0.8,
                               degraded_fraction=0.25)
    assert deg["fetch_bytes"] == pytest.approx(base["fetch_bytes"] * 0.75)
