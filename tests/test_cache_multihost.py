"""Multi-host cache coherence (docs/cache.md "Multi-host coherence").

Covers the full stack of the sharded-capacity tier: plan sub-splitting
(by host bag range and by owner row range), the per-owner segmented fused
backward, the per-host cache manager (clean eviction, invalidation,
prefetch, thrash guard), and the train step's bit-exactness contracts —
vs the single-host cached path on 1 host, and vs the dense single-host
oracle with a hot row cached on several hosts (gradients routed and
reduced once at the owner). The 8-fake-device mesh test exercises the
shard_map owner update against a genuinely row-sharded capacity tier.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cache import (CachedEmbeddingBagCollection,
                              MultiHostCachedEmbeddingBagCollection)
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.core.placement import plan_placement
from repro.data.pipeline import sparse_plan_hook
from repro.data.synthetic import make_dlrm_batch
from repro.kernels import ops as kernel_ops
from repro.kernels.sparse_plan import (SparsePlan, build_sparse_plan_host,
                                       host_plan_from_batch,
                                       host_plans_from_batch,
                                       split_plan_by_host,
                                       split_plan_by_owner)
from repro.launch.analysis import multihost_exchange_traffic
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_cached_dlrm_train_step,
                               build_dlrm_train_step,
                               build_multihost_cached_train_step,
                               cached_dlrm_init_state, dlrm_init_state)

pytestmark = pytest.mark.compat

# ---------------------------------------------------------------------------
# corpus shared by the splitting tests
# ---------------------------------------------------------------------------


def _corpus():
    rng = np.random.RandomState(0)
    out = {
        "random": rng.randint(-1, 40, size=(16, 3, 5)).astype(np.int32),
        "all_dup": np.full((8, 2, 4), 7, np.int32),
        "all_pads": np.full((8, 2, 4), -1, np.int32),
        "zipfish": np.where(rng.rand(16, 2, 6) < 0.7,
                            rng.zipf(1.5, (16, 2, 6)) % 30,
                            -1).astype(np.int32),
    }
    hot = rng.randint(-1, 64, size=(16, 2, 4)).astype(np.int32)
    hot[:, 0, 0] = 3                       # one row on every host
    out["hot_everywhere"] = hot
    return out


def _live(plan):
    rows = np.asarray(plan.unique_rows)
    n = int((rows >= 0).sum())
    offs = np.asarray(plan.bag_offsets).astype(np.int64)
    return rows[:n], offs[: n + 1], np.asarray(plan.bag_ids)


def _pairs(plan):
    """Multiset of (row, bag) pairs a plan encodes (live prefix only)."""
    rows, offs, bags = _live(plan)
    out = []
    for i, r in enumerate(rows):
        for p in range(offs[i], offs[i + 1]):
            out.append((int(r), int(bags[p])))
    return sorted(out)

# ---------------------------------------------------------------------------
# split_plan_by_host
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(_corpus()))
@pytest.mark.parametrize("n_hosts", [1, 2, 4])
def test_split_by_host_equals_per_subbatch_plan(name, n_hosts):
    """Each sub-plan is EXACTLY build_sparse_plan_host on that host's
    contiguous sub-batch (rows, offsets, and the live bag prefix)."""
    idx = _corpus()[name]
    b, f, _ = idx.shape
    if b % n_hosts:
        pytest.skip("batch not divisible")
    subs = split_plan_by_host(build_sparse_plan_host(idx), n_hosts,
                              b // n_hosts * f)
    for h in range(n_hosts):
        want = build_sparse_plan_host(idx[h * (b // n_hosts):
                                          (h + 1) * (b // n_hosts)])
        rows_w, offs_w, bags_w = _live(want)
        n_valid = int(offs_w[-1]) if len(offs_w) else 0
        assert np.array_equal(np.asarray(subs[h].unique_rows),
                              np.asarray(want.unique_rows))
        assert np.array_equal(np.asarray(subs[h].bag_offsets),
                              np.asarray(want.bag_offsets))
        assert np.array_equal(np.asarray(subs[h].bag_ids)[:n_valid],
                              bags_w[:n_valid])


@pytest.mark.parametrize("name", list(_corpus()))
def test_split_by_host_partitions_global_plan(name):
    """The multiset of (row, GLOBAL bag) pairs across sub-plans
    reconstructs the global plan's exactly; each live prefix is strictly
    ascending (the planner invariant every consumer relies on)."""
    idx = _corpus()[name]
    b, f, _ = idx.shape
    n_hosts = 4
    plan = build_sparse_plan_host(idx)
    subs = split_plan_by_host(plan, n_hosts, b // n_hosts * f)
    got = []
    for h, sub in enumerate(subs):
        rows, _, _ = _live(sub)
        assert np.all(np.diff(rows) > 0)     # strictly ascending per host
        got += [(r, bag + h * (b // n_hosts) * f)
                for r, bag in _pairs(sub)]
    assert sorted(got) == _pairs(plan)


def test_split_by_host_partition_property():
    pytest.importorskip("hypothesis",
                        reason="hypothesis not installed (pip install "
                               ".[dev])")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=30, deadline=None)
    @given(b=st.sampled_from([4, 8, 16]), f=st.integers(1, 3),
           lk=st.integers(1, 5), rows=st.integers(1, 50),
           n_hosts=st.sampled_from([1, 2, 4]),
           seed=st.integers(0, 2**31 - 1))
    def check(b, f, lk, rows, n_hosts, seed):
        rng = np.random.RandomState(seed)
        idx = rng.randint(-1, rows, size=(b, f, lk)).astype(np.int32)
        plan = build_sparse_plan_host(idx)
        subs = split_plan_by_host(plan, n_hosts, b // n_hosts * f)
        got = []
        for h, sub in enumerate(subs):
            live, _, _ = _live(sub)
            assert np.all(np.diff(live) > 0)
            got += [(r, bag + h * (b // n_hosts) * f)
                    for r, bag in _pairs(sub)]
        assert sorted(got) == _pairs(plan)

    check()

# ---------------------------------------------------------------------------
# split_plan_by_owner + segmented fused backward
# ---------------------------------------------------------------------------


def test_split_by_owner_is_contiguous_slicing():
    rng = np.random.RandomState(1)
    idx = rng.randint(-1, 48, size=(8, 2, 6)).astype(np.int32)
    plan = build_sparse_plan_host(idx)
    shard_rows, n_shards = 12, 4
    seg_rows, seg_offs, seg_base = split_plan_by_owner(
        plan, shard_rows, n_shards)
    rows_g, offs_g, _ = _live(plan)
    rebuilt = []
    for s in range(n_shards):
        live = seg_rows[s][seg_rows[s] >= 0]
        assert np.all((live >= 0) & (live < shard_rows))   # owner-local
        rebuilt += list(live + seg_base[s])
        # pad offsets equal the segment's bag end (empty runs)
        k = len(live)
        assert np.all(seg_offs[s][k:] == seg_offs[s][k])
    assert np.array_equal(np.asarray(rebuilt), rows_g)
    with pytest.raises(ValueError, match="segment overflow"):
        split_plan_by_owner(plan, shard_rows, n_shards, seg_cap=1)


@pytest.mark.parametrize("name", ["random", "all_dup", "all_pads"])
def test_segmented_backward_bitmatches_global(name):
    """The per-owner segmented update == the unsegmented fused backward,
    bit for bit (jnp oracle path)."""
    rng = np.random.RandomState(2)
    idx = _corpus()[name] % 40                     # rows within the table
    idx = np.where(_corpus()[name] >= 0, idx, -1)
    b, f, _ = idx.shape
    h, d = 48, 16
    table = jnp.asarray(rng.randn(h, d), jnp.float32)
    accum = jnp.asarray(rng.rand(h), jnp.float32)
    gp = jnp.asarray(rng.randn(b, f, d), jnp.float32)
    plan = build_sparse_plan_host(idx)
    want = kernel_ops.fused_sparse_backward(
        table, accum, jnp.asarray(idx), gp, 0.05,
        plan=SparsePlan(jnp.asarray(plan.unique_rows),
                        jnp.asarray(plan.bag_offsets),
                        jnp.asarray(plan.bag_ids)))
    seg_rows, seg_offs, seg_base = split_plan_by_owner(
        plan, 12, 4, seg_cap=len(np.asarray(plan.unique_rows)))
    got = kernel_ops.fused_sparse_backward_segments(
        table, accum, jnp.asarray(seg_rows), jnp.asarray(seg_offs),
        jnp.asarray(plan.bag_ids), gp, 0.05,
        seg_base=jnp.asarray(seg_base))
    assert np.array_equal(np.asarray(want[0]), np.asarray(got[0]))
    assert np.array_equal(np.asarray(want[1]), np.asarray(got[1]))


def test_segmented_kernel_interpret_matches_oracle():
    """The generalized (S, C)-grid Pallas kernel body (interpret mode)
    against the jnp segment oracle, lane-width D."""
    rng = np.random.RandomState(3)
    b, f, lk, h, d = 6, 2, 4, 32, 128
    idx = rng.randint(-1, h, size=(b, f, lk)).astype(np.int32)
    table = jnp.asarray(rng.randn(h, d), jnp.float32)
    accum = jnp.asarray(rng.rand(h), jnp.float32)
    gp = jnp.asarray(rng.randn(b, f, d), jnp.float32)
    plan = build_sparse_plan_host(idx)
    seg_rows, seg_offs, seg_base = split_plan_by_owner(
        plan, 8, 4, seg_cap=len(np.asarray(plan.unique_rows)))
    args = (table, accum, jnp.asarray(seg_rows), jnp.asarray(seg_offs),
            jnp.asarray(plan.bag_ids), gp, 0.05)
    want = kernel_ops.fused_sparse_backward_segments(
        *args, seg_base=jnp.asarray(seg_base))
    got = kernel_ops.fused_sparse_backward_segments(
        *args, seg_base=jnp.asarray(seg_base), interpret=True)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               rtol=1e-6, atol=1e-6)

# ---------------------------------------------------------------------------
# placement: sharded capacity tier
# ---------------------------------------------------------------------------


def test_cached_host_sharded_capacity_plan():
    plan = plan_placement([1000, 500], [2.0, 1.0], 16, 4, 64_000,
                          strategy="cached_host", capacity_shards=4)
    assert plan.capacity_shards == 4
    assert plan.total_rows % (4 * 8) == 0
    assert plan.shard_rows * 4 == plan.total_rows
    assert plan.pspec == jax.sharding.PartitionSpec("data", None)
    # single-host plans are untouched by the new knob
    plan1 = plan_placement([1000, 500], [2.0, 1.0], 16, 4, 64_000,
                           strategy="cached_host")
    assert plan1.capacity_shards == 1
    assert plan1.pspec == jax.sharding.PartitionSpec(None, None)

# ---------------------------------------------------------------------------
# manager semantics
# ---------------------------------------------------------------------------


def _mc_setup(n_hosts=2, cache_rows=256):
    cfg = get_smoke_config("dlrm-m1")
    mc = MultiHostCachedEmbeddingBagCollection.build(
        cfg, n_hosts=n_hosts, cache_rows=cache_rows)
    total = mc.ebc.plan.total_rows
    rng = np.random.RandomState(0)
    mega = jnp.asarray(rng.randn(total, cfg.embed_dim), jnp.float32)
    return cfg, mc, mc.init_state(mega), mega


def test_multihost_lookup_matches_uncached():
    cfg, mc, state, mega = _mc_setup()
    rng = np.random.RandomState(1)
    total = mc.ebc.plan.total_rows
    for step in range(3):
        idx = rng.randint(-1, min(total, 200), size=(8, cfg.n_sparse_features,
                                                     4)).astype(np.int32)
        want = mc.ebc.lookup({"mega": mega}, jnp.asarray(idx))
        got = mc.lookup(state, idx)
        assert np.array_equal(np.asarray(want), np.asarray(got))
    assert state.stats.hits > 0 and state.stats.misses > 0
    assert state.stats.writebacks == 0          # clean caches never flush


def test_multihost_clean_eviction_and_stats():
    cfg, mc, state, _ = _mc_setup(n_hosts=2, cache_rows=32)
    rng = np.random.RandomState(2)
    for step in range(6):                        # force churn through 32 slots
        # sliding 24-row window: each batch's working set fits the cache
        # but the cumulative footprint forces evictions
        idx = (rng.randint(step * 20, step * 20 + 24,
                           size=(4, cfg.n_sparse_features, 4))
               .astype(np.int32))
        mc.lookup(state, idx)
    assert state.stats.evictions > 0
    assert state.stats.writebacks == 0
    # maps stay a bijection per host
    for h in range(2):
        resident = np.flatnonzero(state.slot_row[h] >= 0)
        rows = state.slot_row[h, resident]
        assert np.array_equal(state.row_slot[h, rows], resident)


def test_multihost_thrash_guard():
    cfg, mc, state, _ = _mc_setup(n_hosts=2, cache_rows=8)
    idx = np.arange(2 * cfg.n_sparse_features * 16).reshape(
        2, cfg.n_sparse_features, 16).astype(np.int32)
    with pytest.raises(ValueError, match="cache thrash|unique rows"):
        mc.plan_step(state, np.concatenate([idx, idx], axis=0))


def test_multihost_prefetch_admits_and_hits():
    cfg, mc, state, _ = _mc_setup(n_hosts=2, cache_rows=256)
    rng = np.random.RandomState(3)
    idx = rng.randint(0, 50, size=(8, cfg.n_sparse_features,
                                   4)).astype(np.int32)
    n = mc.prefetch(state, idx)
    assert n > 0 and state.stats.prefetched == n
    h0, m0 = state.stats.hits, state.stats.misses
    mc.plan_step(state, idx, train=False)
    assert state.stats.misses == m0              # everything was prefetched
    assert state.stats.hits > h0

# ---------------------------------------------------------------------------
# train-step bit-exactness
# ---------------------------------------------------------------------------


def _batches(cfg, ebc, n, b, plant_hot=True, hook=None):
    out = []
    for t in range(n):
        raw = make_dlrm_batch(cfg, b, step=t)
        if hook is not None:
            batch = hook({"dense": raw["dense"], "idx": np.asarray(raw["idx"]),
                          "label": raw["label"]})
            batch["dense"] = jnp.asarray(batch["dense"])
            batch["label"] = jnp.asarray(batch["label"])
        else:
            idx = np.array(ebc.offset_indices(jnp.asarray(raw["idx"])))
            batch = {"dense": jnp.asarray(raw["dense"]), "idx": idx,
                     "label": jnp.asarray(raw["label"])}
        if plant_hot:
            idx = np.array(batch["idx"])
            hot = int(idx[idx >= 0][0])
            idx[:, 0, 0] = hot                   # cached on EVERY host
            batch["idx"] = idx
            assert hook is None, "plant before hooking"
        out.append(batch)
    return out


def _run_oracle(cfg, ebc, params, batches):
    opt = adagrad(0.01)
    p = dict(params)
    state = dlrm_init_state(ebc, opt, p)
    step = jax.jit(build_dlrm_train_step(cfg, ebc, opt,
                                         sparse_apply="sparse"))
    losses = []
    for t, b in enumerate(batches):
        bb = dict(b)
        bb["idx"] = jnp.asarray(bb["idx"])
        p, state, m = step(p, state, bb, jnp.asarray(t, jnp.int32))
        losses.append(float(m["loss"]))
    return losses, np.asarray(p["emb"]["mega"]), np.asarray(state["accum"])


def _run_multihost(cfg, mc, params, batches, strict_sync, use_hook_plans):
    opt = adagrad(0.01)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    state = cached_dlrm_init_state(mc, opt, params)
    mstate = mc.init_state(params["emb"]["mega"])
    step = build_multihost_cached_train_step(cfg, mc, opt,
                                             strict_sync=strict_sync)
    losses = []
    for t, b in enumerate(batches):
        nxt = batches[t + 1] if t + 1 < len(batches) else None
        dense, state, m = step(dense, state, mstate, b,
                               jnp.asarray(t, jnp.int32), next_batch=nxt)
        losses.append(float(m["loss"]))
    mega, accum = mc.materialize(mstate)
    return losses, np.asarray(mega), np.asarray(accum), mstate


def test_multihost_step_bitexact_vs_dense_oracle():
    """4 hosts, 4 steps, one hot row planted in every host's slice: losses,
    table, and accumulator must equal the dense single-host oracle's BIT
    FOR BIT — the routed duplicate-row gradients reduce once at the owner
    and every stale copy is refreshed/invalidated in time."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    batches = _batches(cfg, ebc, 4, 16)
    want_l, want_m, want_a = _run_oracle(cfg, ebc, params, batches)
    mc = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=4,
                                                     cache_rows=512)
    r = ebc.plan.total_rows
    for strict in (True, False):
        got_l, got_m, got_a, mstate = _run_multihost(
            cfg, mc, params, batches, strict, False)
        assert got_l == want_l
        assert np.array_equal(got_m[:r], want_m)
        assert np.array_equal(got_a[:r], want_a)
        assert mstate.route.dup_rows > 0         # the hot row, every step
        assert mstate.route.fetch_remote > 0
        assert mstate.route.grad_pairs_remote > 0
    # overlap mode actually prefetched
    assert mstate.stats.prefetched > 0


def test_multihost_step_with_hook_plans_bitexact():
    """The reader-thread artifacts (global plan + per-host sub-plans from
    sparse_plan_hook(n_hosts=H)) drive the same bits as on-the-fly
    planning."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    hook = sparse_plan_hook(ebc.plan.table_offsets, n_hosts=4)
    hooked = _batches(cfg, ebc, 3, 16, plant_hot=False, hook=hook)
    plain = [{"dense": b["dense"], "idx": np.asarray(b["idx"]),
              "label": b["label"]} for b in hooked]
    mc = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=4,
                                                     cache_rows=512)
    want = _run_multihost(cfg, mc, params, plain, True, False)
    got = _run_multihost(cfg, mc, params, hooked, True, True)
    assert want[0] == got[0]
    assert np.array_equal(want[1], got[1])
    assert np.array_equal(want[2], got[2])
    # the hook really attached the per-host artifacts the step consumed
    assert host_plans_from_batch(hooked[0]) is not None
    assert host_plan_from_batch(hooked[0]) is not None


def test_multihost_1host_bitexact_vs_single_host_cached():
    """On one host the tier degenerates to the single-host cached path:
    same losses, same materialized capacity + accumulator, zero cross-host
    traffic."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    batches = _batches(cfg, ebc, 4, 16, plant_hot=False)
    opt = adagrad(0.01)
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=512)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    s1 = cached_dlrm_init_state(cc, opt, params)
    cstate = cc.init_state(params["emb"]["mega"])
    step1 = build_cached_dlrm_train_step(cfg, cc, opt)
    want_l = []
    for t, b in enumerate(batches):
        dense, s1, m = step1(dense, s1, cstate, b, jnp.asarray(t, jnp.int32))
        want_l.append(float(m["loss"]))
    want_m, want_a = cc.materialize(cstate)
    r = ebc.plan.total_rows
    mc = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=1,
                                                     cache_rows=512)
    got_l, got_m, got_a, mstate = _run_multihost(cfg, mc, params, batches,
                                                 True, False)
    assert got_l == want_l
    assert np.array_equal(got_m[:r], np.asarray(want_m))
    assert np.array_equal(got_a[:r], np.asarray(want_a))
    assert mstate.route.fetch_remote == 0
    assert mstate.route.refresh_remote == 0


def test_multihost_invalidation_keeps_copies_coherent():
    """A row cached on host 1 but updated by host 0 alone must be
    invalidated (counted) and re-fetched fresh on host 1's next touch."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    f, lk = cfg.n_sparse_features, 4
    row = 5

    def batch(idx):
        rng = np.random.RandomState(0)
        return {"dense": jnp.asarray(rng.randn(4, cfg.n_dense_features),
                                     jnp.float32),
                "idx": idx,
                "label": jnp.asarray(rng.rand(4) > 0.5, jnp.float32)}

    both = np.full((4, f, lk), -1, np.int32)
    both[:, 0, 0] = row                          # both hosts touch the row
    only0 = np.full((4, f, lk), -1, np.int32)
    only0[:2, 0, 0] = row                        # host 0 only
    only0[2:, 0, 1] = 8                          # host 1 touches another row
    mc = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=2,
                                                     cache_rows=64)
    opt = adagrad(0.01)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    state = cached_dlrm_init_state(mc, opt, params)
    mstate = mc.init_state(params["emb"]["mega"])
    step = build_multihost_cached_train_step(cfg, mc, opt, strict_sync=True)
    dense, state, _ = step(dense, state, mstate, batch(both),
                           jnp.asarray(0, jnp.int32))
    assert mstate.row_slot[1, row] >= 0          # host 1 caches the row
    inv0 = mstate.route.invalidations
    dense, state, _ = step(dense, state, mstate, batch(only0),
                           jnp.asarray(1, jnp.int32))
    assert mstate.route.invalidations == inv0 + 1
    assert mstate.row_slot[1, row] < 0           # host 1's copy dropped
    m0 = mstate.stats.misses
    dense, state, _ = step(dense, state, mstate, batch(both),
                           jnp.asarray(2, jnp.int32))
    assert mstate.stats.misses > m0              # re-fetched fresh
    # end-to-end value check: capacity must match the dense oracle
    opt2 = adagrad(0.01)
    p = dict(params)
    st2 = dlrm_init_state(ebc, opt2, p)
    step_o = jax.jit(build_dlrm_train_step(cfg, ebc, opt2,
                                           sparse_apply="sparse"))
    for t, idx in enumerate([both, only0, both]):
        b = batch(idx)
        b["idx"] = jnp.asarray(b["idx"])
        p, st2, _ = step_o(p, st2, b, jnp.asarray(t, jnp.int32))
    r = ebc.plan.total_rows
    assert np.array_equal(np.asarray(mc.materialize(mstate)[0])[:r],
                          np.asarray(p["emb"]["mega"]))

# ---------------------------------------------------------------------------
# 8 fake devices: shard_map owner update against real capacity shards
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_multihost_step_on_8_device_mesh_bitexact_vs_oracle():
    """The acceptance test: 8 data-parallel hosts over a capacity tier
    genuinely row-sharded on an 8-fake-device mesh (shard_map per-owner
    update), ≥3 steps with the same hot row cached on every host — the
    materialized capacity must equal the dense single-host oracle's bits.
    """
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=8'\n" + """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.configs import get_smoke_config
from repro.core.cache import MultiHostCachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.launch.mesh import make_host_mesh
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_dlrm_train_step, dlrm_init_state,
                               build_multihost_cached_train_step,
                               cached_dlrm_init_state)

cfg = get_smoke_config("dlrm-m1")
H, N, B = 8, 4, 16
ebc = EmbeddingBagCollection.build(cfg, n_shards=1, strategy="replicated")
params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
opt = adagrad(0.01)
batches = []
for t in range(N):
    raw = make_dlrm_batch(cfg, B, step=t)
    idx = np.array(ebc.offset_indices(jnp.asarray(raw["idx"])))
    hot = int(idx[idx >= 0][0])
    idx[:, 0, 0] = hot                 # cached on all 8 hosts
    batches.append({"dense": jnp.asarray(raw["dense"]), "idx": idx,
                    "label": jnp.asarray(raw["label"])})

p = dict(params)
state = dlrm_init_state(ebc, opt, p)
step_o = jax.jit(build_dlrm_train_step(cfg, ebc, opt, sparse_apply="sparse"))
losses_o = []
for t in range(N):
    b = dict(batches[t]); b["idx"] = jnp.asarray(b["idx"])
    p, state, m = step_o(p, state, b, jnp.asarray(t, jnp.int32))
    losses_o.append(float(m["loss"]))
R = ebc.plan.total_rows
mega_o = np.asarray(p["emb"]["mega"])
accum_o = np.asarray(state["accum"])

mesh = make_host_mesh(H)
mc = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=H,
                                                 cache_rows=512)
dense = {"bottom": params["bottom"], "top": params["top"]}
cstate = cached_dlrm_init_state(mc, opt, params)
mstate = mc.init_state(params["emb"]["mega"],
                       capacity_sharding=NamedSharding(mesh,
                                                       mc.ebc.plan.pspec))
assert mstate.capacity.sharding.spec == mc.ebc.plan.pspec
step_m = build_multihost_cached_train_step(cfg, mc, opt, strict_sync=True,
                                           mesh=mesh)
losses_m = []
for t in range(N):
    with mesh:
        dense, cstate, m = step_m(dense, cstate, mstate, batches[t],
                                  jnp.asarray(t, jnp.int32))
    losses_m.append(float(m["loss"]))
mega_m, accum_m = mc.materialize(mstate)
assert losses_o == losses_m, (losses_o, losses_m)
assert np.array_equal(mega_o, np.asarray(mega_m)[:R])
assert np.array_equal(accum_o, np.asarray(accum_m)[:R])
assert mstate.route.dup_rows >= N      # the hot row, each step
assert mstate.route.grad_pairs_remote > 0
print("MULTIHOST_MESH_OK")
""")
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MULTIHOST_MESH_OK" in out.stdout

# ---------------------------------------------------------------------------
# exchange-traffic model
# ---------------------------------------------------------------------------


def test_multihost_exchange_traffic_model():
    kw = dict(batch=4096, n_features=16, truncation=8, embed_dim=64)
    t8 = multihost_exchange_traffic(**kw, n_hosts=8, unique_per_host=9000,
                                    unique_global=30000, hit_rate=0.8)
    # one host -> no cross-host bytes on any leg
    t1 = multihost_exchange_traffic(**kw, n_hosts=1, unique_per_host=30000,
                                    unique_global=30000, hit_rate=0.8)
    for leg in ("fetch_bytes", "grad_bytes", "refresh_bytes",
                "total_bytes"):
        assert t1[leg] == 0.0
        assert t8[leg] > 0.0
    assert t8["dup_rows"] == 8 * 9000 - 30000
    # the dedup'd, cached exchange beats per-lookup shipping, and the
    # production row-sum variant beats the bit-exact per-pair routing
    assert t8["reduction"] > 1.0
    assert t8["rowsum_total_bytes"] < t8["total_bytes"]
    assert t8["rowsum_reduction"] > t8["reduction"]
    # better hit rate -> less fetch traffic, monotone total
    t8_hot = multihost_exchange_traffic(**kw, n_hosts=8,
                                        unique_per_host=9000,
                                        unique_global=30000, hit_rate=0.95)
    assert t8_hot["fetch_bytes"] < t8["fetch_bytes"]
    assert t8_hot["total_bytes"] < t8["total_bytes"]
