"""LM behaviour: loss decreases on a learnable pattern, MoE invariants,
RoPE properties, serving engine end-to-end, analysis parsers vs XLA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm_param_specs
from repro.nn import moe as MOE
from repro.nn.layers import apply_rope
from repro.nn.params import init_params
from repro.optim import adamw
from repro.train.steps import build_lm_train_step


def test_lm_loss_decreases_on_constant_data():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    opt = adamw(3e-3, clip_norm=1.0)
    state = opt.init(params)
    step = jax.jit(build_lm_train_step(cfg, opt))
    rng = np.random.RandomState(0)
    toks = rng.randint(0, cfg.vocab_size, size=(4, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "targets": jnp.asarray(np.roll(toks, -1, axis=1)),
             "loss_mask": jnp.ones((4, 32), jnp.float32)}
    losses = []
    for i in range(30):
        params, state, m = step(params, state, batch,
                                jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[:5]


def test_grad_accumulation_matches_single_step():
    cfg = get_smoke_config("starcoder2-3b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    opt = adamw(1e-3, clip_norm=None)
    rng = np.random.RandomState(1)
    toks = rng.randint(0, cfg.vocab_size, size=(8, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "targets": jnp.asarray(np.roll(toks, -1, axis=1)),
             "loss_mask": jnp.ones((8, 16), jnp.float32)}
    s1 = build_lm_train_step(cfg, opt, accum_steps=1)
    s4 = build_lm_train_step(cfg, opt, accum_steps=4)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch, jnp.asarray(0))
    p4, _, m4 = jax.jit(s4)(params, opt.init(params), batch, jnp.asarray(0))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-2, atol=3e-3)

# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def _moe_cfg():
    return get_smoke_config("granite-moe-1b-a400m")


def test_moe_output_and_aux(rng):
    cfg = _moe_cfg()
    specs = MOE.moe_specs(cfg)
    from repro.nn.params import init_params as ip
    p = ip(specs, jax.random.PRNGKey(0))
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model), jnp.bfloat16)
    y, aux = MOE.moe(p, x, cfg, capacity_factor=8.0)
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    # balanced router at init: aux loss should be near 1 (e * 1/e * 1)
    assert 0.5 < float(aux) < 2.0


def test_moe_capacity_drops_tokens(rng):
    """With capacity factor ~0, every token overflows -> output ~ 0."""
    cfg = _moe_cfg()
    p = init_params(MOE.moe_specs(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(rng.randn(1, 32, cfg.d_model), jnp.bfloat16)
    y_tiny, _ = MOE.moe(p, x, cfg, capacity_factor=1e-9)
    # capacity floor is 8 slots/expert, so *some* tokens survive, but norm
    # must drop vs a generous capacity
    y_big, _ = MOE.moe(p, x, cfg, capacity_factor=8.0)
    assert float(jnp.abs(y_tiny).sum()) < float(jnp.abs(y_big).sum())


def test_moe_is_permutation_equivariant(rng):
    """Token order must not change each token's output (capacity permitting)."""
    cfg = _moe_cfg()
    p = init_params(MOE.moe_specs(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(rng.randn(1, 16, cfg.d_model), jnp.bfloat16)
    y, _ = MOE.moe(p, x, cfg, capacity_factor=8.0)
    perm = np.arange(16)[::-1].copy()
    y2, _ = MOE.moe(p, x[:, perm], cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y[:, perm], np.float32),
                               np.asarray(y2, np.float32),
                               rtol=5e-2, atol=5e-2)

# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("style", ["neox", "glm"])
def test_rope_preserves_norm_and_relativity(rng, style):
    b, s, h, dh = 1, 8, 2, 16
    x = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    y = apply_rope(x, pos, dh, 1.0, 10000.0, style)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jnp.asarray(rng.randn(1, 1, 1, dh), jnp.float32)
    v = jnp.asarray(rng.randn(1, 1, 1, dh), jnp.float32)

    def dot_at(p0, p1):
        qq = apply_rope(q, jnp.full((1, 1), p0), dh, 1.0, 1e4, style)
        vv = apply_rope(v, jnp.full((1, 1), p1), dh, 1.0, 1e4, style)
        return float(jnp.sum(qq * vv))

    assert abs(dot_at(0, 5) - dot_at(7, 12)) < 1e-3


def test_partial_rotary_leaves_tail_untouched(rng):
    x = jnp.asarray(rng.randn(1, 4, 1, 16), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    y = apply_rope(x, pos, 16, rotary_pct=0.25, theta=1e4, style="neox")
    np.testing.assert_array_equal(np.asarray(y)[..., 4:],
                                  np.asarray(x)[..., 4:])

# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_serve_engine_drains_requests():
    from repro.serve import Request, ServeEngine
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=64, rules={})
    rng = np.random.RandomState(0)
    for uid in range(5):
        eng.submit(Request(uid=uid,
                           prompt=rng.randint(0, cfg.vocab_size,
                                              size=(4,)).astype(np.int32),
                           max_new_tokens=6))
    done = eng.run_until_drained(max_steps=500)
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(v) == 6 for v in done.values())


def test_serve_prefill_matches_one_shot_forward():
    """The first sampled token must come from the LAST prompt position: the
    last prompt token enters the KV cache exactly once, via the first
    `step()` at position len-1. Regression: prefill used to feed ALL
    prompt tokens and step() re-fed prompt[-1] at position len, so the
    duplicate corrupted the cache and the first token sampled one position
    past the prompt."""
    from repro.models.lm import init_caches, lm_forward
    from repro.serve import Request, ServeEngine
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(3))
    rngn = np.random.RandomState(11)
    pl, new = 6, 4
    prompt = rngn.randint(0, cfg.vocab_size, size=(pl,)).astype(np.int32)

    eng = ServeEngine(params, cfg, batch_slots=1, max_len=32, rules={})

    # greedy reference through the engine's OWN compiled decode fn, feeding
    # positions exactly as the engine does (scalar during prefill, per-slot
    # vector during step), so token ids compare bit-exactly
    caches = init_caches(cfg, 1, 32)
    for t in range(pl - 1):
        _, caches = eng._decode(
            params, jnp.full((1, 1), int(prompt[t]), jnp.int32), caches,
            jnp.asarray(t, jnp.int32))
    pos = np.asarray([pl - 1], np.int32)
    tok = int(prompt[-1])
    want, first_logits = [], None
    for _ in range(new):
        lg, caches = eng._decode(params, jnp.full((1, 1), tok, jnp.int32),
                                 caches, jnp.asarray(pos, jnp.int32))
        if first_logits is None:
            first_logits = np.asarray(lg[0], np.float32)
        tok = int(np.argmax(np.asarray(lg, np.float32)[0]))
        want.append(tok)
        pos = pos + 1

    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=new))
    done = eng.run_until_drained(max_steps=100)
    assert done[0] == want

    # ... and that sampling position IS the one-shot full-sequence
    # forward's last prompt position
    full_logits, _, _ = lm_forward(params,
                                   {"tokens": jnp.asarray(prompt[None])},
                                   cfg, "train", rules={})
    np.testing.assert_allclose(
        first_logits, np.asarray(full_logits[0, pl - 1], np.float32),
        rtol=3e-2, atol=3e-2)


def test_per_slot_decode_positions_match_isolated():
    """Batched decode with heterogeneous per-slot positions must equal each
    sequence decoded alone (continuous-batching correctness)."""
    import numpy as np
    from repro.models.lm import decode_step, init_caches
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(5))
    rngn = np.random.RandomState(3)
    max_len = 16
    lens = [3, 7]                          # heterogeneous prompt lengths
    prompts = [rngn.randint(0, cfg.vocab_size, size=(L,)).astype(np.int32)
               for L in lens]

    # isolated: run each prompt through teacher-forced decode alone
    iso_logits = []
    for prom in prompts:
        caches = init_caches(cfg, 1, max_len)
        for t, tok in enumerate(prom):
            lg, caches = decode_step(params,
                                     jnp.asarray([[tok]], jnp.int32),
                                     caches, jnp.asarray(t), cfg, {})
        iso_logits.append(np.asarray(lg[0], np.float32))

    # batched with per-slot positions: feed token t of each prompt at its
    # own position; shorter prompt repeats its last token (discarded)
    caches = init_caches(cfg, 2, max_len)
    pos = np.zeros(2, np.int32)
    out = [None, None]
    for t in range(max(lens)):
        toks = np.stack([[prompts[s][min(t, lens[s] - 1)]]
                         for s in range(2)]).astype(np.int32)
        lg, caches = decode_step(params, jnp.asarray(toks), caches,
                                 jnp.asarray(pos, jnp.int32), cfg, {})
        for s in range(2):
            if t == lens[s] - 1:
                out[s] = np.asarray(lg[s], np.float32)
        pos = np.minimum(pos + 1, np.asarray(lens) - 1)

    for s in range(2):
        np.testing.assert_allclose(out[s], iso_logits[s],
                                   rtol=3e-2, atol=3e-2)


def test_serve_submit_rejects_oversized_prompt_and_clamps_budget():
    """Admission contract: a prompt that can never fit the cache window is
    rejected with an actionable error at `submit`, and an admitted
    request's new-token budget is clamped to the window remainder instead
    of overflowing `slot_pos` past the cache."""
    from repro.serve import Request, ServeEngine
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, batch_slots=1, max_len=16, rules={})
    rng = np.random.RandomState(2)
    big = rng.randint(0, cfg.vocab_size, size=(16,)).astype(np.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(uid=0, prompt=big, max_new_tokens=1))
    # 10-token prompt in a 16-token window: at most 6 new tokens fit
    eng.submit(Request(uid=1, prompt=big[:10], max_new_tokens=50))
    done = eng.run_until_drained(max_steps=100)
    assert len(done[1]) == 6
    assert int(eng.slot_pos[0]) <= eng.max_len - 1


def test_run_until_drained_timeout_returns_partial_work():
    """`run_until_drained(max_steps=...)` budgets THIS call's steps and, on
    timeout, raises `DrainTimeout` carrying the completed work and the
    uids still in flight — a stalled drain loses nothing."""
    from repro.serve import DrainTimeout, Request, ServeEngine
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(params, cfg, batch_slots=1, max_len=64, rules={})
    rng = np.random.RandomState(4)
    for uid in range(3):
        eng.submit(Request(uid=uid,
                           prompt=rng.randint(0, cfg.vocab_size,
                                              size=(4,)).astype(np.int32),
                           max_new_tokens=8))
    with pytest.raises(DrainTimeout) as ei:
        eng.run_until_drained(max_steps=10)
    err = ei.value
    assert 0 in err.completed and len(err.completed[0]) == 8
    assert set(err.undrained) == {1, 2}
    assert set(err.completed) | set(err.undrained) == {0, 1, 2}
    # the engine is still usable: a fresh call finishes the backlog
    done = eng.run_until_drained(max_steps=500)
    assert sorted(done) == [0, 1, 2]
    assert all(len(v) == 8 for v in done.values())
    # and the budget is per CALL, not lifetime: a new request drains
    # within a budget smaller than the steps already run
    eng.submit(Request(uid=3, prompt=np.asarray([1, 2, 3], np.int32),
                       max_new_tokens=2))
    assert eng.steps_run > 8
    done = eng.run_until_drained(max_steps=8)
    assert len(done[3]) == 2
