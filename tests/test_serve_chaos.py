"""Chaos-serve invariant (docs/serving.md, the serving mirror of
tests/test_chaos.py):

under ANY seeded `FaultInjector` schedule — capacity-fetch faults, latency
spikes, admission faults — plus flash-crowd traffic offered at >= 4x the
engine's per-step service capacity, every submitted request resolves as
exactly ONE of

  * bit-equal to the unloaded oracle (`degraded=False`),
  * flagged `degraded=True` (stale-snapshot response), or
  * cleanly shed with a typed `Overloaded` result,

with no crash, no hang (bounded step budget) and no wrong unflagged score.
Plus the supporting machinery: determinism of a seeded replay, the
circuit-breaker cycle, deadline shedding on a virtual clock, queue-full
backpressure, stale-serve bit-equality for previously-seen rows, and
admission-time rejection of never-servable requests.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.nn.params import init_params
from repro.serve import (DLRMEngine, DLRMServeEngine, Overloaded,
                         ServeCircuitBreaker, ServeRequest)
from repro.serve.dlrm_engine import SHED_REASONS
from repro.train.fault_tolerance import FaultInjector, FaultSpec

EXAMPLES = 4
MAX_BATCH = 16
CACHE_ROWS = 192


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("dlrm-m1")


@pytest.fixture(scope="module")
def ebc(cfg):
    return EmbeddingBagCollection.build(cfg, n_shards=1,
                                        strategy="replicated")


@pytest.fixture(scope="module")
def params(cfg, ebc):
    return init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(2))


@pytest.fixture(scope="module")
def oracle(cfg, params):
    """Unloaded reference: the read-only engine with a cache big enough to
    never split or evict — existing tests pin it bit-equal to the dense
    uncached forward."""
    return DLRMEngine(params, cfg,
                      CachedEmbeddingBagCollection.build(cfg,
                                                         cache_rows=2048))


class VClock:
    """Deterministic virtual clock (deadline arithmetic, no wall time)."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _request(cfg, ebc, uid, step, deadline=None, flash=False):
    """Seeded drifting-Zipf request; `flash` collapses onto a churned
    8-key hot set per table (the flash-crowd phase)."""
    raw = make_dlrm_batch(cfg, EXAMPLES, step=step, zipf_alpha=1.05)
    idx = np.asarray(raw["idx"]).copy()
    for t, h in enumerate(cfg.hash_sizes):
        col = (idx[:, t, :] + 3 * step) % h
        if flash:
            col = (col % 8 + (step // 4) * 8) % h
        idx[:, t, :] = col
    idx = np.asarray(ebc.offset_indices(idx))
    return ServeRequest(uid, raw["dense"], idx, deadline=deadline)


def _chaos_replay(cfg, ebc, params, seed):
    """Flash-crowd replay at 4x offered load under a seeded schedule.

    8 requests x 4 examples offered per step vs MAX_BATCH=16 examples
    served: 2x in examples, 4x in requests against the <=4-requests-per-
    batch service rate, on a queue of 12. Returns (engine, requests)."""
    inj = FaultInjector.from_seed(seed, 24,
                                  sites=("serve.fetch", "serve.admit"),
                                  n_faults=4)
    clock = VClock()
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=12,
                             max_batch=MAX_BATCH, clock=clock,
                             shed_slack=0.5, injector=inj)
    reqs = {}
    uid = 0
    for step in range(8):
        for _ in range(8):
            r = _request(cfg, ebc, uid, step, deadline=clock() + 3.0,
                         flash=True)
            reqs[uid] = r
            engine.submit(r)
            uid += 1
        engine.step()
        clock.advance(1.0)
    engine.run(max_steps=200)          # bounded: no-hang guarantee
    return engine, reqs


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_chaos_serve_invariant(cfg, ebc, params, oracle, seed):
    """THE invariant: every request resolves as exactly one of
    {bit-equal, flagged degraded, cleanly shed} — never a wrong unflagged
    score, never a dropped uid."""
    engine, reqs = _chaos_replay(cfg, ebc, params, seed)
    assert set(engine.results) == set(reqs)        # nothing lost
    n_exact = n_degraded = n_shed = 0
    for uid, req in reqs.items():
        res = engine.results[uid]
        if isinstance(res, Overloaded):
            assert res.reason in SHED_REASONS
            n_shed += 1
        elif res.degraded:
            n_degraded += 1
        else:
            want = oracle.predict({"dense": req.dense, "idx": req.idx})
            np.testing.assert_array_equal(res.probs, want)
            n_exact += 1
    m = engine.metrics
    assert n_exact + n_degraded + n_shed == len(reqs)
    assert m.served + m.shed == m.submitted == len(reqs)
    assert n_shed > 0        # 4x offered load MUST shed on a queue of 12


def test_chaos_replay_deterministic(cfg, ebc, params):
    """Same seed => same statuses, same bytes, same metrics."""
    a, _ = _chaos_replay(cfg, ebc, params, seed=1)
    b, _ = _chaos_replay(cfg, ebc, params, seed=1)
    assert set(a.results) == set(b.results)
    for uid in a.results:
        ra, rb = a.results[uid], b.results[uid]
        assert type(ra) is type(rb)
        if isinstance(ra, Overloaded):
            assert ra.reason == rb.reason
        else:
            assert ra.degraded == rb.degraded
            np.testing.assert_array_equal(ra.probs, rb.probs)
    sa, sb = a.metrics.snapshot(), b.metrics.snapshot()
    for k in ("served", "shed", "degraded", "batches", "stale_batches"):
        assert sa[k] == sb[k], k
    assert a.breaker.transitions == b.breaker.transitions


def test_stale_serve_bit_equal_for_seen_rows(cfg, ebc, params):
    """Degrade-don't-die correctness: the tier is read-only, so a degraded
    response whose rows were ALL previously fetched is bit-equal to the
    healthy response — the stale snapshot can only differ on never-seen
    (zero-filled) rows, and those responses are flagged."""
    inj = FaultInjector([FaultSpec("serve.fetch", 1, "error"),
                         FaultSpec("serve.fetch", 2, "error")])
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=8,
                             max_batch=MAX_BATCH, injector=inj)
    req = _request(cfg, ebc, 0, 0)
    engine.submit(req)
    engine.step()                                  # fetch 0: healthy
    healthy = engine.results[0]
    assert not healthy.degraded
    # same rows again, now under a fetch fault -> degraded but bit-equal
    again = ServeRequest(1, req.dense, req.idx)
    engine.submit(again)
    engine.step()                                  # fetch 1: injected fault
    stale = engine.results[1]
    assert stale.degraded
    np.testing.assert_array_equal(stale.probs, healthy.probs)
    # fresh rows under a fault -> still served, flagged degraded
    fresh = _request(cfg, ebc, 2, 19)
    engine.submit(fresh)
    engine.step()                                  # fetch 2: injected fault
    assert engine.results[2].degraded


def test_circuit_breaker_full_cycle(cfg, ebc, params):
    """healthy -> stale_only (consecutive fetch faults) -> healthy (probe
    successes), end to end through the engine."""
    inj = FaultInjector([FaultSpec("serve.fetch", 0, "error"),
                         FaultSpec("serve.fetch", 1, "error")])
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    breaker = ServeCircuitBreaker(demote_after=2, promote_after=2,
                                  probe_every=2)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=8,
                             max_batch=MAX_BATCH, injector=inj,
                             breaker=breaker)
    for uid in range(10):
        engine.submit(_request(cfg, ebc, uid, uid))
        engine.step()
    states = [s for s, _ in breaker.transitions]
    assert "stale_only" in states
    assert states[-1] == "healthy"                 # probes healed it
    # while stale_only, batches served from the snapshot (flagged)
    assert engine.metrics.stale_batches >= 2
    # and afterwards healthy responses are exact again
    assert not engine.results[9].degraded


def test_breaker_pressure_watermarks():
    """healthy <-> shedding transitions on queue-depth watermarks."""
    br = ServeCircuitBreaker(shed_enter=0.75, shed_exit=0.25)
    br.record_pressure(0.5)
    assert br.state == "healthy"
    br.record_pressure(0.8)
    assert br.state == "shedding"
    br.record_pressure(0.5)                        # hysteresis band
    assert br.state == "shedding"
    br.record_pressure(0.2)
    assert br.state == "healthy"
    assert [s for s, _ in br.transitions] == ["shedding", "healthy"]


def test_deadline_shedding_on_virtual_clock(cfg, ebc, params):
    """An expired deadline sheds cleanly; an open one is served."""
    clock = VClock()
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=8,
                             max_batch=MAX_BATCH, clock=clock)
    engine.submit(_request(cfg, ebc, 0, 0, deadline=0.5))
    engine.submit(_request(cfg, ebc, 1, 1, deadline=9.0))
    clock.advance(1.0)                             # uid 0 expires queued
    engine.step()
    shed = engine.results[0]
    assert isinstance(shed, Overloaded) and shed.reason == "deadline"
    assert not engine.results[1].degraded
    assert engine.metrics.shed_deadline == 1


def test_queue_full_backpressure_is_typed(cfg, ebc, params):
    """Overflowing the bounded queue returns (and records) `Overloaded`
    rather than raising or growing without bound."""
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=2,
                             max_batch=MAX_BATCH)
    outcomes = [engine.submit(_request(cfg, ebc, uid, uid))
                for uid in range(5)]
    assert outcomes[:2] == [None, None]
    assert all(isinstance(o, Overloaded) and o.reason == "queue_full"
               for o in outcomes[2:])
    assert engine.metrics.shed_queue_full == 3
    engine.run()
    assert len(engine.results) == 5                # sheds recorded too


def test_never_servable_requests_rejected_at_submit(cfg, ebc, params):
    """Malformed != overloaded: requests that could never form a batch
    (too many examples, working set over the cache) raise at submit."""
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=24)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=8, max_batch=4)
    raw = make_dlrm_batch(cfg, 8, step=0)
    idx = np.asarray(ebc.offset_indices(np.asarray(raw["idx"])))
    with pytest.raises(ValueError, match="max_batch"):
        engine.submit(ServeRequest(0, raw["dense"], idx))
    small = ServeRequest(1, raw["dense"][:4], idx[:4])
    assert len(np.unique(idx[:4][idx[:4] >= 0])) > 24
    with pytest.raises(ValueError, match="cache_rows"):
        engine.submit(small)
