"""Hypothesis property tests on the system's invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (pip install .[dev])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core.cache import CachedEmbeddingBagCollection  # noqa: E402
from repro.core.embedding import EmbeddingBagCollection  # noqa: E402
from repro.core.placement import plan_placement  # noqa: E402
from repro.kernels import ops as kernel_ops  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.nn.layers import (  # noqa: E402
    blockwise_attention,
    blockwise_attention_skip,
    full_attention,
)
from repro.nn.mamba2 import ssd_chunked, ssd_decode_step  # noqa: E402

# ---------------------------------------------------------------------------
# placement planner invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 24),
    n_shards=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    strategy=st.sampled_from(["auto", "table_wise", "row_wise",
                              "column_wise", "replicated", "cached_host"]),
)
def test_placement_invariants(n, n_shards, seed, strategy):
    rng = np.random.RandomState(seed)
    hashes = [int(h) for h in rng.randint(30, 200_000, size=n)]
    loads = [float(ld) for ld in rng.uniform(1, 60, size=n)]
    budget = max(hashes) * 64 * 4 * 2 + 1     # every table fits one shard
    plan = plan_placement(hashes, loads, 64, n_shards, budget,
                          strategy=strategy)
    # 1. every table has a slot; offsets are non-overlapping
    spans = sorted(zip(plan.table_offsets, hashes))
    for (o1, h1), (o2, _) in zip(spans, spans[1:]):
        assert o1 + h1 <= o2, "tables overlap"
    assert spans[-1][0] + spans[-1][1] <= plan.total_rows
    # 2. table_wise: no table straddles a shard boundary
    if plan.strategy == "table_wise":
        shard_rows = plan.total_rows // n_shards
        for off, h in zip(plan.table_offsets, hashes):
            assert off // shard_rows == (off + h - 1) // shard_rows
        # 3. each table assigned exactly one shard
        assert len(plan.shard_of_table) == n
        assert all(0 <= s < n_shards for s in plan.shard_of_table)
    # 4. row_wise total rows divide evenly
    if plan.strategy == "row_wise":
        assert plan.total_rows % n_shards == 0
    # 5. cached_host: device cache is aligned, non-empty, within the table
    if plan.strategy == "cached_host":
        assert 0 < plan.cache_rows <= plan.total_rows
        assert plan.cache_rows % 8 == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_placement_load_balance_beats_naive(seed):
    """Bin-packing on load should not be worse than contiguous assignment."""
    rng = np.random.RandomState(seed)
    n, n_shards = 32, 8
    hashes = [int(h) for h in rng.randint(1000, 100_000, size=n)]
    loads = [float(ld) for ld in np.sort(rng.pareto(1.2, size=n) * 10 + 1)]
    budget = sum(hashes) * 64 * 4.0          # capacity not binding
    plan = plan_placement(hashes, loads, 64, n_shards, budget,
                          strategy="table_wise")
    naive = np.zeros(n_shards)
    for t in range(n):
        naive[t % n_shards] += loads[t]
    naive_imbalance = naive.max() / naive.mean()
    assert plan.load_imbalance <= naive_imbalance + 1e-6

# ---------------------------------------------------------------------------
# embedding bag / rowwise adagrad algebra
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 8),
       lk=st.integers(1, 9))
def test_embedding_bag_linearity(seed, b, lk):
    """sum-pooled lookup is linear in the table."""
    rng = np.random.RandomState(seed)
    t1 = jnp.asarray(rng.randn(20, 12), jnp.float32)
    t2 = jnp.asarray(rng.randn(20, 12), jnp.float32)
    idx = jnp.asarray(rng.randint(-1, 20, size=(b, lk)), jnp.int32)
    lhs = ref.embedding_bag_ref(t1 + t2, idx)
    rhs = ref.embedding_bag_ref(t1, idx) + ref.embedding_bag_ref(t2, idx)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_rowwise_adagrad_untouched_rows_frozen(seed):
    rng = np.random.RandomState(seed)
    h = 30
    table = jnp.asarray(rng.randn(h, 8), jnp.float32)
    accum = jnp.asarray(np.abs(rng.randn(h)), jnp.float32)
    idx = jnp.asarray(rng.randint(0, 10, size=(6,)), jnp.int32)  # rows < 10
    grads = jnp.asarray(rng.randn(6, 8), jnp.float32)
    t2, a2 = ref.rowwise_adagrad_ref(table, accum, idx, grads, 0.1)
    np.testing.assert_array_equal(np.asarray(t2)[10:], np.asarray(table)[10:])
    np.testing.assert_array_equal(np.asarray(a2)[10:], np.asarray(accum)[10:])
    assert np.all(np.asarray(a2)[np.unique(np.asarray(idx))]
                  >= np.asarray(accum)[np.unique(np.asarray(idx))])

# ---------------------------------------------------------------------------
# async cache stream invariants (core/cache.py AsyncCacheState)
# ---------------------------------------------------------------------------


def _tiny_cache_cfg(n_rows: int):
    return dataclasses.replace(
        get_smoke_config("dlrm-m1"), n_sparse_features=1,
        hash_sizes=(n_rows,), mean_lookups=(4,),
        bottom_mlp=(8, 16), top_mlp=(8, 1))


def _assert_slot_map_bijection(astate):
    """Invariant (a): the slot map is a bijection onto resident rows —
    every occupied slot's row points back at it and vice versa, with no
    phantom entries on either side."""
    occupied = np.flatnonzero(astate.slot_row >= 0)
    np.testing.assert_array_equal(
        astate.row_slot[astate.slot_row[occupied]], occupied)
    cached_rows = np.flatnonzero(astate.row_slot >= 0)
    np.testing.assert_array_equal(
        astate.slot_row[astate.row_slot[cached_rows]], cached_rows)
    assert len(occupied) == len(cached_rows)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n_rows=st.sampled_from([64, 96, 128]),
       cache_rows=st.sampled_from([36, 48]),
       steps=st.integers(3, 6))
def test_async_cache_stream_invariants_and_bit_exactness(
        seed, n_rows, cache_rows, steps):
    """Random index streams through the overlapped schedule assert, per
    step: (a) slot-map bijection, (b) LFU-with-decay never evicts a slot
    the in-flight batch references, and after N steps (c) async and sync
    paths leave bit-identical embeddings and AdaGrad state."""
    rng = np.random.RandomState(seed)
    cfg = _tiny_cache_cfg(n_rows)
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=cache_rows)
    mega = jnp.asarray(rng.randn(ebc.plan.total_rows, cfg.embed_dim),
                       jnp.float32)
    # (4, 1, 4) multi-hot batches with pads: working set <= 16 <= C/2, so
    # double buffering never thrashes
    idx_stream = [rng.randint(-1, n_rows, size=(4, 1, 4)).astype(np.int32)
                  for _ in range(steps)]
    grads = [jnp.asarray(rng.randn(4, 1, cfg.embed_dim), jnp.float32)
             for _ in range(steps)]

    astate = cc.init_async_state(mega)
    local = cc.take_async(astate, idx_stream[0], train=True)
    for k in range(steps):
        _assert_slot_map_bijection(astate)
        fi, fg = ebc.per_lookup_grads(jnp.asarray(local), grads[k])
        new_cache, new_accum = kernel_ops.rowwise_adagrad_update(
            astate.cache, astate.cache_accum, fi, fg, 0.05)
        cc.mark_updated(astate, new_cache, new_accum)
        if k + 1 < steps:
            inflight = astate.inflight_mask.copy()
            cc.stage_async(astate, idx_stream[k + 1], train=True)
            staged = astate.pending[-1]
            assert not inflight[staged.victim_slots].any()     # (b)
            assert not inflight[staged.slots].any()
            _assert_slot_map_bijection(astate)
            local = cc.take_async(astate, idx_stream[k + 1], train=True)
    mega_async, accum_async = cc.materialize_async(astate)

    state = cc.init_state(mega)
    for k in range(steps):
        loc = cc.prepare(state, idx_stream[k], train=True)
        fi, fg = ebc.per_lookup_grads(jnp.asarray(loc), grads[k])
        new_cache, new_accum = kernel_ops.rowwise_adagrad_update(
            state.cache, state.cache_accum, fi, fg, 0.05)
        cc.mark_updated(state, new_cache, new_accum)
    mega_sync, accum_sync = cc.materialize(state)
    np.testing.assert_array_equal(np.asarray(mega_async),                # (c)
                                  np.asarray(mega_sync))
    np.testing.assert_array_equal(np.asarray(accum_async),
                                  np.asarray(accum_sync))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_async_prefetch_preserves_bijection_and_never_evicts_staged(seed):
    """stage_rows (k-step lookahead) keeps the slot map a bijection and
    never evicts rows another queued plan admitted."""
    rng = np.random.RandomState(seed)
    cfg = _tiny_cache_cfg(96)
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=40)
    mega = jnp.zeros((cc.ebc.plan.total_rows, cfg.embed_dim), jnp.float32)
    astate = cc.init_async_state(mega)
    first = rng.choice(96, size=20, replace=False)
    assert cc.stage_rows(astate, first) == 20
    staged_before = astate.row_slot[first].copy()
    cc.stage_rows(astate, rng.randint(0, 96, size=60))
    _assert_slot_map_bijection(astate)
    # the first plan's rows kept their slots (protected while queued)
    np.testing.assert_array_equal(astate.row_slot[first], staged_before)
    cc.commit_async(astate)
    _assert_slot_map_bijection(astate)
    assert astate.resident <= 40


# ---------------------------------------------------------------------------
# attention invariances
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_blockwise_attention_matches_full(seed):
    rng = np.random.RandomState(seed)
    b, s, h, dh = 2, 64, 2, 16
    q = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    o_full = full_attention(q, k, v, causal=True)
    o_blk = blockwise_attention(q, k, v, block_q=16, block_k=16)
    o_skip = blockwise_attention_skip(q, k, v, block_q=16, block_k=16)
    np.testing.assert_allclose(o_blk, o_full, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(o_skip, o_full, rtol=2e-4, atol=2e-4)


def test_attention_is_causal(rng):
    """Future tokens must not influence past outputs."""
    b, s, h, dh = 1, 32, 2, 8
    q = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    base = full_attention(q, k, v, causal=True)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    pert = full_attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(base[:, :20], pert[:, :20], rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(base[:, 21:], pert[:, 21:])

# ---------------------------------------------------------------------------
# mamba2 SSD: chunked == recurrent
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_matches_recurrence(seed, chunk):
    rng = np.random.RandomState(seed)
    b, s, h, p, g, n = 2, 16, 4, 8, 2, 6
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32) * 0.5
    dt = jnp.asarray(np.abs(rng.randn(b, s, h)) * 0.5 + 0.1, jnp.float32)
    A = -jnp.asarray(np.abs(rng.randn(h)) + 0.2, jnp.float32)
    B = jnp.asarray(rng.randn(b, s, g, n), jnp.float32) * 0.5
    C = jnp.asarray(rng.randn(b, s, g, n), jnp.float32) * 0.5

    y_chunk, final = ssd_chunked(x, dt, A, B, C, chunk)

    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                     B[:, t], C[:, t])
        ys.append(y_t)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_rec, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(final, state, rtol=2e-3, atol=2e-3)

# ---------------------------------------------------------------------------
# int8 KV cache quantization error bound
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_int8_kv_quantization_bounded(seed):
    from repro.nn.layers import _quantize_i8
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, 4, 3, 16) * rng.uniform(0.01, 10),
                    jnp.float32)
    q, scale = _quantize_i8(x)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(scale)
                 - np.asarray(x))
    # max error is half a quantization step per (token, head)
    step = np.asarray(scale)
    assert np.all(err <= step[..., 0][..., None] * 0.5 + 1e-7)
