"""Training-runtime behaviour: checkpoint roundtrip + atomicity + elastic
restore, preemption drain, straggler detection, optimizers, EASGD math,
gradient compression, data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline, ShardedLoader
from repro.optim import (adagrad, adamw, clip_by_global_norm, easgd_init,
                         easgd_sync, error_feedback_compress, local_sgd_sync,
                         sgd)
from repro.optim.compression import init_residual
from repro.optim.easgd import replica_step
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (FaultInjector, FaultSpec,
                                         PreemptionHandler,
                                         StragglerDetector,
                                         run_resilient_loop)

# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(rng):
    return {"a": jnp.asarray(rng.randn(4, 3), jnp.float32),
            "b": {"c": jnp.asarray(rng.randn(7), jnp.bfloat16),
                  "d": jnp.asarray(5, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(3, tree)
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.latest_step() == 3


def test_checkpoint_async_and_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    for step in (1, 2, 3, 4):
        mgr.save(step, tree, async_=True)
    mgr.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_000000003", "step_000000004"]
    assert mgr.latest_step() == 4


def test_checkpoint_gc_keep_zero_deletes_everything(tmp_path, rng):
    """keep=0 means keep NONE: steps[:-0] is the empty slice, so the old
    negative-slice _gc silently kept every directory forever."""
    mgr = CheckpointManager(str(tmp_path), keep=0)
    tree = _tree(rng)
    for step in (1, 2):
        mgr.save(step, tree)
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert dirs == []
    # every step is gone, so the stale LATEST must not dangle
    assert mgr.latest_step() is None


def test_checkpoint_latest_survives_gced_pointer(tmp_path, rng):
    """A LATEST file pointing at a directory _gc removed must fall back to
    the newest surviving step, not hand restore() a dangling path."""
    import shutil
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    for step in (1, 2, 3):
        mgr.save(step, tree)
    assert mgr.latest_step() == 3
    shutil.rmtree(tmp_path / "step_000000003")   # simulate external GC
    assert mgr.latest_step() == 2
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree),
                      step=mgr.latest_step())
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_with_new_sharding(tmp_path, rng):
    """Elastic restore: same bytes, different target sharding (1-device
    'mesh' here; the mechanism is sharding-agnostic device_put)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(rng.randn(8, 4), jnp.float32)}
    mgr.save(1, tree)
    shardings = {"w": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree),
                      shardings=shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_checkpoint_no_partial_visibility(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.zeros(2)})


def test_checkpoint_resave_same_step_overwrites(tmp_path, rng):
    """Re-saving a step that already exists on disk (replay after restore
    fell back past a corrupt copy) must overwrite it, not crash on the
    non-empty destination directory."""
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(1, tree)
    tree2 = jax.tree.map(jnp.ones_like, tree)
    mgr.save(1, tree2)
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree), step=1)
    for a, b in zip(jax.tree.leaves(tree2), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_save_error_surfaces_on_wait(tmp_path, rng):
    """A failed async writer must NOT vanish into its daemon thread: the
    parked exception re-raises on wait()."""
    inj = FaultInjector([FaultSpec("checkpoint.write", 0, "error")])
    mgr = CheckpointManager(str(tmp_path), injector=inj)
    mgr.save(1, _tree(rng), async_=True)
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.wait()
    # the error is consumed: the manager is usable again afterwards
    mgr.save(2, _tree(rng))
    assert mgr.latest_step() == 2


def test_checkpoint_async_save_error_surfaces_on_next_save(tmp_path, rng):
    """...and on the NEXT save() call too (save() drains the in-flight
    writer first), so a fire-and-forget loop cannot silently lose steps."""
    inj = FaultInjector([FaultSpec("checkpoint.write", 0, "error")])
    mgr = CheckpointManager(str(tmp_path), injector=inj)
    mgr.save(1, _tree(rng), async_=True)
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        mgr.save(2, _tree(rng))


def test_checkpoint_restore_structure_mismatch_names_leaves(tmp_path, rng):
    """Tree/manifest disagreement is a caller bug, not corruption: the
    error must NAME the missing/extra leaf paths (the old code raised a
    bare KeyError on the first absent path)."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(rng))
    bad = {"a": jnp.zeros((4, 3)), "b": {"c": jnp.zeros(7, jnp.bfloat16)},
           "z": jnp.zeros(2)}
    with pytest.raises(ValueError, match="structure mismatch") as ei:
        mgr.restore(bad, step=1)
    assert "z" in str(ei.value)          # in the example tree, not saved
    assert "b/d" in str(ei.value)        # saved, not in the example tree

# ---------------------------------------------------------------------------
# fault tolerance loop
# ---------------------------------------------------------------------------


def test_preemption_checkpoints_and_stops():
    preempt = PreemptionHandler(signals=())
    saved = []
    steps_run = []

    def step_fn(step):
        steps_run.append(step)
        if step == 4:
            preempt.trigger()            # simulated SIGTERM mid-run

    last = run_resilient_loop(step_fn, 100, lambda s: saved.append(s),
                              checkpoint_every=50, preemption=preempt)
    assert last == 5                     # stopped right after the signal
    assert saved == [5]                  # checkpoint-now on preemption


def test_preemption_at_checkpoint_boundary_saves_once():
    """A preemption landing exactly on a scheduled checkpoint step must
    save ONCE — the old loop wrote the same step twice back to back."""
    preempt = PreemptionHandler(signals=())
    saved = []

    def step_fn(step):
        if step == 4:
            preempt.trigger()            # step 5 is also a scheduled save

    last = run_resilient_loop(step_fn, 100, lambda s: saved.append(s),
                              checkpoint_every=5, preemption=preempt)
    assert last == 5
    assert saved == [5]                  # deduped, not [5, 5]


def test_fault_injector_rejects_unknown_site_and_kind():
    with pytest.raises(ValueError, match="site"):
        FaultInjector([FaultSpec("no.such.site", 0, "error")])
    with pytest.raises(ValueError, match="kind"):
        FaultInjector([FaultSpec("loop.step", 0, "meteor")])


def test_straggler_detection():
    det = StragglerDetector(window=20, z_threshold=3.0, warmup=5)
    for _ in range(19):
        det.record(0.10 + np.random.RandomState(1).rand() * 1e-3)
    assert det.record(0.50) is True      # 5x step time -> flagged
    assert det.flagged_steps


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    opt = adamw(0.1, weight_decay=0.0, clip_norm=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for i in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = opt.apply(params, grads, state,
                                  jnp.asarray(i, jnp.int32))
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_adagrad_and_sgd_step():
    for opt in (adagrad(0.5), sgd(0.1, momentum=0.9)):
        params = {"x": jnp.asarray([1.0])}
        state = opt.init(params)
        p2, _ = opt.apply(params, {"x": jnp.asarray([1.0])}, state,
                          jnp.asarray(0))
        assert float(p2["x"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               rtol=1e-6)

# ---------------------------------------------------------------------------
# EASGD / local SGD (paper section III-A.6)
# ---------------------------------------------------------------------------


def test_easgd_converges_and_center_tracks():
    """R replicas on a quadratic with different minima: EASGD pulls the
    center to the consensus (mean of minima)."""
    minima = jnp.asarray([[1.0], [3.0]])
    state = easgd_init({"x": jnp.zeros(1)}, n_replicas=2)
    for step in range(300):
        grads = {"x": 2 * (state.replicas["x"] - minima)}
        state = replica_step(state, grads, lr=0.05)
        if step % 5 == 4:
            state = easgd_sync(state, alpha=0.3, beta=0.3)
    assert abs(float(state.center["x"][0]) - 2.0) < 0.2


def test_local_sgd_sync_averages():
    state = easgd_init({"x": jnp.zeros(2)}, n_replicas=4)
    state = state._replace(replicas={"x": jnp.asarray(
        [[1.0, 0.], [2.0, 0.], [3.0, 0.], [6.0, 0.]])})
    state = local_sgd_sync(state)
    np.testing.assert_allclose(np.asarray(state.replicas["x"])[:, 0],
                               [3.0] * 4)

# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_error_feedback_unbiased_over_time(rng):
    """With error feedback, the SUM of compressed grads tracks the sum of
    true grads (residual stays bounded)."""
    true_sum = np.zeros(64, np.float32)
    comp_sum = np.zeros(64, np.float32)
    residual = init_residual({"g": jnp.zeros(64)})
    for _ in range(50):
        g = {"g": jnp.asarray(rng.randn(64) * 1e-3, jnp.float32)}
        comp, residual = error_feedback_compress(g, residual)
        true_sum += np.asarray(g["g"])
        comp_sum += np.asarray(comp["g"], np.float32)
    resid = np.abs(true_sum - comp_sum)
    assert resid.max() < 1e-4            # residual bounded, not accumulating

# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_sharded_loader_partitions_batch():
    def gen(step, seed):
        return {"x": np.arange(16) + 100 * step}

    loaders = [ShardedLoader(gen, 16, host_index=i, num_hosts=4)
               for i in range(4)]
    slices = [ld.host_slice(2) for ld in loaders]
    got = np.concatenate([s["x"] for s in slices])
    np.testing.assert_array_equal(got, np.arange(16) + 200)


def test_pipeline_prefetch_and_order():
    def gen(step):
        return {"x": np.asarray([step])}

    pipe = DataPipeline(gen, prefetch=2)
    steps = [next(pipe)[0] for _ in range(5)]
    pipe.close()
    assert steps == [0, 1, 2, 3, 4]


# ---------------------------------------------------------------------------
# data pipeline fault injection: a dying reader thread must surface in the
# consumer within one step — never deadlock it — and shutdown must be clean
# with batches still queued (the async-fetch-stream hardening, docs/cache.md)
# ---------------------------------------------------------------------------


def test_pipeline_transform_error_surfaces_in_consumer():
    """The dedup hook runs inside the reader thread; its failure must
    surface exactly like a generator failure."""
    def bad_hook(batch):
        if int(batch["x"][0]) >= 2:
            raise ValueError("hook boom")
        return batch

    pipe = DataPipeline(lambda s: {"x": np.asarray([s])}, prefetch=1,
                        transform=bad_hook)
    assert next(pipe)[1]["x"][0] == 0
    assert next(pipe)[1]["x"][0] == 1
    with pytest.raises(RuntimeError, match="step 2"):
        next(pipe)
    pipe.close()


def test_pipeline_reader_kill_surfaces_within_one_step():
    """A BaseException 'kill' (SystemExit) inside the reader mid-stream
    must reach the consumer as a RuntimeError promptly, not starve it."""
    import time

    def gen(step):
        if step >= 1:
            raise SystemExit("reader killed")
        return {"x": np.asarray([step])}

    pipe = DataPipeline(gen, prefetch=1)
    assert next(pipe)[1]["x"][0] == 0
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="step 1"):
        next(pipe)
    assert time.monotonic() - t0 < 2.0         # within one step, no hang
    pipe.close()


def test_pipeline_vanished_worker_detected_not_deadlocked():
    """A reader that dies WITHOUT parking an error (thread gone, queue
    empty) is caught by the liveness check instead of blocking forever."""
    import time

    class _DyingPipeline(DataPipeline):
        def _worker(self):
            return                              # vanishes silently

    pipe = _DyingPipeline(lambda s: {"x": np.asarray([s])}, prefetch=1)
    t0 = time.monotonic()
    with pytest.raises(RuntimeError, match="died"):
        next(pipe)
    assert time.monotonic() - t0 < 2.0
    pipe.close()


def test_pipeline_peeked_batches_survive_worker_death():
    """Good batches buffered by peek() before a vanished worker was
    detected are still delivered, in order, BEFORE the failure raises —
    completed work (e.g. a checkpointable final step) is not dropped."""
    class _TwoThenVanish(DataPipeline):
        def _worker(self):                      # parks NOTHING on exit
            for step in range(2):
                self._q.put((step, {"x": np.asarray([step])}))

    pipe = _TwoThenVanish(lambda s: {}, prefetch=4)
    assert pipe.peek(5) is None                 # buffers 0..1, sees death
    assert next(pipe)[0] == 0                   # buffered batches delivered
    assert next(pipe)[0] == 1
    with pytest.raises(RuntimeError, match="died"):
        next(pipe)                              # then the failure raises
    pipe.close()


def test_pipeline_dead_worker_observed_via_peek_still_fails_next():
    """Regression: when a vanished worker is first observed by peek()
    (the lookahead path), the liveness error must stay sticky — the next
    __next__ raises RuntimeError, NOT a clean StopIteration that would
    make the trainer exit as if the dataset ended."""
    class _DyingPipeline(DataPipeline):
        def _worker(self):
            return

    pipe = _DyingPipeline(lambda s: {"x": np.asarray([s])}, prefetch=1)
    assert pipe.peek(0) is None                 # death observed softly here
    with pytest.raises(RuntimeError, match="died"):
        next(pipe)
    with pytest.raises(RuntimeError, match="died"):
        next(pipe)                              # and it stays sticky
    pipe.close()
    with pytest.raises(StopIteration):
        next(pipe)                              # explicit close wins


def test_pipeline_clean_shutdown_with_nonempty_queue():
    """close() with a full prefetch queue (consumer never drained it) must
    unblock the worker's put() and join the thread."""
    import time

    pipe = DataPipeline(lambda s: {"x": np.zeros(4)}, prefetch=4)
    time.sleep(0.2)                             # let the queue fill
    t0 = time.monotonic()
    pipe.close()
    assert time.monotonic() - t0 < 2.0
    assert not pipe._thread.is_alive()
    with pytest.raises(StopIteration):
        next(pipe)


def test_pipeline_peek_does_not_consume_and_preserves_order():
    pipe = DataPipeline(lambda s: {"x": np.asarray([s])}, prefetch=2)
    assert pipe.peek(1)["x"][0] == 1            # out-of-order peeks...
    assert pipe.peek(0)["x"][0] == 0
    steps = [next(pipe)[0] for _ in range(4)]   # ...don't disturb delivery
    pipe.close()
    assert steps == [0, 1, 2, 3]


def test_pipeline_peek_past_failure_returns_none_then_next_raises():
    """Peeking beyond the failure point degrades softly (None -> trainer
    falls back to strict-sync); the error itself raises on consumption."""
    def gen(step):
        if step >= 1:
            raise KeyError("boom")
        return {"x": np.asarray([step])}

    pipe = DataPipeline(gen, prefetch=1)
    assert pipe.peek(0)["x"][0] == 0
    assert pipe.peek(1) is None                 # failure peeked, not raised
    assert pipe.peek(3) is None
    assert next(pipe)[1]["x"][0] == 0           # good batch still delivered
    with pytest.raises(RuntimeError, match="step 1"):
        next(pipe)
    pipe.close()


def test_lookahead_rows_unions_upcoming_dedup_sets():
    from repro.data.pipeline import dedup_indices_hook, lookahead_rows

    def gen(step):
        return {"idx": np.asarray([[[step, step + 1, -1]]], np.int32)}

    pipe = DataPipeline(gen, prefetch=3,
                        transform=dedup_indices_hook([100]))
    rows = lookahead_rows(pipe, 3)
    np.testing.assert_array_equal(rows, [100, 101, 102, 103])
    assert next(pipe)[0] == 0                   # peeks consumed nothing
    pipe.close()


def test_lookahead_rows_stops_at_stream_failure():
    from repro.data.pipeline import dedup_indices_hook, lookahead_rows

    def gen(step):
        if step >= 2:
            raise ValueError("boom")
        return {"idx": np.asarray([[[step, -1, -1]]], np.int32)}

    pipe = DataPipeline(gen, prefetch=1,
                        transform=dedup_indices_hook([0]))
    rows = lookahead_rows(pipe, 5)              # union of the 2 good batches
    np.testing.assert_array_equal(rows, [0, 1])
    pipe.close()


# ---------------------------------------------------------------------------
# hypothesis: checkpoint round-trips arbitrary pytrees (skips without the
# [dev] extra — guarded import, stub decorators keep the module importable)
# ---------------------------------------------------------------------------

from conftest import HAS_HYPOTHESIS, requires_hypothesis  # noqa: E402

if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st  # noqa: E402
else:
    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda f: f

    def settings(*a, **k):
        return lambda f: f


@requires_hypothesis
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(1, 3),
       dtype=st.sampled_from(["float32", "bfloat16", "int32"]))
def test_checkpoint_roundtrip_fuzz(tmp_path_factory, seed, depth, dtype):
    import jax.numpy as jnp
    rng = np.random.RandomState(seed)
    tmp = tmp_path_factory.mktemp(f"ckpt{seed % 1000}")

    def make(d):
        if d == 0:
            shape = tuple(int(x) for x in rng.randint(1, 5, size=2))
            arr = rng.randn(*shape)
            return jnp.asarray(arr, dtype)
        return {f"k{i}": make(d - 1) for i in range(rng.randint(1, 3))}

    tree = make(depth)
    mgr = CheckpointManager(str(tmp))
    mgr.save(1, tree)
    out = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
