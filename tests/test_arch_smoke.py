"""Per-architecture REDUCED-config smoke tests (deliverable f): every
assigned arch instantiates, runs one forward/train step on CPU, asserts
output shapes and finiteness; decode paths run one cached step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config, shapes_for
from repro.data import make_dlrm_batch, make_lm_batch
from repro.models import (decode_step, init_caches, lm_loss, lm_param_specs,
                          prefill_step)
from repro.nn.params import init_params

LM_ARCHS = [n for n in ARCH_NAMES if not n.startswith("dlrm")]
DLRM_ARCHS = [n for n in ARCH_NAMES if n.startswith("dlrm")]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = {k: jnp.asarray(v) for k, v in make_lm_batch(cfg, b, s).items()}

    def loss_fn(p):
        loss, parts = lm_loss(p, batch, cfg)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # gradient exists and is finite for every leaf
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, np.float32)))
    # loss close to uniform-random baseline ln(V)
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(1))
    b, s = 2, 16
    batch = {k: jnp.asarray(v) for k, v in make_lm_batch(cfg, b, s).items()}
    batch.pop("targets")
    batch.pop("loss_mask")
    caches = init_caches(cfg, b, max_len=s + 4)
    logits, caches = prefill_step(params, batch, caches, cfg, {})
    if cfg.frontend == "audio":
        assert logits.shape == (b, cfg.n_codebooks, cfg.vocab_size)
        tok = jnp.zeros((b, 1, cfg.n_codebooks), jnp.int32)
    else:
        assert logits.shape == (b, cfg.vocab_size)
        tok = jnp.zeros((b, 1), jnp.int32)
    lg, caches2 = decode_step(params, tok, caches, jnp.asarray(s), cfg, {})
    assert np.all(np.isfinite(np.asarray(lg, np.float32)))
    # caches must actually change where written
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(caches), jax.tree.leaves(caches2)))
    assert changed


def test_decode_matches_forward_logits():
    """Teacher-forced decode must reproduce the train-mode logits."""
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(lm_param_specs(cfg), jax.random.PRNGKey(2))
    from repro.models.lm import lm_forward
    b, s = 1, 12
    rngn = np.random.RandomState(0)
    toks = jnp.asarray(rngn.randint(0, cfg.vocab_size, size=(b, s)),
                       jnp.int32)
    full_logits, _, _ = lm_forward(params, {"tokens": toks}, cfg, "train",
                                   rules={})
    caches = init_caches(cfg, b, max_len=s)
    for t in range(s):
        lg, caches = decode_step(params, toks[:, t:t + 1], caches,
                                 jnp.asarray(t), cfg, {})
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", DLRM_ARCHS)
def test_dlrm_arch_train_step(arch):
    from repro.core import EmbeddingBagCollection, dlrm_param_specs
    from repro.optim import adagrad
    from repro.train.steps import build_dlrm_train_step, dlrm_init_state
    cfg = get_smoke_config(arch)
    ebc = EmbeddingBagCollection.build(cfg, n_shards=4)
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.05)
    state = dlrm_init_state(ebc, opt, params)
    step = jax.jit(build_dlrm_train_step(cfg, ebc, opt))
    raw = make_dlrm_batch(cfg, 16)
    batch = {"dense": jnp.asarray(raw["dense"]),
             "idx": ebc.offset_indices(jnp.asarray(raw["idx"])),
             "label": jnp.asarray(raw["label"])}
    params2, state2, metrics = step(params, state, batch,
                                    jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["lookups"]) > 0
    # embedding rows touched by the batch must move
    assert not np.array_equal(np.asarray(params2["emb"]["mega"]),
                              np.asarray(params["emb"]["mega"]))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_shapes_registry(arch):
    shapes = shapes_for(arch)
    assert shapes, arch
    if arch in ("mamba2-780m", "jamba-v0.1-52b"):
        assert "long_500k" in shapes
    elif not arch.startswith("dlrm"):
        assert "long_500k" not in shapes       # full-attention archs skip it
