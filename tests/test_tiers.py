"""N-tier heterogeneous memory (core/tiers.py) + the EmbeddingTier protocol.

Covers the PR-level acceptance contract: every cached collection conforms
to the `EmbeddingTier` protocol, the 3-tier path is bit-exact against the
dense single-host oracle AND against the 2-tier path when the bulk tier is
sized to zero, residency is exclusive under any promotion/demotion
interleaving (hypothesis property), the mmap-backed bulk store round-trips,
and the old step builders keep working behind DeprecationWarning aliases.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAS_HYPOTHESIS, requires_hypothesis
from repro.configs import get_smoke_config
from repro.core.cache import (CachedEmbeddingBagCollection, CacheStats,
                              MultiHostCachedEmbeddingBagCollection)
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.core.tiers import (AsyncCachedTier, BulkCachedEmbeddingBagCollection,
                              EmbeddingTier, TierCacheStats, tier_conformance)
from repro.data.synthetic import make_dlrm_batch
from repro.kernels import ops
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import (build_async_cached_dlrm_train_step,
                               build_cached_dlrm_train_step,
                               build_cached_train_step,
                               build_multihost_cached_train_step,
                               cached_dlrm_init_state)

pytestmark = pytest.mark.compat

if HAS_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("dlrm-m1")


@pytest.fixture(scope="module")
def ebc(cfg):
    return EmbeddingBagCollection.build(cfg, n_shards=1,
                                        strategy="replicated")


def _batch(cfg, ebc, t, b=8):
    raw = make_dlrm_batch(cfg, b, step=t)
    return {"dense": jnp.asarray(raw["dense"]),
            "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
            "label": jnp.asarray(raw["label"])}


def _batch_idx(cfg, ebc, t, b=8):
    return _batch(cfg, ebc, t, b)["idx"]


def _bulk(cfg, **kw):
    kw.setdefault("cache_rows", 256)
    kw.setdefault("dram_rows", 300)
    kw.setdefault("bulk_chunk", 16)
    kw.setdefault("bulk_latency_us", 0.0)
    return BulkCachedEmbeddingBagCollection.build(cfg, **kw)


# ---------------------------------------------------------------------------
# protocol conformance
# ---------------------------------------------------------------------------


def test_every_cached_tier_conforms_to_embedding_tier(cfg):
    """All four tiers present the full EmbeddingTier surface — the factory
    and every cached call site outside core/ consume them through it."""
    sync = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
    tiers = [sync,
             AsyncCachedTier(sync),
             MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=2,
                                                         cache_rows=256),
             _bulk(cfg)]
    for t in tiers:
        assert tier_conformance(t), type(t).__name__
        assert isinstance(t, EmbeddingTier)


def test_factory_rejects_non_tier_with_protocol_hint(cfg, ebc):
    with pytest.raises(TypeError, match="EmbeddingTier"):
        build_cached_train_step(cfg, object(), adagrad(0.01))


def test_deprecated_builders_warn_and_delegate(cfg):
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
    opt = adagrad(0.01)
    with pytest.warns(DeprecationWarning, match="build_cached_train_step"):
        build_cached_dlrm_train_step(cfg, cc, opt)
    with pytest.warns(DeprecationWarning, match="build_cached_train_step"):
        build_async_cached_dlrm_train_step(cfg, cc, opt)
    mc = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=2,
                                                     cache_rows=256)
    with pytest.warns(DeprecationWarning, match="build_cached_train_step"):
        build_multihost_cached_train_step(cfg, mc, opt)


def test_tier_stats_snapshot_and_reset():
    s = TierCacheStats(hits=5, misses=3, dram_hits=2, bulk_hits=1,
                       promotion_bytes=640, bulk_sched_us=100,
                       bulk_wait_us=25)
    snap = s.snapshot()
    assert snap["cache_hits"] == 5
    assert snap["tier_hit_dram"] == 2
    assert snap["tier_hit_bulk"] == 1
    assert snap["tier_promotion_bytes"] == 640
    assert s.dram_hit_rate == pytest.approx(2 / 3)
    assert s.hidden_fraction == pytest.approx(0.75)
    s.reset()
    assert s.hits == s.dram_hits == s.bulk_hits == s.promotion_bytes == 0
    # the generic reset covers the base class too
    b = CacheStats(hits=7, fetch_chunks=2)
    b.reset()
    assert b.hits == b.fetch_chunks == 0


# ---------------------------------------------------------------------------
# bit-exactness: dense oracle / 2-tier equivalence
# ---------------------------------------------------------------------------


def test_three_tier_roundtrip_matches_dense_oracle(cfg, ebc):
    """Training updates streamed through HBM-cache evictions, DRAM
    overflow demotions, and bulk promotions materialize to the SAME table
    as the dense single-host update — the 3-tier plumbing moves bits, it
    never transforms them."""
    lr, steps = 0.05, 5
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(1))
    bc = _bulk(cfg, cache_rows=160)
    state = bc.init_state(params["mega"])

    mega_ref = params["mega"]
    accum_ref = jnp.zeros((ebc.plan.total_rows,), jnp.float32)
    rng = np.random.RandomState(0)
    for step in range(steps):
        idx = _batch_idx(cfg, ebc, step)
        g_pooled = jnp.asarray(
            rng.randn(*idx.shape[:2], cfg.embed_dim), jnp.float32)
        local = bc.take(state, idx, train=True)
        fi, fg = ebc.per_lookup_grads(jnp.asarray(local), g_pooled)
        new_cache, new_accum = ops.rowwise_adagrad_update(
            state.cache, state.cache_accum, fi, fg, lr)
        bc.mark_updated(state, new_cache, new_accum)
        fi_r, fg_r = ebc.per_lookup_grads(jnp.asarray(idx), g_pooled)
        mega_ref, accum_ref = ops.rowwise_adagrad_update(
            mega_ref, accum_ref, fi_r, fg_r, lr)
    assert state.stats.bulk_hits > 0              # promotions happened
    mega_c, accum_c = bc.materialize(state)
    np.testing.assert_array_equal(np.asarray(mega_c), np.asarray(mega_ref))
    np.testing.assert_array_equal(np.asarray(accum_c), np.asarray(accum_ref))


@pytest.mark.parametrize("mode", ["sync", "async", "strict"])
def test_three_tier_train_matches_two_tier(cfg, ebc, mode):
    """The factory-built 3-tier train step (budgeted DRAM, live bulk
    traffic) is bit-equal to the 2-tier step: same losses, same
    materialized table. With dram_rows=0 the bulk tier disables itself and
    the run must ALSO book zero bulk traffic."""
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)
    n = 4

    def run(col):
        is_async = mode != "sync"
        tier = AsyncCachedTier(col) if is_async else col
        dense = {"bottom": params["bottom"], "top": params["top"]}
        cstate = cached_dlrm_init_state(col, opt, params)
        tstate = tier.init_state(params["emb"]["mega"])
        step = build_cached_train_step(cfg, tier, opt,
                                       strict_sync=(mode == "strict"))
        losses = []
        for t in range(n):
            nxt = (_batch(cfg, ebc, t + 1)
                   if is_async and t + 1 < n else None)
            kw = {"next_batch": nxt} if is_async else {}
            dense, cstate, m = step(dense, cstate, tstate,
                                    _batch(cfg, ebc, t),
                                    jnp.asarray(t, jnp.int32), **kw)
            losses.append(float(m["loss"]))
        mega, accum = tier.materialize(tstate)
        return losses, np.asarray(mega), np.asarray(accum), tstate

    ref_l, ref_m, ref_a, _ = run(
        CachedEmbeddingBagCollection.build(cfg, cache_rows=256))
    got_l, got_m, got_a, tstate = run(_bulk(cfg))
    assert got_l == ref_l
    assert tstate.stats.bulk_hits > 0
    np.testing.assert_array_equal(got_m, ref_m)
    np.testing.assert_array_equal(got_a, ref_a)

    # bulk sized to zero: identical numbers AND zero bulk traffic
    off_l, off_m, off_a, off_state = run(_bulk(cfg, dram_rows=0))
    assert off_l == ref_l
    np.testing.assert_array_equal(off_m, ref_m)
    s = off_state.stats
    assert s.bulk_hits == s.demotions == s.promotion_bytes == 0
    assert s.bulk_read_chunks == s.bulk_write_chunks == 0


def test_mmap_backed_bulk_store_roundtrips(cfg, ebc, tmp_path):
    """`bulk_path` puts the bulk payload on disk (np.memmap) with no
    change in numbers vs the in-memory store."""
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(2))
    mem = _bulk(cfg)
    dsk = _bulk(cfg, bulk_path=str(tmp_path / "bulk.npy"))
    s_mem = mem.init_state(params["mega"])
    s_dsk = dsk.init_state(params["mega"])
    assert isinstance(s_dsk.bulk.values, np.memmap)
    for t in range(3):
        idx = _batch_idx(cfg, ebc, t)
        a = mem.lookup(s_mem, idx, train=False)
        b = dsk.lookup(s_dsk, idx, train=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s_dsk.stats.bulk_hits == s_mem.stats.bulk_hits > 0


# ---------------------------------------------------------------------------
# property: residency is exclusive under any interleaving
# ---------------------------------------------------------------------------


def _assert_residency_invariants(bc, state):
    masks = bc.tier_residency(state)
    hbm, dram, bulk = masks["hbm"], masks["dram"], masks["bulk"]
    total = len(hbm)
    # exclusive partition: every row in exactly one tier
    assert int((hbm & dram).sum()) == 0
    assert int((hbm & bulk).sum()) == 0
    assert int((dram & bulk).sum()) == 0
    assert int(hbm.sum() + dram.sum() + bulk.sum()) == total
    assert state.dram_occupancy <= bc._dram_cap()
    # bulk-resident rows carry their capacity bits verbatim
    rows = np.flatnonzero(bulk)
    if len(rows):
        cap = np.asarray(jnp.take(state.capacity, jnp.asarray(rows), axis=0))
        np.testing.assert_array_equal(np.asarray(state.bulk.values[rows]),
                                      cap)


def _residency_trip(cfg, ebc, seed, dram_rows):
    params = init_params(ebc.param_specs(), jax.random.PRNGKey(0))
    bc = _bulk(cfg, dram_rows=dram_rows)
    state = bc.init_state(params["mega"])
    for t in range(4):
        idx = _batch_idx(cfg, ebc, seed * 31 + t)
        bc.lookup(state, idx, train=True)
        _assert_residency_invariants(bc, state)
    mega, _ = bc.materialize(state)
    assert mega.shape == params["mega"].shape
    _assert_residency_invariants(bc, state)


def test_residency_exclusive_after_promotion_demotion(cfg, ebc):
    _residency_trip(cfg, ebc, seed=1, dram_rows=300)


if HAS_HYPOTHESIS:

    @requires_hypothesis
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10 ** 6),
           dram_rows=st.sampled_from([0, 200, 400, 1000]))
    def test_residency_property_under_any_interleaving(seed, dram_rows):
        """No row is ever resident in two tiers, DRAM occupancy never
        exceeds its budget, and bulk bits always mirror capacity —
        whatever promotion/demotion interleaving the traffic induces."""
        cfg = get_smoke_config("dlrm-m1")
        ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                           strategy="replicated")
        _residency_trip(cfg, ebc, seed, dram_rows)
