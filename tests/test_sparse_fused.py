"""Fused sparse backward (kernels/sparse_plan.py + sparse_update.py + the
rewired train steps): the bucketing planner, the bit-exactness contract vs
the legacy per-lookup layout, the Pallas kernel body, the pipeline plan
hook, and the index-only / intermediate-bytes acceptance checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.pipeline import sparse_plan_hook
from repro.data.synthetic import make_dlrm_batch
from repro.kernels import ops, ref
from repro.kernels.sparse_plan import (SparsePlan, build_sparse_plan,
                                       build_sparse_plan_host,
                                       plan_from_batch)
from repro.launch.analysis import sparse_backward_traffic
from repro.nn.params import init_params
from repro.optim import adagrad
from repro.train.steps import build_dlrm_train_step, dlrm_init_state

from conftest import requires_hypothesis  # noqa: E402  (pytest test path)

# exercised on BOTH jax floors: this module drives the compat-shim surfaces
# (Pallas memory spaces, shard_map, kernel interpret paths) — see pyproject
# markers and the CI jax-floor leg
pytestmark = pytest.mark.compat

# ---------------------------------------------------------------------------
# index corpora: the ISSUE's stress patterns
# ---------------------------------------------------------------------------


def _zipf_idx(rng, b, f, lk, h, a=1.1):
    """Duplicate-heavy (Zipf) multi-hot batch with ragged -1 padding."""
    idx = (rng.zipf(a, size=(b, f, lk)) - 1) % h
    lengths = rng.randint(0, lk + 1, size=(b, f))
    mask = np.arange(lk)[None, None, :] < lengths[..., None]
    return np.where(mask, idx, -1).astype(np.int32)


def _corpus(rng, h=60, b=5, f=3, lk=6):
    uniform = rng.randint(-1, h, size=(b, f, lk)).astype(np.int32)
    zipf = _zipf_idx(rng, b, f, lk, h)
    all_pad = np.full((b, f, lk), -1, np.int32)
    all_dup = np.full((b, f, lk), 7, np.int32)
    empty_bags = uniform.copy()
    empty_bags[::2] = -1                       # whole examples empty
    single = np.full((1, 1, 1), h - 1, np.int32)
    return {"uniform": uniform, "zipf": zipf, "all_pad": all_pad,
            "all_dup": all_dup, "empty_bags": empty_bags, "single": single}

# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", ["uniform", "zipf", "all_pad", "all_dup",
                                  "empty_bags", "single"])
def test_plan_host_matches_jnp(rng, case):
    idx = _corpus(rng)[case]
    pj = build_sparse_plan(jnp.asarray(idx))
    ph = build_sparse_plan_host(idx)
    for a, b in zip(pj, ph):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("case", ["uniform", "zipf", "empty_bags"])
def test_plan_reconstructs_lookup_multiset(rng, case):
    """Decoding the CSR layout must recover exactly the (row, bag) pair
    multiset of the raw batch — nothing dropped, nothing invented."""
    idx = _corpus(rng)[case]
    b, f, lk = idx.shape
    plan = build_sparse_plan_host(idx)
    rows, offs, bags = (np.asarray(x) for x in plan)
    decoded = []
    for i, r in enumerate(rows):
        if r < 0:
            assert offs[i + 1] == offs[i] or i >= (rows >= 0).sum()
            continue
        for j in range(offs[i], offs[i + 1]):
            decoded.append((int(r), int(bags[j])))
    expected = []
    flat = idx.reshape(-1)
    for pos, r in enumerate(flat):
        if r >= 0:
            expected.append((int(r), pos // lk))
    assert sorted(decoded) == sorted(expected)
    # unique rows are strictly increasing over the live prefix (sorted)
    live = rows[rows >= 0]
    assert np.all(np.diff(live) > 0)


def test_plan_lowering_is_index_only():
    """Acceptance: the bucketing plan aggregates on int32 indices only — its
    lowered StableHLO contains no float tensors at all."""
    idx = jax.ShapeDtypeStruct((8, 4, 16), jnp.int32)
    text = jax.jit(build_sparse_plan).lower(idx).as_text()
    for ft in ("f32", "f64", "bf16", "f16"):
        assert f"x{ft}" not in text and f"tensor<{ft}" not in text, ft

# ---------------------------------------------------------------------------
# fused ref == legacy rowwise_adagrad_ref, bit for bit
# ---------------------------------------------------------------------------


def _legacy(table, accum, idx, pooled, lr=0.05, eps=1e-8):
    b, f, lk = idx.shape
    d = pooled.shape[-1]
    g = jnp.broadcast_to(jnp.asarray(pooled)[:, :, None, :], (b, f, lk, d))
    return ref.rowwise_adagrad_ref(
        jnp.asarray(table), jnp.asarray(accum),
        jnp.asarray(idx.reshape(-1)), g.reshape(b * f * lk, d), lr, eps)


@pytest.mark.parametrize("case", ["uniform", "zipf", "all_pad", "all_dup",
                                  "empty_bags", "single"])
def test_fused_bit_matches_legacy_ref(rng, case):
    idx = _corpus(rng)[case]
    b, f, _ = idx.shape
    h, d = 60, 12
    table = rng.randn(h, d).astype(np.float32)
    accum = np.abs(rng.randn(h)).astype(np.float32)
    pooled = rng.randn(b, f, d).astype(np.float32)
    tl, al = _legacy(table, accum, idx, pooled)
    tf, af = ops.fused_sparse_backward(
        jnp.asarray(table), jnp.asarray(accum), jnp.asarray(idx),
        jnp.asarray(pooled), 0.05)
    np.testing.assert_array_equal(np.asarray(tl), np.asarray(tf))
    np.testing.assert_array_equal(np.asarray(al), np.asarray(af))


@requires_hypothesis
def test_fused_bit_matches_legacy_ref_fuzz():
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), b=st.integers(1, 6),
           f=st.integers(1, 4), lk=st.integers(1, 9),
           zipf=st.booleans())
    def run(seed, b, f, lk, zipf):
        rng = np.random.RandomState(seed)
        h, d = 40, 8
        idx = _zipf_idx(rng, b, f, lk, h) if zipf else \
            rng.randint(-1, h, size=(b, f, lk)).astype(np.int32)
        table = rng.randn(h, d).astype(np.float32)
        accum = np.abs(rng.randn(h)).astype(np.float32)
        pooled = rng.randn(b, f, d).astype(np.float32)
        tl, al = _legacy(table, accum, idx, pooled)
        tf, af = ops.fused_sparse_backward(
            jnp.asarray(table), jnp.asarray(accum), jnp.asarray(idx),
            jnp.asarray(pooled), 0.05)
        np.testing.assert_array_equal(np.asarray(tl), np.asarray(tf))
        np.testing.assert_array_equal(np.asarray(al), np.asarray(af))

    run()

# ---------------------------------------------------------------------------
# Pallas kernel body (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,d,b,f,lk", [
    (64, 128, 4, 2, 5),      # lane-aligned d
    (97, 48, 6, 3, 7),       # padded d, odd sizes
    (33, 200, 2, 1, 32),     # d > lane, truncation-sized lk
])
def test_fused_kernel_interpret_matches_ref(rng, h, d, b, f, lk):
    idx = rng.randint(-1, h, size=(b, f, lk)).astype(np.int32)
    table = rng.randn(h, d).astype(np.float32)
    accum = np.abs(rng.randn(h)).astype(np.float32)
    pooled = rng.randn(b, f, d).astype(np.float32)
    tk, ak = ops.fused_sparse_backward(
        jnp.asarray(table), jnp.asarray(accum), jnp.asarray(idx),
        jnp.asarray(pooled), 0.05, use_kernel=None, interpret=True)
    tr, ar = _legacy(table, accum, idx, pooled)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar),
                               rtol=1e-5, atol=1e-6)


def test_fused_kernel_interpret_tight_when_lane_aligned(rng):
    """With D already lane-aligned nothing is padded or rescaled: the kernel
    body tracks the legacy oracle to ~1 ulp (the residual difference is
    mean()'s backend-dependent reduction order, same as the legacy rowwise
    kernel; the jnp FALLBACK is the bit-exact contract, asserted above)."""
    h, d, b, f, lk = 32, 128, 3, 2, 6
    idx = rng.randint(-1, h, size=(b, f, lk)).astype(np.int32)
    table = rng.randn(h, d).astype(np.float32)
    accum = np.abs(rng.randn(h)).astype(np.float32)
    pooled = rng.randn(b, f, d).astype(np.float32)
    tk, ak = ops.fused_sparse_backward(
        jnp.asarray(table), jnp.asarray(accum), jnp.asarray(idx),
        jnp.asarray(pooled), 0.05, use_kernel=None, interpret=True)
    tr, ar = _legacy(table, accum, idx, pooled)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar),
                               rtol=1e-6, atol=1e-7)

# ---------------------------------------------------------------------------
# plan passthrough: hook-built plan == on-device plan
# ---------------------------------------------------------------------------


def test_precomputed_plan_matches_on_device_plan(rng):
    idx = _zipf_idx(rng, 6, 3, 8, 50)
    table = rng.randn(50, 16).astype(np.float32)
    accum = np.abs(rng.randn(50)).astype(np.float32)
    pooled = rng.randn(6, 3, 16).astype(np.float32)
    plan = build_sparse_plan_host(idx)
    t1, a1 = ops.fused_sparse_backward(
        jnp.asarray(table), jnp.asarray(accum), None, jnp.asarray(pooled),
        0.05, plan=SparsePlan(*(jnp.asarray(x) for x in plan)))
    t2, a2 = ops.fused_sparse_backward(
        jnp.asarray(table), jnp.asarray(accum), jnp.asarray(idx),
        jnp.asarray(pooled), 0.05)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_sparse_plan_hook_attaches_relabelable_plan(rng):
    """The pipeline hook rewrites idx to offset rows AND attaches the CSR
    plan; plan_from_batch rehydrates it; the train step consumes it to the
    same result as planning on device."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    hook = sparse_plan_hook(ebc.plan.table_offsets)
    raw = make_dlrm_batch(cfg, 8)
    batch = hook({k: np.asarray(v) for k, v in raw.items()})
    for key in ("plan_rows", "plan_offsets", "plan_bags", "uniq_rows"):
        assert key in batch
    want = build_sparse_plan_host(batch["idx"])
    got = plan_from_batch(batch)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)
    state = dlrm_init_state(ebc, opt, params)
    step = build_dlrm_train_step(cfg, ebc, opt, sparse_apply="sparse")
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    no_plan = {k: v for k, v in jb.items()
               if not k.startswith("plan_") and k != "uniq_rows"}
    p1, s1, m1 = jax.jit(step)(params, state, jb, jnp.asarray(0, jnp.int32))
    p2, s2, m2 = jax.jit(step)(params, state, no_plan,
                               jnp.asarray(0, jnp.int32))
    np.testing.assert_array_equal(np.asarray(p1["emb"]["mega"]),
                                  np.asarray(p2["emb"]["mega"]))
    np.testing.assert_array_equal(np.asarray(s1["accum"]),
                                  np.asarray(s2["accum"]))

# ---------------------------------------------------------------------------
# train-step rewiring: fused nrows == legacy math
# ---------------------------------------------------------------------------


def test_fused_train_step_matches_legacy_sparse_apply(rng):
    """The rewired sparse_apply="sparse" step must produce the same mega
    table as the legacy broadcast + dedup + rowwise update on the same
    batch (the semantics the seed tests pinned)."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(1))
    opt = adagrad(0.01)
    state = dlrm_init_state(ebc, opt, params)
    raw = make_dlrm_batch(cfg, 8)
    idx = ebc.offset_indices(jnp.asarray(raw["idx"]))
    batch = {"dense": jnp.asarray(raw["dense"]), "idx": idx,
             "label": jnp.asarray(raw["label"])}
    step = build_dlrm_train_step(cfg, ebc, opt, sparse_apply="sparse")
    p1, s1, _ = jax.jit(step)(params, state, batch, jnp.asarray(0, jnp.int32))

    from repro.core.dlrm import dlrm_grads
    _, _, (idx_blf, g_pooled) = dlrm_grads(params, batch, cfg, ebc)
    fi, fg = ebc.per_lookup_grads(idx_blf, g_pooled)
    want_mega, want_accum = ref.rowwise_adagrad_ref(
        params["emb"]["mega"], state["accum"], fi, fg, 0.05)
    np.testing.assert_allclose(np.asarray(p1["emb"]["mega"]),
                               np.asarray(want_mega), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(s1["accum"]),
                               np.asarray(want_accum), rtol=1e-6, atol=1e-7)

# ---------------------------------------------------------------------------
# cached tier: slot-space plan relabel
# ---------------------------------------------------------------------------


def test_cached_step_with_plan_hook_bit_matches_plain(rng):
    """The cached train step fed hook-attached plans (relabelled to slot
    space) must leave bit-identical tiers vs the same batches without
    plans."""
    from repro.train.steps import (build_cached_dlrm_train_step,
                                   cached_dlrm_init_state)
    cfg = dataclasses.replace(
        get_smoke_config("dlrm-m1"), n_sparse_features=2,
        hash_sizes=(80, 40), mean_lookups=(4, 2), bottom_mlp=(8, 16),
        top_mlp=(26, 1))
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(2))
    opt = adagrad(0.01)
    hook = sparse_plan_hook(ebc.plan.table_offsets)
    batches = []
    for t in range(3):
        raw = make_dlrm_batch(cfg, 8, step=t)
        batches.append(hook({k: np.asarray(v) for k, v in raw.items()}))

    def run(with_plan):
        cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=64)
        dense = {"bottom": params["bottom"], "top": params["top"]}
        state = cached_dlrm_init_state(cc, opt, params)
        cstate = cc.init_state(params["emb"]["mega"])
        step = build_cached_dlrm_train_step(cfg, cc, opt)
        for t, b in enumerate(batches):
            b = dict(b)
            if not with_plan:
                for k in ("plan_rows", "plan_offsets", "plan_bags"):
                    b.pop(k)
            dense, state, _ = step(dense, state, cstate, b,
                                   jnp.asarray(t, jnp.int32))
        return cc.materialize(cstate)

    m1, a1 = run(True)
    m2, a2 = run(False)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

# ---------------------------------------------------------------------------
# 8-fake-device shard_map variant (subprocess — the main process pins 1 CPU
# device; same isolation discipline as tests/test_multidevice.py)
# ---------------------------------------------------------------------------


def test_fused_shardmap_update_routes_duplicates_across_shards():
    import os
    import subprocess
    import sys
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_smoke_config
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.nn.params import init_params
from repro.optim import adagrad
from repro.train.steps import build_dlrm_train_step, dlrm_init_state

cfg = dataclasses.replace(get_smoke_config("dlrm-m1"),
                          placement="row_wise", lookup_impl="psum")
mesh = jax.make_mesh((2, 4), ("data", "model"))
ebc = EmbeddingBagCollection.build(cfg, n_shards=4)
params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
opt = adagrad(0.05)
state = dlrm_init_state(ebc, opt, params)
raw = make_dlrm_batch(cfg, 16)
idx = np.array(ebc.offset_indices(jnp.asarray(raw["idx"])))
hot = int(idx[idx >= 0][0])
idx[:, 0, 0] = hot      # same row in EVERY example: every data shard must
                        # contribute to one row's aggregated gradient
batch = {"dense": jnp.asarray(raw["dense"]), "idx": jnp.asarray(idx),
         "label": jnp.asarray(raw["label"])}
with mesh:
    # fused shard_map PS aggregation (psum) vs the pjit dense-scatter path
    p1, s1, m1 = jax.jit(build_dlrm_train_step(cfg, ebc, opt))(
        params, state, batch, jnp.asarray(0, jnp.int32))
    cfg_ref = dataclasses.replace(cfg, lookup_impl="gather")
    p2, s2, m2 = jax.jit(build_dlrm_train_step(cfg_ref, ebc, opt))(
        params, state, batch, jnp.asarray(0, jnp.int32))
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
np.testing.assert_allclose(np.asarray(p1["emb"]["mega"]),
                           np.asarray(p2["emb"]["mega"]),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(np.asarray(s1["accum"]), np.asarray(s2["accum"]),
                           rtol=1e-4, atol=1e-5)
# the planted row really aggregated across shards: its accumulator moved
assert float(s1["accum"][hot]) > 0.0
print("FUSED_SHARDMAP_OK")
"""
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "FUSED_SHARDMAP_OK" in out.stdout


# ---------------------------------------------------------------------------
# acceptance: intermediate-bytes accounting
# ---------------------------------------------------------------------------


def test_sparse_backward_traffic_reduction_exceeds_truncation():
    """ISSUE acceptance: >= L x reduction in sparse-backward intermediate
    bytes for a truncation-32 config (the m3/prod shape)."""
    t = sparse_backward_traffic(4096, 127, 32, 128)
    assert t["reduction"] >= 32
    # and the bench shape emitted by kernels_bench
    t2 = sparse_backward_traffic(256, 8, 32, 128)
    assert t2["reduction"] >= 32
    # sanity: legacy counts the three (B*F*L, D) fp32 intermediates
    n = 4096 * 127 * 32
    assert t["legacy_bytes"] == pytest.approx(3 * n * 128 * 4)
    assert t["fused_bytes"] == pytest.approx((3 * n + 1) * 4)
