"""Chaos soak suite (train/fault_tolerance.py, docs/fault_tolerance.md).

The invariant every scenario asserts: ANY seeded fault schedule — reader
death, transient-fetch bursts with degradation to strict_sync, preemption
plus a torn checkpoint leaf, host loss with an elastic table-wise re-pack —
yields final losses (and the materialized capacity tier, accumulators, and
dense params) BIT-EQUAL to the fault-free run. Recovery restores the
TrainState bundle (params + optimizer + cache `state_dict` + pipeline
cursor) from the newest intact checkpoint and replays; replayed steps
recompute identical losses because synthetic batches are deterministic per
step and the bundle round-trips bit-exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAS_HYPOTHESIS, requires_hypothesis
from repro.configs import get_smoke_config
from repro.core.cache import (CachedEmbeddingBagCollection,
                              MultiHostCachedEmbeddingBagCollection)
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.core.tiers import BulkCachedEmbeddingBagCollection
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import make_dlrm_batch
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (DegradationManager, FaultInjector,
                                         FaultSpec, PreemptionHandler,
                                         RetryPolicy, TrainState,
                                         elastic_tablewise_repack,
                                         restore_train_state, run_chaos_loop,
                                         save_train_state)
from repro.train.steps import (build_async_cached_dlrm_train_step,
                               build_cached_train_step,
                               build_multihost_cached_train_step,
                               build_tablewise_train_step,
                               cached_dlrm_init_state, dlrm_init_state)

pytestmark = pytest.mark.compat

if HAS_HYPOTHESIS:
    from hypothesis import given, settings
    from hypothesis import strategies as st


@pytest.fixture(scope="module")
def cfg():
    return get_smoke_config("dlrm-m1")


@pytest.fixture(scope="module")
def ebc(cfg):
    return EmbeddingBagCollection.build(cfg, n_shards=1,
                                        strategy="replicated")


def _batch(cfg, ebc, t, b=8):
    raw = make_dlrm_batch(cfg, b, step=t)
    return {"dense": jnp.asarray(raw["dense"]),
            "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
            "label": jnp.asarray(raw["label"])}


# ---------------------------------------------------------------------------
# fault-free oracle (async cached tier)
# ---------------------------------------------------------------------------


def _oracle_async(cfg, ebc, n_steps, cache_rows=256):
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=cache_rows)
    dense = {"bottom": params["bottom"], "top": params["top"]}
    cstate = cached_dlrm_init_state(cc, opt, params)
    astate = cc.init_async_state(params["emb"]["mega"])
    step = build_async_cached_dlrm_train_step(cfg, cc, opt)
    losses = {}
    for t in range(n_steps):
        nxt = _batch(cfg, ebc, t + 1) if t + 1 < n_steps else None
        dense, cstate, m = step(dense, cstate, astate, _batch(cfg, ebc, t),
                                jnp.asarray(t, jnp.int32), next_batch=nxt)
        losses[t] = float(m["loss"])
    mega, accum = cc.materialize_async(astate)
    return (losses, np.asarray(mega), np.asarray(accum),
            jax.tree.map(np.asarray, dense))


# ---------------------------------------------------------------------------
# chaos harness: async cached DLRM + pipeline + checkpoint bundle
# ---------------------------------------------------------------------------


def _run_chaos(cfg, ebc, ckpt_dir, injector, *, n_steps=8, checkpoint_every=2,
               retry=None, degradation=None, cache_rows=256, max_restarts=10,
               keep=4):
    """Drive `run_chaos_loop` over the full stack: DataPipeline (injector
    threaded into the reader), async cached tier (injector + retry on the
    fetch path), CheckpointManager (torn-leaf injection + CRC fallback),
    TrainState bundle save/restore."""
    params0 = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)
    mgr = CheckpointManager(str(ckpt_dir), keep=keep, injector=injector)
    losses: dict[int, float] = {}
    job: dict = {}

    def gen(t):
        raw = make_dlrm_batch(cfg, 8, step=t)
        return {"dense": raw["dense"],
                "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
                "label": raw["label"]}

    def fresh():
        cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=cache_rows)
        cc = dataclasses.replace(cc, injector=injector, retry=retry)
        dense = {"bottom": params0["bottom"], "top": params0["top"]}
        cstate = cached_dlrm_init_state(cc, opt, params0)
        astate = cc.init_async_state(params0["emb"]["mega"])
        return cc, dense, cstate, astate

    def restore_cb():
        # simulated restart: tear the whole job down and rebuild it from
        # the newest intact checkpoint (or from scratch when none exists)
        if job.get("pipe") is not None:
            job["pipe"].close()
        cc, dense, cstate, astate = fresh()
        example = TrainState(dense, cstate, cc.state_dict(astate), 0)
        try:
            ts = restore_train_state(mgr, example)
            astate = cc.load_state_dict(ts.cache)
            dense, cstate = ts.params, ts.opt_state
            start = ts.step
        except FileNotFoundError:
            start = 0
        job.update(cc=cc, dense=dense, cstate=cstate, astate=astate,
                   step=build_async_cached_dlrm_train_step(cfg, cc, opt),
                   pipe=DataPipeline(gen, prefetch=2, start_step=start,
                                     injector=injector))
        return start

    def save_cb(step):
        ts = TrainState(job["dense"], job["cstate"],
                        job["cc"].state_dict(job["astate"]), step)
        save_train_state(mgr, ts)

    def step_fn(step):
        t, raw = next(job["pipe"])
        assert t == step
        batch = {"dense": jnp.asarray(raw["dense"]), "idx": raw["idx"],
                 "label": jnp.asarray(raw["label"])}
        degraded = degradation is not None and degradation.degraded
        nxt = None
        if not degraded and step + 1 < n_steps:
            peek = job["pipe"].peek(0)
            if peek is not None:
                nxt = {"dense": jnp.asarray(peek["dense"]),
                       "idx": peek["idx"],
                       "label": jnp.asarray(peek["label"])}
        dense, cstate, m = job["step"](
            job["dense"], job["cstate"], job["astate"], batch,
            jnp.asarray(step, jnp.int32), next_batch=nxt)
        job["dense"], job["cstate"] = dense, cstate
        losses[step] = float(m["loss"])

    preempt = PreemptionHandler(signals=())
    rep = run_chaos_loop(step_fn, n_steps, save_cb=save_cb,
                         restore_cb=restore_cb,
                         checkpoint_every=checkpoint_every,
                         preemption=preempt, injector=injector,
                         degradation=degradation, max_restarts=max_restarts)
    job["pipe"].close()
    mega, accum = job["cc"].materialize_async(job["astate"])
    return (rep, mgr, losses, np.asarray(mega), np.asarray(accum),
            jax.tree.map(np.asarray, job["dense"]))


def _assert_matches_oracle(cfg, ebc, got, n_steps=8):
    losses, mega, accum, dense = got
    want_l, want_m, want_a, want_d = _oracle_async(cfg, ebc, n_steps)
    assert losses == want_l
    np.testing.assert_array_equal(mega, want_m)
    np.testing.assert_array_equal(accum, want_a)
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(want_d)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# scenario 1: reader-thread death mid-run
# ---------------------------------------------------------------------------


def test_chaos_reader_death_resumes_bitexact(cfg, ebc, tmp_path):
    """A killed reader thread (SystemExit inside the worker) surfaces as a
    RuntimeError in the consumer; the chaos loop restores the bundle and
    reopens the pipeline at the restored cursor — final state bit-equal to
    the fault-free run."""
    inj = FaultInjector([FaultSpec("pipeline.batch", 4, "kill")])
    rep, mgr, *got = _run_chaos(cfg, ebc, tmp_path, inj)
    assert rep.restarts >= 1 and rep.last_step == 8
    assert ("pipeline.batch", 4, "kill") in inj.fired
    assert len(rep.recovery_s) == rep.restarts
    _assert_matches_oracle(cfg, ebc, got)


# ---------------------------------------------------------------------------
# scenario 2: transient-fetch burst -> retry -> degrade -> promote
# ---------------------------------------------------------------------------


def test_chaos_fetch_fault_absorbed_by_retry(cfg, ebc, tmp_path):
    """An ISOLATED transient fetch fault never surfaces: the bounded
    retry inside the cache's fetch guard absorbs it. Zero restarts."""
    inj = FaultInjector([FaultSpec("cache.fetch", 2, "error"),
                         FaultSpec("cache.fetch", 5, "latency", arg=1e-4)])
    rep, mgr, *got = _run_chaos(cfg, ebc, tmp_path, inj,
                                retry=RetryPolicy(max_retries=2,
                                                  backoff_s=1e-5))
    assert rep.restarts == 0
    assert len(inj.fired) == 2
    _assert_matches_oracle(cfg, ebc, got)


def test_chaos_fetch_burst_degrades_then_promotes(cfg, ebc, tmp_path):
    """A BURST of consecutive fetch faults exhausts the retry budget: the
    step fails, the loop restores, and after `demote_after` consecutive
    failures the DegradationManager flips the schedule to strict_sync.
    Once the storage heals, a clean window promotes it back. Both
    schedules are bit-identical, so the soak still matches the oracle."""
    burst = [FaultSpec("cache.fetch", at, "error") for at in range(3, 15)]
    inj = FaultInjector(burst)
    deg = DegradationManager(demote_after=2, promote_after=2)
    rep, mgr, *got = _run_chaos(cfg, ebc, tmp_path, inj,
                                retry=RetryPolicy(max_retries=1,
                                                  backoff_s=1e-5),
                                degradation=deg)
    assert rep.restarts >= 2
    assert deg.demotions >= 1 and deg.promotions >= 1
    assert rep.degraded_steps > 0
    assert deg.mode == "async"              # promoted back by the end
    _assert_matches_oracle(cfg, ebc, got)


# ---------------------------------------------------------------------------
# scenario 3: preemption at step k + torn checkpoint leaf
# ---------------------------------------------------------------------------


def test_chaos_preempt_with_torn_checkpoint_falls_back(cfg, ebc, tmp_path):
    """Preemption at step 4 forces an off-schedule save whose leaf is torn
    AFTER the atomic publish (a storage-level tear only the CRC catches).
    The simulated restart's restore() skips the corrupt step and falls
    back to the previous intact one; the replay converges bit-exactly."""
    inj = FaultInjector([FaultSpec("loop.step", 4, "preempt"),
                         FaultSpec("checkpoint.write", 2, "torn", arg=1)])
    rep, mgr, *got = _run_chaos(cfg, ebc, tmp_path, inj)
    # saves: step 2 (write 0), step 4 (write 1), preemption save at step 5
    # (write 2, TORN) -> restore falls back past 5 to 4
    assert rep.restarts == 1
    assert mgr.last_restored_step == 4
    assert 8 in mgr.saved_steps()
    _assert_matches_oracle(cfg, ebc, got)


def test_byte_flip_on_disk_falls_back_to_previous_step(cfg, ebc, tmp_path):
    """Acceptance check, no injector: flipping ONE byte of a saved leaf
    file on disk makes restore() reject that step on CRC and fall back to
    the previous intact one."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": np.arange(8, dtype=np.float32), "b": np.ones(3, np.float32)}
    mgr.save(1, tree)
    tree2 = {"w": tree["w"] * 2, "b": tree["b"] * 3}
    mgr.save(2, tree2)
    leaf = sorted((tmp_path / "step_000000002").glob("leaf_*.npy"))[0]
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    got = mgr.restore(tree)
    assert mgr.last_restored_step == 1
    np.testing.assert_array_equal(np.asarray(got["w"]), tree["w"])


# ---------------------------------------------------------------------------
# scenario 4: host loss -> elastic table-wise re-pack
# ---------------------------------------------------------------------------


def test_chaos_host_loss_elastic_repack_bitexact(cfg, tmp_path):
    """Losing one of 4 table-wise owners mid-run: checkpoint the bundle,
    re-run the bin-pack for 3 survivors, re-scatter mega/accum rows under
    the new placement, and continue. Row renumbering is invariant for
    per-bag pooling and per-row AdaGrad, so the remaining losses are
    bit-equal to the uninterrupted 4-owner run."""
    ebc4 = EmbeddingBagCollection.build(cfg, n_shards=4,
                                        strategy="table_wise")
    # numpy master copy: the table-wise step DONATES the mega buffer, so
    # each run must start from fresh device arrays
    params_np = jax.tree.map(np.asarray, init_params(
        dlrm_param_specs(cfg, ebc4), jax.random.PRNGKey(3)))
    opt = adagrad(0.01)

    def run_oracle():
        params = jax.tree.map(jnp.asarray, params_np)
        p, s = dict(params), dlrm_init_state(ebc4, opt, params)
        step = build_tablewise_train_step(cfg, ebc4, opt)
        out = []
        for t in range(6):
            p, s, m = step(p, s, _batch(cfg, ebc4, t, b=16),
                           jnp.asarray(t, jnp.int32))
            out.append(float(m["loss"]))
        return out

    want = run_oracle()

    inj = FaultInjector([FaultSpec("loop.step", 3, "host_loss", arg=1)])
    mgr = CheckpointManager(str(tmp_path), injector=inj)
    params = jax.tree.map(jnp.asarray, params_np)
    e, p, s = ebc4, dict(params), dlrm_init_state(ebc4, opt, params)
    step = build_tablewise_train_step(cfg, ebc4, opt)
    got = []
    for t in range(6):
        spec = inj.fire("loop.step", step=t)
        if spec is not None and spec.kind == "host_loss":
            mgr.save(t, {"params": p, "state": s})
            tree = mgr.restore({"params": p, "state": s})
            e, mega, accum = elastic_tablewise_repack(
                cfg, e, tree["params"]["emb"]["mega"],
                tree["state"]["accum"], 3)
            p = {"bottom": tree["params"]["bottom"],
                 "top": tree["params"]["top"], "emb": {"mega": mega}}
            s = {"dense": tree["state"]["dense"], "accum": accum}
            step = build_tablewise_train_step(cfg, e, opt)
        p, s, m = step(p, s, _batch(cfg, e, t, b=16),
                       jnp.asarray(t, jnp.int32))
        got.append(float(m["loss"]))
    assert e.plan.strategy == "table_wise" and e is not ebc4
    assert got == want


def test_chaos_seeded_schedule_is_deterministic():
    a = FaultInjector.from_seed(11, 16)
    b = FaultInjector.from_seed(11, 16)
    c = FaultInjector.from_seed(12, 16)
    assert [dataclasses.astuple(s) for s in a.schedule] == \
        [dataclasses.astuple(s) for s in b.schedule]
    assert [dataclasses.astuple(s) for s in a.schedule] != \
        [dataclasses.astuple(s) for s in c.schedule]


# ---------------------------------------------------------------------------
# property: snapshot/restore + faults == uninterrupted, on every tier
# ---------------------------------------------------------------------------


def _tier_tools(cfg, ebc, tier, injector=None, retry=None):
    """(collection, init_tier_state, step_adapter, snapshot, load) for one
    cache tier; the adapters normalize the three step signatures."""
    opt = adagrad(0.01)
    if tier == "multihost":
        col = MultiHostCachedEmbeddingBagCollection.build(cfg, n_hosts=2,
                                                          cache_rows=256)
    elif tier == "bulk":
        # 3-tier flavor: DRAM budget below the table height so promotions
        # pull from bulk and evictions overflow DRAM back into it
        col = BulkCachedEmbeddingBagCollection.build(
            cfg, cache_rows=256, dram_rows=300, bulk_chunk=16,
            bulk_latency_us=0.0)
    else:
        col = CachedEmbeddingBagCollection.build(cfg, cache_rows=256)
    col = dataclasses.replace(col, injector=injector, retry=retry)

    if tier in ("sync", "bulk"):
        step = build_cached_train_step(cfg, col, opt)

        def run(dense, cstate, tstate, t, batch, nxt):
            return step(dense, cstate, tstate, batch,
                        jnp.asarray(t, jnp.int32))
        init = col.init_state
    elif tier == "async":
        step = build_async_cached_dlrm_train_step(cfg, col, opt)

        def run(dense, cstate, tstate, t, batch, nxt):
            return step(dense, cstate, tstate, batch,
                        jnp.asarray(t, jnp.int32), next_batch=nxt)
        init = col.init_async_state
    else:
        step = build_multihost_cached_train_step(cfg, col, opt)

        def run(dense, cstate, tstate, t, batch, nxt):
            return step(dense, cstate, tstate, batch,
                        jnp.asarray(t, jnp.int32), next_batch=nxt)
        init = col.init_state
    return col, opt, init, run


def _tier_segment(cfg, ebc, tier, tools, dense, cstate, tstate, t0, t1,
                  n_total):
    col, opt, init, run = tools
    losses = []
    for t in range(t0, t1):
        nxt = _batch(cfg, ebc, t + 1) if t + 1 < n_total else None
        dense, cstate, m = run(dense, cstate, tstate, t,
                               _batch(cfg, ebc, t), nxt)
        losses.append(float(m["loss"]))
    return dense, cstate, losses


def _tier_materialize(tier, col, tstate):
    if tier == "async":
        return col.materialize_async(tstate)
    return col.materialize(tstate)


def _check_resume_equivalence(tier, seed):
    """state_dict -> load_state_dict -> N more steps (into a FRESH
    collection whose fetch path has a seeded schedule of retryable
    transient faults) is bit-equal to running uninterrupted — on the
    sync, async, and multi-host tiers alike."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc),
                         jax.random.PRNGKey(seed % 97))
    n1, n2 = 2, 2

    def boot(tools):
        col, opt, init, run = tools
        dense = {"bottom": params["bottom"], "top": params["top"]}
        cstate = cached_dlrm_init_state(col, opt, params)
        return dense, cstate, init(params["emb"]["mega"])

    # uninterrupted oracle
    tools = _tier_tools(cfg, ebc, tier)
    dense, cstate, tstate = boot(tools)
    dense, cstate, l1 = _tier_segment(cfg, ebc, tier, tools, dense,
                                      cstate, tstate, 0, n1 + n2, n1 + n2)
    want_m, want_a = _tier_materialize(tier, tools[0], tstate)

    # interrupted: snapshot after n1, reload into a FAULTY collection
    tools = _tier_tools(cfg, ebc, tier)
    dense, cstate, tstate = boot(tools)
    dense, cstate, l2a = _tier_segment(cfg, ebc, tier, tools, dense,
                                       cstate, tstate, 0, n1, n1 + n2)
    snap = tools[0].state_dict(tstate)
    sites = (("cache.fetch", "bulk.fetch") if tier == "bulk"
             else ("cache.fetch",))
    inj = FaultInjector.from_seed(seed, 32, sites=sites, n_faults=2)
    tools2 = _tier_tools(cfg, ebc, tier, injector=inj,
                         retry=RetryPolicy(max_retries=3, backoff_s=1e-5))
    tstate2 = tools2[0].load_state_dict(snap)
    dense, cstate, l2b = _tier_segment(cfg, ebc, tier, tools2, dense,
                                       cstate, tstate2, n1, n1 + n2,
                                       n1 + n2)
    got_m, got_a = _tier_materialize(tier, tools2[0], tstate2)

    assert l2a + l2b == l1
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(want_m))
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))


@pytest.mark.parametrize("tier", ["sync", "async", "multihost", "bulk"])
def test_resume_under_faults_equals_uninterrupted(tier):
    _check_resume_equivalence(tier, seed=5)


def test_chaos_bulk_latency_fault_with_preemption_bitexact(cfg, ebc):
    """3-tier chaos: multi-millisecond latency faults armed on the bulk
    promotion path (`bulk.fetch`) PLUS a mid-run preemption (snapshot ->
    discard live state -> restore into a fresh faulty collection) leave
    the run bit-equal to the fault-free uninterrupted oracle. Latency
    faults only stretch wall time, and the capacity tier is always
    current, so the restored bulk store reseeds bit-identically."""
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(3))
    n1, n2 = 2, 3

    def boot(tools):
        col, opt, init, run = tools
        dense = {"bottom": params["bottom"], "top": params["top"]}
        return dense, cached_dlrm_init_state(col, opt, params), \
            init(params["emb"]["mega"])

    tools = _tier_tools(cfg, ebc, "bulk")
    dense, cstate, tstate = boot(tools)
    dense, cstate, l1 = _tier_segment(cfg, ebc, "bulk", tools, dense,
                                      cstate, tstate, 0, n1 + n2, n1 + n2)
    want_m, want_a = _tier_materialize("bulk", tools[0], tstate)

    tools = _tier_tools(cfg, ebc, "bulk")
    dense, cstate, tstate = boot(tools)
    dense, cstate, l2a = _tier_segment(cfg, ebc, "bulk", tools, dense,
                                       cstate, tstate, 0, n1, n1 + n2)
    # preemption: checkpoint, then throw the live collection away and
    # restore into one whose bulk reads fire latency + transient faults
    snap = tools[0].state_dict(tstate)
    del tstate
    inj = FaultInjector([FaultSpec("bulk.fetch", 0, "latency", 0.002),
                         FaultSpec("bulk.fetch", 1, "error"),
                         FaultSpec("bulk.fetch", 2, "latency", 0.002)])
    tools2 = _tier_tools(cfg, ebc, "bulk", injector=inj,
                         retry=RetryPolicy(max_retries=3, backoff_s=1e-5))
    tstate2 = tools2[0].load_state_dict(snap)
    dense, cstate, l2b = _tier_segment(cfg, ebc, "bulk", tools2, dense,
                                       cstate, tstate2, n1, n1 + n2,
                                       n1 + n2)
    got_m, got_a = _tier_materialize("bulk", tools2[0], tstate2)

    assert l2a + l2b == l1
    assert any(site == "bulk.fetch" for site, _, _ in inj.fired)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(want_m))
    np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))


if HAS_HYPOTHESIS:

    @requires_hypothesis
    @settings(max_examples=4, deadline=None)
    @given(tier=st.sampled_from(["sync", "async", "multihost", "bulk"]),
           seed=st.integers(0, 10 ** 6))
    def test_resume_under_fuzzed_faults_equals_uninterrupted(tier, seed):
        _check_resume_equivalence(tier, seed)
