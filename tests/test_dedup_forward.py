"""Plan-shared dedup'd embedding forward (docs/embedding_forward.md):
bit-exactness of the jnp fallback vs the legacy lookup on the stress
corpus, interpret-mode sweep of the new Pallas kernel, plan capacity
trimming, the index-only StableHLO gather check, the cached tiers' miss
planning through the plan, and the forward-traffic acceptance model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.pipeline import sparse_plan_hook
from repro.data.synthetic import make_dlrm_batch
from repro.kernels import ops, ref
from repro.kernels.sparse_plan import (SparsePlan, build_sparse_plan,
                                       build_sparse_plan_host)
from repro.launch.analysis import (embedding_forward_traffic,
                                   zipf_expected_unique)
from repro.nn.params import init_params
from repro.optim import adagrad

# exercised on BOTH jax floors: this module drives the compat-shim surfaces
# (Pallas memory spaces, shard_map, kernel interpret paths) — see pyproject
# markers and the CI jax-floor leg
pytestmark = pytest.mark.compat

# ---------------------------------------------------------------------------
# index corpora: the ISSUE's stress patterns (2D bag layout)
# ---------------------------------------------------------------------------


def _zipf_idx2(rng, b, lk, h, a=1.1):
    idx = (rng.zipf(a, size=(b, lk)) - 1) % h
    lengths = rng.randint(0, lk + 1, size=(b,))
    mask = np.arange(lk)[None, :] < lengths[:, None]
    return np.where(mask, idx, -1).astype(np.int32)


def _corpus2(rng, h=60, b=12, lk=6):
    uniform = rng.randint(-1, h, size=(b, lk)).astype(np.int32)
    zipf = _zipf_idx2(rng, b, lk, h)
    all_pad = np.full((b, lk), -1, np.int32)
    all_dup = np.full((b, lk), 7, np.int32)
    empty_bags = uniform.copy()
    empty_bags[::2] = -1
    single = np.full((1, 1), h - 1, np.int32)
    return {"uniform": uniform, "zipf": zipf, "all_pad": all_pad,
            "all_dup": all_dup, "empty_bags": empty_bags, "single": single}


CASES = ["uniform", "zipf", "all_pad", "all_dup", "empty_bags", "single"]

# ---------------------------------------------------------------------------
# jnp fallback: bit-exact vs the legacy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_dedup_fallback_bit_matches_legacy_ref(rng, case, mode):
    idx = _corpus2(rng)[case]
    h, d = 60, 12
    table = jnp.asarray(rng.randn(h, d).astype(np.float32))
    want = ref.embedding_bag_ref(table, jnp.asarray(idx), mode)
    got = ops.dedup_embedding_bag(table, jnp.asarray(idx), mode=mode)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("case", ["uniform", "zipf", "all_dup"])
def test_dedup_fallback_with_trimmed_plan_bit_exact(rng, case):
    """Capacity-trimmed plans gather U rows instead of B*L and must still
    be bit-exact (the trim only drops dead -1 tail entries)."""
    idx = _corpus2(rng)[case]
    h, d = 60, 12
    n_unique = len(np.unique(idx[idx >= 0])) or 1
    cap = 1 << (n_unique - 1).bit_length()
    table = jnp.asarray(rng.randn(h, d).astype(np.float32))
    plan = build_sparse_plan_host(idx.reshape(-1),
                                  lookups_per_bag=idx.shape[1],
                                  capacity=cap)
    planj = SparsePlan(*(jnp.asarray(x) for x in plan))
    want = ref.embedding_bag_ref(table, jnp.asarray(idx), "sum")
    got = ops.dedup_embedding_bag(table, jnp.asarray(idx), plan=planj)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_dedup_vjp_matches_embedding_bag_vjp(rng):
    idx = jnp.asarray(rng.randint(-1, 30, size=(5, 4)).astype(np.int32))
    table = jnp.asarray(rng.randn(30, 8).astype(np.float32))
    g = jnp.asarray(rng.randn(5, 8).astype(np.float32))
    g1 = jax.grad(lambda t: (ops.embedding_bag(t, idx, "sum", False, False)
                             * g).sum())(table)
    g2 = jax.grad(lambda t: (ops.dedup_embedding_bag(t, idx)
                             * g).sum())(table)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))

# ---------------------------------------------------------------------------
# Pallas kernel body (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,d,b,lk", [
    (64, 128, 8, 5),        # lane-aligned d
    (97, 48, 6, 7),         # padded d, odd sizes
    (33, 200, 3, 32),       # d > lane, truncation-sized lk
    (50, 16, 11, 6),        # n_bags not a sublane multiple
])
def test_dedup_kernel_interpret_matches_ref(rng, h, d, b, lk):
    idx = rng.randint(-1, h, size=(b, lk)).astype(np.int32)
    table = jnp.asarray(rng.randn(h, d).astype(np.float32))
    want = ref.embedding_bag_ref(table, jnp.asarray(idx), "sum")
    got = ops.dedup_embedding_bag(table, jnp.asarray(idx),
                                  use_kernel=None, interpret=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("case", CASES)
def test_dedup_kernel_interpret_corpus(rng, case):
    """Corpus sweep incl. the deep-CSR all-duplicate case (one unique row
    referenced by every bag — the longest expansion run) and all-pads
    (zero live rows: the kernel must still zero its resident out block)."""
    idx = _corpus2(rng)[case]
    table = jnp.asarray(rng.randn(60, 12).astype(np.float32))
    want = ref.embedding_bag_ref(table, jnp.asarray(idx), "sum")
    got = ops.dedup_embedding_bag(table, jnp.asarray(idx),
                                  use_kernel=None, interpret=True)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


def test_fused_backward_interpret_with_deep_grad_stream(rng):
    """The double-buffered per-bag grad DMA stream (PR 3 follow-on): an
    all-duplicate batch routes EVERY bag's gradient through one unique
    row's stream — the deepest pipeline — and must still match the
    legacy oracle."""
    h, d, b, f, lk = 32, 128, 4, 2, 6
    idx = np.full((b, f, lk), 7, np.int32)
    table = rng.randn(h, d).astype(np.float32)
    accum = np.abs(rng.randn(h)).astype(np.float32)
    pooled = rng.randn(b, f, d).astype(np.float32)
    g = jnp.broadcast_to(jnp.asarray(pooled)[:, :, None, :], (b, f, lk, d))
    tr, ar = ref.rowwise_adagrad_ref(
        jnp.asarray(table), jnp.asarray(accum),
        jnp.asarray(idx.reshape(-1)), g.reshape(b * f * lk, d), 0.05)
    tk, ak = ops.fused_sparse_backward(
        jnp.asarray(table), jnp.asarray(accum), jnp.asarray(idx),
        jnp.asarray(pooled), 0.05, use_kernel=None, interpret=True)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ak), np.asarray(ar),
                               rtol=1e-5, atol=1e-6)

# ---------------------------------------------------------------------------
# plan capacity: trimming is behaviour-preserving, overflow raises
# ---------------------------------------------------------------------------


def test_plan_capacity_host_matches_jnp_and_preserves_backward(rng):
    idx = _zipf_idx2(rng, 10, 8, 40).reshape(5, 2, 8)
    n_unique = len(np.unique(idx[idx >= 0]))
    cap = n_unique + 3
    ph = build_sparse_plan_host(idx, capacity=cap)
    pj = build_sparse_plan(jnp.asarray(idx), capacity=cap)
    for a, b in zip(pj, ph):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ph.unique_rows.shape == (cap,)
    assert ph.bag_offsets.shape == (cap + 1,)
    # fused backward through the trimmed plan == untrimmed
    table = jnp.asarray(rng.randn(40, 16).astype(np.float32))
    accum = jnp.asarray(np.abs(rng.randn(40)).astype(np.float32))
    pooled = jnp.asarray(rng.randn(5, 2, 16).astype(np.float32))
    t1, a1 = ops.fused_sparse_backward(
        table, accum, None, pooled, 0.05,
        plan=SparsePlan(*(jnp.asarray(x) for x in ph)))
    t2, a2 = ops.fused_sparse_backward(
        table, accum, jnp.asarray(idx), pooled, 0.05)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_plan_capacity_overflow_raises_on_host(rng):
    idx = np.arange(24, dtype=np.int32).reshape(2, 2, 6)
    with pytest.raises(ValueError, match="capacity overflow"):
        build_sparse_plan_host(idx, capacity=8)


def test_sparse_plan_hook_capacity_rides_to_batch(rng):
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    raw = make_dlrm_batch(cfg, 8)
    probe = sparse_plan_hook(ebc.plan.table_offsets)(
        {k: np.asarray(v) for k, v in raw.items()})
    n_unique = int((probe["plan_rows"] >= 0).sum())
    cap = n_unique + 5
    hook = sparse_plan_hook(ebc.plan.table_offsets, capacity=cap)
    batch = hook({k: np.asarray(v) for k, v in raw.items()})
    assert batch["plan_rows"].shape == (cap,)
    assert batch["plan_offsets"].shape == (cap + 1,)

# ---------------------------------------------------------------------------
# acceptance: the forward gathers n_unique rows, not B*F*L (StableHLO)
# ---------------------------------------------------------------------------


def test_forward_gather_is_unique_capacity_not_slot_count(rng):
    """Index-only StableHLO check: with a capacity-trimmed plan, the only
    gather that touches the (H, D) table has U rows; no table gather is
    B*L-sized."""
    h, d, b, lk, cap = 997, 16, 8, 16, 64
    idx = jax.ShapeDtypeStruct((b, lk), jnp.int32)
    plan = SparsePlan(jax.ShapeDtypeStruct((cap,), jnp.int32),
                      jax.ShapeDtypeStruct((cap + 1,), jnp.int32),
                      jax.ShapeDtypeStruct((b * lk,), jnp.int32))
    table = jax.ShapeDtypeStruct((h, d), jnp.float32)
    text = jax.jit(
        lambda t, i, p: ops.dedup_embedding_bag(t, i, plan=p)
    ).lower(table, idx, plan).as_text()
    table_gathers = [ln for ln in text.splitlines()
                     if "gather" in ln and f"tensor<{h}x{d}xf32>" in ln]
    assert table_gathers, "expected a gather from the table"
    for ln in table_gathers:
        res = ln.rsplit("-> tensor<", 1)[-1]
        assert res.startswith(f"{cap}x"), ln
        assert not res.startswith(f"{b * lk}x"), ln

# ---------------------------------------------------------------------------
# EBC / train-step integration
# ---------------------------------------------------------------------------


def _planned_vs_plain_lookup(cfg, rng):
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    raw = make_dlrm_batch(cfg, 8)
    idx = np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))
    mega = rng.randn(ebc.plan.total_rows, cfg.embed_dim).astype(np.float32)
    params = {"mega": jnp.asarray(mega)}
    plan = build_sparse_plan_host(idx)
    planj = SparsePlan(*(jnp.asarray(x) for x in plan))
    p0 = jax.jit(lambda p, i: ebc.lookup(p, i))(params, jnp.asarray(idx))
    p1 = jax.jit(lambda p, i, pl_: ebc.lookup(p, i, plan=pl_))(
        params, jnp.asarray(idx), planj)
    np.testing.assert_array_equal(np.asarray(p0), np.asarray(p1))


def test_lookup_with_plan_bit_exact_direct_path(rng):
    _planned_vs_plain_lookup(get_smoke_config("dlrm-m1"), rng)   # f <= 8


def test_lookup_with_plan_bit_exact_scan_path(rng):
    cfg = get_smoke_config("dlrm-m1")
    f = 10                                                        # f > 8
    cfg = dataclasses.replace(cfg, n_sparse_features=f,
                              hash_sizes=(40,) * f,
                              mean_lookups=(3,) * f)
    _planned_vs_plain_lookup(cfg, rng)


def test_lookup_local_dedup_matches_legacy(rng):
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    raw = make_dlrm_batch(cfg, 8)
    idx = ebc.offset_indices(jnp.asarray(raw["idx"]))
    mega = jnp.asarray(rng.randn(ebc.plan.total_rows,
                                 cfg.embed_dim).astype(np.float32))
    lo, hi = 0, ebc.plan.total_rows
    out0 = ebc.lookup_local(mega, idx, lo, hi)
    out1 = ebc.lookup_local(mega, idx, lo, hi, dedup=True)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))


def test_dlrm_forward_consumes_batch_plan_bit_exact(rng):
    """dlrm_grads picks the plan off the batch for the FORWARD too: loss
    and pooled grads must be bit-identical with and without plan keys."""
    from repro.core.dlrm import dlrm_grads
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(3))
    hook = sparse_plan_hook(ebc.plan.table_offsets)
    raw = make_dlrm_batch(cfg, 8)
    batch = hook({k: np.asarray(v) for k, v in raw.items()})
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    no_plan = {k: v for k, v in jb.items()
               if not k.startswith("plan_") and k != "uniq_rows"}
    l1, _, (_, g1) = dlrm_grads(params, jb, cfg, ebc)
    l2, _, (_, g2) = dlrm_grads(params, no_plan, cfg, ebc)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))

# ---------------------------------------------------------------------------
# cached tiers: miss planning through the plan
# ---------------------------------------------------------------------------


def _tiny_cached_cfg():
    return dataclasses.replace(
        get_smoke_config("dlrm-m1"), n_sparse_features=2,
        hash_sizes=(80, 40), mean_lookups=(4, 2), bottom_mlp=(8, 16),
        top_mlp=(26, 1))


def test_cache_prepare_with_plan_matches_without(rng):
    """The miss planner fed the reader-thread plan must produce the same
    remap, slot maps, and counters as the np.unique path — the plan's
    live prefix IS the sorted unique row set."""
    cfg = _tiny_cached_cfg()
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    mega = jnp.asarray(rng.randn(ebc.plan.total_rows,
                                 cfg.embed_dim).astype(np.float32))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=64)
    s1, s2 = cc.init_state(mega), cc.init_state(mega)
    for t in range(3):
        raw = make_dlrm_batch(cfg, 8, step=t)
        idx = np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))
        plan = build_sparse_plan_host(idx)
        l1 = cc.prepare(s1, idx, train=True)
        l2 = cc.prepare(s2, idx, train=True, plan=plan)
        np.testing.assert_array_equal(l1, l2)
        np.testing.assert_array_equal(s1.slot_row, s2.slot_row)
        np.testing.assert_array_equal(s1.dirty, s2.dirty)
        assert s1.stats.snapshot() == s2.stats.snapshot()
        np.testing.assert_array_equal(np.asarray(s1.freq),
                                      np.asarray(s2.freq))


def test_plan_to_slots_keeps_rows_sorted_and_decodes(rng):
    """After the row->slot relabel the live prefix must stay strictly
    ascending (the dedup'd forward's invariant) and still decode to the
    same (slot, bag) multiset."""
    cfg = _tiny_cached_cfg()
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    mega = jnp.asarray(rng.randn(ebc.plan.total_rows,
                                 cfg.embed_dim).astype(np.float32))
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=64)
    state = cc.init_state(mega)
    raw = make_dlrm_batch(cfg, 8, step=5)
    idx = np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"])))
    plan = build_sparse_plan_host(idx)
    cc.prepare(state, idx, train=True, plan=plan)
    slot_plan = cc.plan_to_slots(state, plan.to_batch())
    rows, offs, bags = (slot_plan["plan_rows"], slot_plan["plan_offsets"],
                        slot_plan["plan_bags"])
    live = rows[rows >= 0]
    assert np.all(np.diff(live) > 0)
    # decode (slot, bag) pairs and compare against the direct remap
    decoded = sorted(
        (int(rows[i]), int(bags[j]))
        for i in range(len(live))
        for j in range(offs[i], offs[i + 1]))
    local = state.row_slot[np.maximum(idx, 0)]
    flat = np.where(idx >= 0, local, -1).reshape(-1)
    lk = idx.shape[2]
    expected = sorted((int(s), p // lk)
                      for p, s in enumerate(flat) if s >= 0)
    assert decoded == expected


def test_cached_step_forward_and_backward_share_slot_plan(rng):
    """End-to-end: cached train steps fed hook plans (which now drive the
    forward gather, the fused backward, AND the miss planner) leave
    bit-identical tiers vs the plan-less run."""
    from repro.train.steps import (build_cached_dlrm_train_step,
                                   cached_dlrm_init_state)
    cfg = _tiny_cached_cfg()
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(7))
    opt = adagrad(0.01)
    hook = sparse_plan_hook(ebc.plan.table_offsets)
    batches = [hook({k: np.asarray(v) for k, v in
                     make_dlrm_batch(cfg, 8, step=t).items()})
               for t in range(3)]

    def run(with_plan):
        cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=64)
        dense = {"bottom": params["bottom"], "top": params["top"]}
        state = cached_dlrm_init_state(cc, opt, params)
        cstate = cc.init_state(params["emb"]["mega"])
        step = build_cached_dlrm_train_step(cfg, cc, opt)
        losses = []
        for t, b in enumerate(batches):
            b = dict(b)
            if not with_plan:
                for k in ("plan_rows", "plan_offsets", "plan_bags"):
                    b.pop(k)
            dense, state, m = step(dense, state, cstate, b,
                                   jnp.asarray(t, jnp.int32))
            losses.append(float(m["loss"]))
        mega, accum = cc.materialize(cstate)
        return mega, accum, losses

    m1, a1, l1 = run(True)
    m2, a2, l2 = run(False)
    assert l1 == l2
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

# ---------------------------------------------------------------------------
# acceptance: forward-traffic model
# ---------------------------------------------------------------------------


def test_embedding_forward_traffic_reduction_exceeds_truncation():
    """ISSUE acceptance: >= L-fold HBM row-read (and bytes) reduction at
    the prod shape (B=4096, F=127, L=32) in the Zipf-head reuse regime
    (Gupta et al.): hot batches reference at most one unique row per bag
    (n_unique <= B*F). The model is linear in n_unique, so any batch at
    least this duplicate-heavy does at least this well — asserted with
    the FULL plan charged to the forward (plan_shared=False), at both
    m3's real embed dim (64) and the bench dim (128)."""
    b, f, lk = 4096, 127, 32
    for d in (64, 128):
        t = embedding_forward_traffic(b, f, lk, d, n_unique=b * f,
                                      plan_shared=False)
        assert t["row_read_reduction"] >= lk
        assert t["reduction"] >= lk
    # sanity: legacy counts the three full-width per-slot tensors and
    # B*F*L row reads (the legacy kernel DMAs every slot)
    n = b * f * lk
    t = embedding_forward_traffic(b, f, lk, 128, n_unique=b * f)
    assert t["legacy_bytes"] == pytest.approx(3 * n * 128 * 4)
    assert t["legacy_row_reads"] == n
    assert t["dedup_bytes"] == pytest.approx(b * f * 128 * 4)


def test_zipf_expected_unique_exact_and_monotone():
    """The deterministic E[unique] helper: exact on a tiny enumerable
    case, monotone in draws, capped by the hash size."""
    # h=2, alpha->p = (0.659, 0.341); E[unique] for n=1 is 1 exactly
    assert zipf_expected_unique(1, 2) == pytest.approx(1.0)
    u1 = zipf_expected_unique(100, 1000)
    u2 = zipf_expected_unique(1000, 1000)
    assert 0 < u1 < u2 < 1000
    # saturation: far more draws than rows -> every row seen
    assert zipf_expected_unique(1e7, 50) == pytest.approx(50, rel=1e-6)
    # matches a direct dense computation on a small case
    r = np.arange(1, 301, dtype=np.float64)
    p = r ** -1.05
    p /= p.sum()
    want = (1 - (1 - p) ** 500).sum()
    assert zipf_expected_unique(500, 300) == pytest.approx(want, rel=1e-9)


def test_bag_grad_sums_capacity_trim_matches_full(rng):
    idx = _zipf_idx2(rng, 9, 7, 30)
    n = idx.size
    nu = len(np.unique(idx[idx >= 0]))
    full = build_sparse_plan_host(idx.reshape(-1), lookups_per_bag=7)
    trim = build_sparse_plan_host(idx.reshape(-1), lookups_per_bag=7,
                                  capacity=nu + 2)
    pooled = jnp.asarray(rng.randn(9, 16).astype(np.float32))
    g_full = ref.bag_grad_sums(*(jnp.asarray(x) for x in full),
                               pooled)
    g_trim = ref.bag_grad_sums(*(jnp.asarray(x) for x in trim), pooled)
    assert g_full.shape == (n, 16)
    assert g_trim.shape == (nu + 2, 16)
    np.testing.assert_array_equal(np.asarray(g_full[:nu + 2]),
                                  np.asarray(g_trim))
