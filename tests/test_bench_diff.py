"""benchmarks/diff_bench.py: the CI bench-regression gate."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.diff_bench import diff, load_rows, main, trend  # noqa: E402


def _rows(**kv):
    return {name: (us, drv) for name, (us, drv) in kv.items()}


def test_hit_rate_drop_flagged_rise_ignored():
    base = _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.80)})
    # 15% relative drop > 10% threshold
    regs, _ = diff(base, _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.68)}))
    assert len(regs) == 1 and "derived" in regs[0]
    # improvement never flags
    regs, _ = diff(base, _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.95)}))
    assert regs == []
    # drop within threshold passes
    regs, _ = diff(base, _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.75)}))
    assert regs == []


def test_overlap_rows_gated_at_the_time_threshold():
    """Overlap efficiency is a ratio of wall-clock times — it regresses at
    the (relaxable) time threshold, not the strict hit-rate one."""
    base = _rows(**{"cache/overlap_b4096_c10pct": (150000.0, 0.95)})
    cur = _rows(**{"cache/overlap_b4096_c10pct": (150000.0, 0.40)})
    regs, _ = diff(base, cur)
    assert len(regs) == 1                           # 58% drop > 10% default
    regs, _ = diff(base, cur, time_threshold=0.75)  # CI's relaxed gate
    assert regs == []
    # a hit-rate row keeps the strict threshold even when time is relaxed
    base = _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.80)})
    cur = _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.60)})
    regs, _ = diff(base, cur, time_threshold=0.75)
    assert len(regs) == 1


def test_step_time_rise_flagged_and_noise_floor_respected():
    base = _rows(**{"cache/step_cached_10pct": (10_000.0, 5.0),
                    "kernels/tiny": (8.0, 1.0)})
    cur = _rows(**{"cache/step_cached_10pct": (13_000.0, 5.0),
                   "kernels/tiny": (24.0, 1.0)})      # 3x but under min_us
    regs, _ = diff(base, cur)
    assert len(regs) == 1
    assert "step_cached" in regs[0]
    # relaxed CI threshold lets the same rise through
    regs, _ = diff(base, cur, time_threshold=0.50)
    assert regs == []


def test_added_and_removed_rows_warn_not_fail():
    base = _rows(old=(100.0, 1.0))
    cur = _rows(new=(100.0, 1.0))
    regs, warns = diff(base, cur)
    assert regs == []
    assert len(warns) == 2


def test_quality_row_also_checked_for_time():
    base = _rows(**{"cache/hit_a1.2_c25pct": (10_000.0, 0.9)})
    cur = _rows(**{"cache/hit_a1.2_c25pct": (20_000.0, 0.9)})
    regs, _ = diff(base, cur)
    assert len(regs) == 1 and "us_per_call" in regs[0]


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(
        {"rows": [{"name": n, "us_per_call": u, "derived": d}
                  for n, (u, d) in rows.items()], "failures": 0}))
    return str(p)


def test_cli_end_to_end(tmp_path):
    base = _write(tmp_path, "base.json",
                  _rows(**{"cache/hit_x": (1000.0, 0.8),
                           "cache/step_y": (5000.0, 10.0)}))
    good = _write(tmp_path, "good.json",
                  _rows(**{"cache/hit_x": (1010.0, 0.81),
                           "cache/step_y": (5100.0, 10.0)}))
    bad = _write(tmp_path, "bad.json",
                 _rows(**{"cache/hit_x": (1000.0, 0.5),
                          "cache/step_y": (5000.0, 10.0)}))
    assert main([base, good]) == 0
    assert main([base, bad]) == 1
    assert load_rows(base)["cache/hit_x"] == (1000.0, 0.8)


def test_trend_report_tracks_history_worst_drift_first(tmp_path):
    """The longer-horizon trend report: per-row sequences across the whole
    artifact history, end-to-end deltas, worst time drift ordered first,
    rows absent from some artifacts shown with gaps."""
    a = _write(tmp_path, "a.json", _rows(**{"k/slow": (100.0, 1.0),
                                            "k/fast": (100.0, 2.0)}))
    b = _write(tmp_path, "b.json", _rows(**{"k/slow": (130.0, 1.0),
                                            "k/fast": (90.0, 2.0),
                                            "k/new": (10.0, 5.0)}))
    c = _write(tmp_path, "c.json", _rows(**{"k/slow": (180.0, 0.9),
                                            "k/fast": (95.0, 2.2),
                                            "k/new": (11.0, 5.0)}))
    lines = trend([a, b, c])
    assert lines[0].startswith("# trend over 3 artifacts")
    body = lines[1:]
    # worst drift (k/slow, +80%) first
    assert body[0].startswith("k/slow:")
    assert "+80.0%" in body[0]
    assert "100.0 -> 130.0 -> 180.0" in body[0]
    # gap rendering for the late-appearing row
    new_line = next(ln for ln in body if ln.startswith("k/new:"))
    assert "- -> 10.0 -> 11.0" in new_line
    # derived deltas tracked too
    fast_line = next(ln for ln in body if ln.startswith("k/fast:"))
    assert "+10.0%" in fast_line


def test_trend_cli_never_fails(tmp_path):
    a = _write(tmp_path, "a.json", _rows(**{"cache/hit_x": (1000.0, 0.8)}))
    b = _write(tmp_path, "b.json", _rows(**{"cache/hit_x": (1000.0, 0.1)}))
    # a catastrophic hit-rate drop still exits 0 under --trend: the
    # pairwise diff is the only gate
    assert main(["--trend", a, b]) == 0
    assert main([a, b]) == 1


def test_serve_rows_gate_two_sided_on_derived_only():
    """serve/ rows: any derived drift beyond the threshold flags — BOTH
    directions (a deterministic rate that moved means serving behaviour
    changed) — while their us columns stay informational, and the serve
    rule wins over the one-sided hit rule for serve/..._hit_rate."""
    base = _rows(**{"serve/replay_shed_rate_4x": (1000.0, 0.40),
                    "serve/replay_hit_rate": (1000.0, 0.80)})
    # a DROP in shed rate (looks like an improvement) still flags
    regs, _ = diff(base, _rows(**{"serve/replay_shed_rate_4x": (1000.0, 0.20),
                                  "serve/replay_hit_rate": (1000.0, 0.80)}))
    assert len(regs) == 1 and "shed_rate" in regs[0] and "drift" in regs[0]
    # a RISE in hit rate flags too: the serve rule, not the hit rule
    regs, _ = diff(base, _rows(**{"serve/replay_shed_rate_4x": (1000.0, 0.40),
                                  "serve/replay_hit_rate": (1000.0, 0.95)}))
    assert len(regs) == 1 and "hit_rate" in regs[0]
    # within-threshold moves pass, and a 10x us swing never gates
    regs, _ = diff(base, _rows(**{"serve/replay_shed_rate_4x": (9999.0, 0.41),
                                  "serve/replay_hit_rate": (100.0, 0.79)}))
    assert regs == []
