"""benchmarks/diff_bench.py: the CI bench-regression gate."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.diff_bench import diff, load_rows, main  # noqa: E402


def _rows(**kv):
    return {name: (us, drv) for name, (us, drv) in kv.items()}


def test_hit_rate_drop_flagged_rise_ignored():
    base = _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.80)})
    # 15% relative drop > 10% threshold
    regs, _ = diff(base, _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.68)}))
    assert len(regs) == 1 and "derived" in regs[0]
    # improvement never flags
    regs, _ = diff(base, _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.95)}))
    assert regs == []
    # drop within threshold passes
    regs, _ = diff(base, _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.75)}))
    assert regs == []


def test_overlap_rows_gated_at_the_time_threshold():
    """Overlap efficiency is a ratio of wall-clock times — it regresses at
    the (relaxable) time threshold, not the strict hit-rate one."""
    base = _rows(**{"cache/overlap_b4096_c10pct": (150000.0, 0.95)})
    cur = _rows(**{"cache/overlap_b4096_c10pct": (150000.0, 0.40)})
    regs, _ = diff(base, cur)
    assert len(regs) == 1                           # 58% drop > 10% default
    regs, _ = diff(base, cur, time_threshold=0.75)  # CI's relaxed gate
    assert regs == []
    # a hit-rate row keeps the strict threshold even when time is relaxed
    base = _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.80)})
    cur = _rows(**{"cache/hit_a1.05_c10pct": (1000.0, 0.60)})
    regs, _ = diff(base, cur, time_threshold=0.75)
    assert len(regs) == 1


def test_step_time_rise_flagged_and_noise_floor_respected():
    base = _rows(**{"cache/step_cached_10pct": (10_000.0, 5.0),
                    "kernels/tiny": (8.0, 1.0)})
    cur = _rows(**{"cache/step_cached_10pct": (13_000.0, 5.0),
                   "kernels/tiny": (24.0, 1.0)})      # 3x but under min_us
    regs, _ = diff(base, cur)
    assert len(regs) == 1
    assert "step_cached" in regs[0]
    # relaxed CI threshold lets the same rise through
    regs, _ = diff(base, cur, time_threshold=0.50)
    assert regs == []


def test_added_and_removed_rows_warn_not_fail():
    base = _rows(old=(100.0, 1.0))
    cur = _rows(new=(100.0, 1.0))
    regs, warns = diff(base, cur)
    assert regs == []
    assert len(warns) == 2


def test_quality_row_also_checked_for_time():
    base = _rows(**{"cache/hit_a1.2_c25pct": (10_000.0, 0.9)})
    cur = _rows(**{"cache/hit_a1.2_c25pct": (20_000.0, 0.9)})
    regs, _ = diff(base, cur)
    assert len(regs) == 1 and "us_per_call" in regs[0]


def test_cli_end_to_end(tmp_path):
    def write(name, rows):
        p = tmp_path / name
        p.write_text(json.dumps(
            {"rows": [{"name": n, "us_per_call": u, "derived": d}
                      for n, (u, d) in rows.items()], "failures": 0}))
        return str(p)

    base = write("base.json", _rows(**{"cache/hit_x": (1000.0, 0.8),
                                       "cache/step_y": (5000.0, 10.0)}))
    good = write("good.json", _rows(**{"cache/hit_x": (1010.0, 0.81),
                                       "cache/step_y": (5100.0, 10.0)}))
    bad = write("bad.json", _rows(**{"cache/hit_x": (1000.0, 0.5),
                                     "cache/step_y": (5000.0, 10.0)}))
    assert main([base, good]) == 0
    assert main([base, bad]) == 1
    assert load_rows(base)["cache/hit_x"] == (1000.0, 0.8)
