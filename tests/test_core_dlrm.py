"""DLRM core behaviour: interaction math, placement auto-selection, and a
short end-to-end training run whose loss must decrease (planted signal)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import EmbeddingBagCollection, dlrm_param_specs
from repro.core.dlrm import dlrm_grads, dlrm_loss, normalized_entropy
from repro.core.interaction import interact, interaction_dim
from repro.core.placement import plan_placement
from repro.data import make_dlrm_batch
from repro.nn.params import init_params
from repro.optim import adagrad
from repro.train.steps import build_dlrm_train_step, dlrm_init_state


def test_interaction_dims(rng):
    b, f, d = 4, 5, 8
    bot = jnp.asarray(rng.randn(b, d), jnp.float32)
    pooled = jnp.asarray(rng.randn(b, f, d), jnp.float32)
    for kind in ("dot", "cat"):
        out = interact(bot, pooled, kind)
        assert out.shape == (b, interaction_dim(f, d, kind))


def test_dot_interaction_order_invariance(rng):
    """Pairwise dots are permutation-covariant: permuting the sparse features
    permutes the triangle but preserves the value multiset."""
    b, f, d = 2, 4, 8
    bot = jnp.asarray(rng.randn(b, d), jnp.float32)
    pooled = jnp.asarray(rng.randn(b, f, d), jnp.float32)
    out1 = np.sort(np.asarray(interact(bot, pooled, "dot"))[:, 8:], axis=1)
    perm = pooled[:, ::-1, :]
    out2 = np.sort(np.asarray(interact(bot, perm, "dot"))[:, 8:], axis=1)
    np.testing.assert_allclose(out1, out2, rtol=1e-4, atol=1e-5)


def test_placement_auto_matches_paper_logic():
    """Paper Fig. 1/8: fits-on-one-chip -> local; fits-in-pod -> table-wise;
    giant tables -> row-wise."""
    small = plan_placement([100] * 4, [5] * 4, 64, 16, hbm_budget_bytes=1e9)
    assert small.strategy == "replicated"
    mid = plan_placement([1_000_000] * 32, [5] * 32, 64, 16,
                         hbm_budget_bytes=600e6)
    assert mid.strategy == "table_wise"     # 8.2 GB over 16 x 0.6 GB shards
    big = plan_placement([50_000_000, 100], [5, 5], 64, 16,
                         hbm_budget_bytes=600e6)
    assert big.strategy == "row_wise"       # 12.8 GB single table straddles


def test_offset_indices_respect_plan():
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=4)
    raw = jnp.asarray(np.array([[[0, -1], [0, 1]]]), jnp.int32)
    raw = jnp.broadcast_to(raw, (1, 2, 2))[:, :cfg.n_sparse_features][
        :, :, :2]
    idx = ebc.offset_indices(
        jnp.zeros((1, cfg.n_sparse_features, 2), jnp.int32))
    offs = np.asarray(idx)[0, :, 0]
    np.testing.assert_array_equal(offs, np.asarray(ebc.plan.table_offsets))


def test_dlrm_loss_decreases():
    cfg = get_smoke_config("dlrm-m2")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=2)
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.1)
    state = dlrm_init_state(ebc, opt, params)
    step = jax.jit(build_dlrm_train_step(cfg, ebc, opt, sparse_lr=0.1))
    losses = []
    for i in range(40):
        raw = make_dlrm_batch(cfg, 64, step=i)
        batch = {"dense": jnp.asarray(raw["dense"]),
                 "idx": ebc.offset_indices(jnp.asarray(raw["idx"])),
                 "label": jnp.asarray(raw["label"])}
        params, state, m = step(params, state, batch,
                                jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.02, losses[:3]


def test_sparse_dense_grad_split_matches_autodiff():
    """The two-phase (dense autodiff + manual sparse) gradient must equal
    full autodiff through the embedding lookup."""
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=2)
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(3))
    raw = make_dlrm_batch(cfg, 8)
    batch = {"dense": jnp.asarray(raw["dense"]),
             "idx": ebc.offset_indices(jnp.asarray(raw["idx"])),
             "label": jnp.asarray(raw["label"])}

    loss, g_dense, (idx_blf, g_pooled) = dlrm_grads(params, batch, cfg, ebc)
    # full autodiff
    full = jax.grad(lambda p: dlrm_loss(p, batch, cfg, ebc))(params)
    for k in ("bottom", "top"):
        for ga, gb in zip(jax.tree.leaves(g_dense[k]),
                          jax.tree.leaves(full[k])):
            np.testing.assert_allclose(ga, gb, rtol=1e-4, atol=1e-5)
    # sparse: scatter manual per-lookup grads densely and compare
    fi, fg = ebc.per_lookup_grads(idx_blf, g_pooled)
    h = ebc.plan.total_rows
    valid = fi >= 0
    idx = jnp.where(valid, fi, h)
    dense_sparse = jnp.zeros((h + 1, cfg.embed_dim), jnp.float32).at[idx] \
        .add(jnp.where(valid[:, None], fg, 0.0))[:h]
    np.testing.assert_allclose(dense_sparse, full["emb"]["mega"],
                               rtol=1e-4, atol=1e-5)


def test_normalized_entropy_baseline(rng):
    labels = jnp.asarray((rng.rand(4096) < 0.3).astype(np.float32))
    p = float(labels.mean())
    const_logit = jnp.full((4096,), np.log(p / (1 - p)), jnp.float32)
    ne = normalized_entropy(const_logit, labels)
    assert abs(float(ne) - 1.0) < 0.02     # predicting base rate -> NE ~ 1
