"""Per-kernel validation: Pallas kernel bodies (interpret=True) vs the
pure-jnp oracles, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# exercised on BOTH jax floors: this module drives the compat-shim surfaces
# (Pallas memory spaces, shard_map, kernel interpret paths) — see pyproject
# markers and the CI jax-floor leg
pytestmark = pytest.mark.compat
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return {"rtol": 2e-2, "atol": 2e-2} if dtype == jnp.bfloat16 else \
        {"rtol": 1e-5, "atol": 1e-5}

# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("h,d,b,lk", [
    (64, 8, 4, 3),        # tiny
    (97, 48, 16, 7),      # non-128 d, odd sizes
    (257, 128, 8, 32),    # lane-aligned d, truncation-sized lk
    (33, 200, 5, 1),      # single lookup, d > 128
])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_kernel_matches_ref(rng, h, d, b, lk, mode, dtype):
    table = jnp.asarray(rng.randn(h, d), dtype)
    idx = jnp.asarray(rng.randint(-1, h, size=(b, lk)), jnp.int32)
    out_k = ops.embedding_bag(table, idx, mode, None, True)
    out_r = ref.embedding_bag_ref(table, idx, mode)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), **_tol(dtype))


def test_embedding_bag_all_padding(rng):
    table = jnp.asarray(rng.randn(10, 16), jnp.float32)
    idx = jnp.full((3, 4), -1, jnp.int32)
    out = ops.embedding_bag(table, idx, "sum", None, True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_embedding_bag_grad_matches_ref(rng):
    table = jnp.asarray(rng.randn(50, 24), jnp.float32)
    idx = jnp.asarray(rng.randint(-1, 50, size=(8, 5)), jnp.int32)
    g = jnp.asarray(rng.randn(8, 24), jnp.float32)

    def f(t):
        return (ops.embedding_bag(t, idx, "sum", False, False) * g).sum()

    def fr(t):
        return (ref.embedding_bag_ref(t, idx, "sum") * g).sum()

    np.testing.assert_allclose(jax.grad(f)(table), jax.grad(fr)(table),
                               rtol=1e-5, atol=1e-5)

# ---------------------------------------------------------------------------
# dot_interaction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,f,d", [
    (8, 4, 16), (8, 11, 33), (16, 27, 64), (4, 8, 128),
])
def test_dot_interaction_kernel_matches_ref(rng, b, f, d, dtype):
    z = jnp.asarray(rng.randn(b, f, d), dtype)
    out_k = ops.dot_interaction(z, 4, None, True)
    out_r = ref.dot_interaction_ref(z)
    assert out_k.shape == (b, f * (f - 1) // 2)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-1 if dtype == jnp.bfloat16 else 1e-4)


def test_dot_interaction_grad(rng):
    z = jnp.asarray(rng.randn(4, 6, 12), jnp.float32)
    gk = jax.grad(lambda z: (ops.dot_interaction(z, 4, False, False) ** 2)
                  .sum())(z)
    gr = jax.grad(lambda z: (ref.dot_interaction_ref(z) ** 2).sum())(z)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)

# ---------------------------------------------------------------------------
# rowwise_adagrad
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,d,n", [(64, 8, 16), (97, 48, 23), (128, 64, 64)])
def test_rowwise_adagrad_kernel_matches_ref(rng, h, d, n):
    table = jnp.asarray(rng.randn(h, d), jnp.float32)
    accum = jnp.asarray(np.abs(rng.randn(h)), jnp.float32)
    idx = jnp.asarray(rng.randint(-1, h, size=(n,)), jnp.int32)
    grads = jnp.asarray(rng.randn(n, d), jnp.float32)
    tk, ak = ops.rowwise_adagrad_update(table, accum, idx, grads, 0.05,
                                        1e-8, None, True)
    tr, ar = ref.rowwise_adagrad_ref(table, accum, idx, grads, 0.05, 1e-8)
    np.testing.assert_allclose(ak, ar, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(tk, tr, rtol=1e-5, atol=1e-6)


def test_rowwise_adagrad_dedup_semantics(rng):
    """Duplicate rows must be aggregated BEFORE the update (one rsqrt), not
    applied per-duplicate — the sync replacement for HogWild (DESIGN 2)."""
    table = jnp.zeros((4, 8), jnp.float32)
    accum = jnp.zeros((4,), jnp.float32)
    g = jnp.ones((2, 8), jnp.float32)
    idx = jnp.asarray([2, 2], jnp.int32)
    t1, a1 = ref.rowwise_adagrad_ref(table, accum, idx, g, 1.0, 0.0)
    # aggregated grad = 2 -> accum = 4, step = 2/sqrt(4) = 1
    np.testing.assert_allclose(a1[2], 4.0)
    np.testing.assert_allclose(t1[2], -1.0 * jnp.ones(8), rtol=1e-6)


def test_dedup_grads_ref_aggregates_duplicates(rng):
    idx = jnp.asarray([5, 3, 5, -1, 3, 7], jnp.int32)
    grads = jnp.asarray(np.arange(6 * 2).reshape(6, 2), jnp.float32)
    uniq, gsum = ref.dedup_grads_ref(idx, grads, 10)
    got = {int(u): np.asarray(gsum[i]) for i, u in enumerate(np.asarray(uniq))
           if u >= 0}
    assert sorted(got) == [3, 5, 7]
    np.testing.assert_allclose(got[5], np.asarray(grads[0] + grads[2]))
    np.testing.assert_allclose(got[3], np.asarray(grads[1] + grads[4]))
    np.testing.assert_allclose(got[7], np.asarray(grads[5]))
    # every non-unique slot zeroed
    for i, u in enumerate(np.asarray(uniq)):
        if u < 0:
            np.testing.assert_array_equal(np.asarray(gsum[i]), 0.0)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,s,h,dh,bq,bk", [
    (2, 64, 3, 16, 16, 16),     # tiny, square blocks
    (1, 128, 2, 128, 32, 64),   # lane-aligned dh, rectangular blocks
    (2, 96, 2, 40, 32, 32),     # dh and seq need padding
])
def test_flash_attention_kernel_matches_ref(rng, b, s, h, dh, bq, bk, dtype):
    q = jnp.asarray(rng.randn(b, s, h, dh) * 0.5, dtype)
    k = jnp.asarray(rng.randn(b, s, h, dh) * 0.5, dtype)
    v = jnp.asarray(rng.randn(b, s, h, dh), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk, causal=True,
                              use_kernel=None, interpret=True)
    r = ref.flash_attention_ref(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                v.swapaxes(1, 2), True).swapaxes(1, 2)
    tol = {"rtol": 3e-2, "atol": 3e-2} if dtype == jnp.bfloat16 else \
        {"rtol": 2e-4, "atol": 2e-4}
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), **tol)


def test_flash_attention_is_causal(rng):
    b, s, h, dh = 1, 64, 2, 16
    q = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    base = ops.flash_attention(q, k, v, 16, 16, True, None, True)
    k2 = k.at[:, 40:].set(77.0)
    v2 = v.at[:, 40:].set(-77.0)
    pert = ops.flash_attention(q, k2, v2, 16, 16, True, None, True)
    np.testing.assert_allclose(np.asarray(base[:, :40]),
                               np.asarray(pert[:, :40]), rtol=1e-5,
                               atol=1e-5)
