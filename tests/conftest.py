"""Shared fixtures. NOTE: no XLA_FLAGS here on purpose — smoke tests and
benches must see 1 CPU device (the 512-device flag belongs ONLY to
launch/dryrun.py, which always runs as its own process)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# hypothesis is a [dev] extra — property tests must skip, not error, when it
# is absent (bare `pip install .` environments still run the suite)
try:
    import hypothesis  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

requires_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed (pip install .[dev])")


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
