"""Render the section-Roofline table from runs/dryrun/*.json artifacts.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir runs/dryrun]
Emits a markdown table (also used verbatim in EXPERIMENTS.md) plus CSV rows.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}m"
    return f"{x * 1e6:.0f}u"


def markdown(recs: list[dict], mesh: str = "single") -> str:
    rows = [r for r in recs if r.get("mesh") ==
            ("2x16x16" if mesh == "multi" else "16x16")]
    out = ["| arch | shape | compute_s | per-chip | memory_s | "
           "collective_s | dominant | model/HLO | roofline | est GB "
           "| raw GB | ok |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - |"
                       f" - | - | - | - | FAIL: "
                       f"{r.get('error', '?')[:40]} |")
            continue
        gb = r["memory"]["peak_per_device"] / 1e9
        est = r.get("hbm_estimate_gb")
        pc = r.get("compute_s_per_chip")
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(pc) if pc is not None else '-'} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{r['model_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{est if est is not None else '-'} | {gb:.1f} | yes |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print(f"(no dry-run artifacts in {args.dir} — run "
              f"`python -m repro.launch.dryrun` first)")
        return
    print(markdown(recs, args.mesh))
    ok = sum(1 for r in recs if r.get("ok"))
    print(f"\n{ok}/{len(recs)} cells ok")


if __name__ == "__main__":
    main()
