"""Run every paper-table benchmark. One section per paper figure/table.

`PYTHONPATH=src python -m benchmarks.run`
prints ``name,us_per_call,derived`` CSV (derived = examples/s unless noted).

`--only SUBSTR` (repeatable) filters sections by name — the CI benchmark
smoke runs `--only cache --only kernels`. `--json PATH` additionally dumps
the collected rows as JSON (the `BENCH_*.json` perf-trajectory artifacts).
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks import (cache_bench, fig6_access, fig10_features, fig11_batch,
                        fig12_hash, fig13_mlp, fig14_placement, kernels_bench,
                        resilience_bench, serve_bench, table3_prod,
                        tablewise_bench, tiers_bench)
from benchmarks.common import ROWS, header


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="run only sections whose name contains SUBSTR")
    ap.add_argument("--json", default=None,
                    help="also write rows to this JSON file")
    args, _ = ap.parse_known_args()
    header()
    sections = [
        ("fig6/7 access distributions", fig6_access.main),
        ("kernels (section III-A.2)", kernels_bench.main),
        ("fig10 feature sweep", fig10_features.main),
        ("fig11 batch scaling", fig11_batch.main),
        ("fig12 hash scaling", fig12_hash.main),
        ("fig13 mlp dims", fig13_mlp.main),
        ("table III production models", table3_prod.main),
        ("fig1/14 placement", fig14_placement.main),
        ("cache tier (section IV-B)", cache_bench.main),
        ("tiers / heterogeneous memory", tiers_bench.main),
        ("tablewise hybrid parallelism", tablewise_bench.main),
        ("resilience / fault recovery", resilience_bench.main),
        ("serve traffic replay", serve_bench.main),
    ]
    if args.only:
        sections = [(n, f) for n, f in sections
                    if any(sub in n for sub in args.only)]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001 — report all sections
            failures += 1
            traceback.print_exc()
    print("# --- roofline (from dry-run artifacts, if present) ---")
    try:
        from benchmarks import roofline_report
        recs = roofline_report.load("runs/dryrun")
        if recs:
            print(roofline_report.markdown(recs))
    except Exception:  # noqa: BLE001
        traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": u, "derived": d}
                                for n, u, d in ROWS],
                       "failures": failures}, f, indent=1)
        print(f"# wrote {len(ROWS)} rows to {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
