"""Paper Fig. 10: throughput vs (dense x sparse) feature counts.

Expected reproduction: throughput drops as either feature count grows;
sparse features cost more than dense at equal count (embedding lookups +
interaction dominate) — the paper's section V-A claim.
"""
from benchmarks.dlrm_bench import bench_dlrm
from repro.core.design_space import test_suite_config


def main(batch: int = 256):
    for n_dense in (64, 256, 1024):
        for n_sparse in (4, 16, 64):
            cfg = test_suite_config(n_dense=n_dense, n_sparse=n_sparse)
            bench_dlrm(f"fig10/dense{n_dense}_sparse{n_sparse}", cfg, batch,
                       reduce_factor=4)


if __name__ == "__main__":
    main()
