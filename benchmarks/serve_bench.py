"""Serving-tier traffic replay rows (serve/dlrm_engine.py, docs/serving.md).

Zipf traffic with temporal drift plus a flash-crowd key churn phase,
replayed through the overload-robust `DLRMServeEngine`. Four figures:

  * `serve/replay_hit_rate` — steady-state replay (drifting Zipf, no
    deadlines): us = wall per served request, derived = the cache
    hit rate. Traffic is seeded and batch forming is host-deterministic,
    so the derived column is exactly reproducible (ring-gated).
  * `serve/replay_p99_latency` — same replay: us = measured p99
    per-request latency (informational wall time), derived = requests
    served (a determinism canary: any change means the replay changed).
  * `serve/replay_shed_rate_4x` — flash-crowd churn offered at 4x the
    engine's per-step service capacity on a VIRTUAL clock, bounded queue,
    per-request deadlines: derived = shed rate (queue_full + deadline).
    Every shed decision is clock arithmetic on the virtual clock —
    deterministic, ring-gated.
  * `serve/replay_degraded_fraction_chaos` — the steady replay under a
    seeded `FaultInjector` schedule on `serve.fetch`: derived = fraction
    of served requests flagged degraded (stale-snapshot responses).
    Deterministic for a fixed seed, ring-gated.

diff_bench gates `serve/` rows TWO-SIDED on the derived column (any
drift in a deterministic rate is a behaviour change); us columns are
shared-runner wall times, informational only.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.core.cache import CachedEmbeddingBagCollection
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.launch.analysis import serve_replay_traffic
from repro.nn.params import init_params
from repro.serve import DLRMServeEngine, ServeRequest
from repro.train.fault_tolerance import FaultInjector

EXAMPLES = 4          # examples per request
CACHE_ROWS = 256
MAX_BATCH = 16        # engine dispatch slots
MAX_QUEUE = 16


class _VClock:
    """Deterministic virtual clock: shed/deadline decisions become pure
    arithmetic, so the derived columns survive runner noise."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _build():
    cfg = get_smoke_config("dlrm-m1")
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                       strategy="replicated")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    return cfg, ebc, params


def _request(cfg, ebc, uid: int, step: int, deadline=None,
             flash: bool = False) -> ServeRequest:
    """One seeded request: bounded-Zipf rows with a per-step drift of the
    hot head; `flash` collapses traffic onto a small churned key set (the
    flash-crowd phase — everyone hitting the same few items, and WHICH
    items changes every few steps)."""
    raw = make_dlrm_batch(cfg, EXAMPLES, step=step, zipf_alpha=1.05)
    idx = np.asarray(raw["idx"]).copy()
    for t, h in enumerate(cfg.hash_sizes):
        col = (idx[:, t, :] + 3 * step) % h          # temporal drift
        if flash:
            col = (col % 8 + (step // 4) * 8) % h    # churned hot set
        idx[:, t, :] = col
    idx = np.asarray(ebc.offset_indices(idx))
    return ServeRequest(uid, raw["dense"], idx, deadline=deadline)


def replay_bench():
    """Steady-state drifting-Zipf replay: hit rate + p99 latency rows,
    plus the analytic serve-path byte reduction at the measured rates."""
    cfg, ebc, params = _build()
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    engine = DLRMServeEngine(params, cfg, cc, max_queue=MAX_QUEUE,
                             max_batch=MAX_BATCH)
    n_requests = 48
    t0 = time.perf_counter()
    for uid in range(n_requests):
        engine.submit(_request(cfg, ebc, uid, uid))
        if (uid + 1) % 2 == 0:        # 2 requests offered per engine step
            engine.step()
    engine.run()
    wall = time.perf_counter() - t0
    m = engine.metrics.snapshot()
    hit = engine.cache_stats.hit_rate
    emit("serve/replay_hit_rate", wall / max(m["served"], 1) * 1e6, hit)
    emit("serve/replay_p99_latency", m["p99_latency"] * 1e6, m["served"])
    traffic = serve_replay_traffic(
        requests=m["served"], examples=EXAMPLES,
        n_features=cfg.n_sparse_features, truncation=cfg.truncation,
        embed_dim=cfg.embed_dim, hit_rate=hit)
    emit("serve/replay_bytes_reduction", 0.0, traffic["uncached_vs_cached"])


def overload_bench():
    """Flash-crowd churn at 4x the per-step service capacity: 8 requests
    (32 examples) offered per step vs MAX_BATCH=16 examples served, on a
    bounded queue with per-request deadlines — derived = shed rate."""
    cfg, ebc, params = _build()
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    clock = _VClock()
    engine = DLRMServeEngine(params, cfg, cc, max_queue=MAX_QUEUE,
                             max_batch=MAX_BATCH, clock=clock,
                             shed_slack=0.5)
    uid = 0
    t0 = time.perf_counter()
    for step in range(12):
        for _ in range(8):            # 4x offered load
            engine.submit(_request(cfg, ebc, uid, step,
                                   deadline=clock() + 2.5, flash=True))
            uid += 1
        engine.step()
        clock.advance(1.0)
    engine.run()
    wall = time.perf_counter() - t0
    m = engine.metrics.snapshot()
    emit("serve/replay_shed_rate_4x", wall / max(m["submitted"], 1) * 1e6,
         m["shed_rate"])


def chaos_bench():
    """Steady replay under a seeded serve.fetch fault schedule: derived =
    degraded fraction (stale-snapshot responses / served)."""
    cfg, ebc, params = _build()
    cc = CachedEmbeddingBagCollection.build(cfg, cache_rows=CACHE_ROWS)
    inj = FaultInjector.from_seed(13, 16, sites=("serve.fetch",),
                                  n_faults=4)
    clock = _VClock()
    engine = DLRMServeEngine(params, cfg, cc, max_queue=MAX_QUEUE,
                             max_batch=MAX_BATCH, clock=clock,
                             injector=inj)
    n_requests = 48
    t0 = time.perf_counter()
    for uid in range(n_requests):
        engine.submit(_request(cfg, ebc, uid, uid))
        if (uid + 1) % 2 == 0:
            engine.step()
            clock.advance(0.1)
    engine.run()
    wall = time.perf_counter() - t0
    m = engine.metrics.snapshot()
    emit("serve/replay_degraded_fraction_chaos",
         wall / max(m["served"], 1) * 1e6, m["degraded_fraction"])


def main():
    """Run all serving replay rows."""
    replay_bench()
    overload_bench()
    chaos_bench()


if __name__ == "__main__":
    main()
