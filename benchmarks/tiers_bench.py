"""N-tier heterogeneous memory bench: HBM/DRAM/bulk hit mix + bulk overlap.

Three legs, all over the 3-tier `BulkCachedEmbeddingBagCollection`
(docs/memory_tiers.md):

* `tiers/hit_{hbm,dram,bulk}_a{alpha}_c{frac}pct` — steady-state fraction
  of lookup traffic served by each tier under seeded Zipf(alpha) traffic,
  swept over access skew x HBM cache fraction at zero injected bulk
  latency. Deterministic (seeded traffic, sync path): diff_bench gates any
  drift two-sided at the tight threshold. `tiers/promotion_bytes_*` rides
  the same sweep (bulk -> DRAM promotion bytes per step).
* `tiers/bulk_vs_dram_latency` — the ANALYTIC price of the hierarchy from
  `launch/analysis.tier_hierarchy_traffic` (miss-stream latency with the
  measured DRAM hit rate vs an all-DRAM host tier), the model
  `recommend_placement` uses to mark tables cached_host vs cached_bulk.
* `tiers/bulk_overlap_l5us[_strict]` — fraction of the injected
  multi-microsecond bulk fetch latency HIDDEN behind dense compute by the
  async exchange stream (derived = 1 - waited/scheduled, from
  `TierCacheStats`). Timing-derived, so diff_bench gates it at the
  wall-clock threshold.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_interleaved
from repro.core.design_space import test_suite_config
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.core.tiers import AsyncCachedTier, BulkCachedEmbeddingBagCollection
from repro.data.synthetic import bounded_zipf_rows
from repro.launch.analysis import tier_hierarchy_traffic
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import build_cached_train_step, cached_dlrm_init_state

WARM_STEPS = 20
MEASURE_STEPS = 20
BATCH, LOOKUPS = 256, 8

# overlap leg: heavier dense compute so there is in-flight work for the
# deferred bulk deadline to hide behind (constants chosen so the async
# stream hides >= 0.9 of the scheduled latency at Zipf 1.05)
OV_BATCH = 1024
OV_WARM, OV_MEASURE = 5, 10
OV_LATENCY_US = 5.0


def _traffic(cfg, ebc, alpha: float, step: int, batch: int) -> np.ndarray:
    """(B, F, L) OFFSET global rows under bounded Zipf(alpha) per table."""
    rng = np.random.RandomState(1000 + step)
    f = cfg.n_sparse_features
    idx = np.empty((batch, f, LOOKUPS), np.int32)
    for t in range(f):
        idx[:, t, :] = bounded_zipf_rows(
            rng, cfg.hash_sizes[t], batch * LOOKUPS, alpha
        ).reshape(batch, LOOKUPS)
    off = np.asarray(ebc.plan.table_offsets, np.int32)
    return idx + off[None, :, None]


def tier_hit_sweep():
    """derived = per-tier steady-state traffic fractions (deterministic).

    Same discipline as cache_bench.hit_rate_sweep: candidates are timed
    round-robin through `time_interleaved` so runner drift hits every
    config equally, and the counter window is isolated with
    `stats.reset()` at the warm/measure boundary. Bulk latency is zero
    here — these rows gate the tier ROUTING, not the latency model (the
    overlap rows below own the timing side)."""
    cfg = test_suite_config(n_dense=64, n_sparse=2, hash_size=25_000,
                            mlp_width=64, mlp_layers=1, embed_dim=32)
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                      strategy="cached_host")
    total = ebc.plan.total_rows
    mega = jnp.zeros((total, cfg.embed_dim), jnp.float32)
    # HBM floor mirrors cache_bench: the cache must hold one batch's
    # unique working set or prepare() thrashes. DRAM gets 25% of rows so
    # the cold tail genuinely lives in bulk and evictions overflow DRAM.
    combos = [(alpha, frac) for alpha in (1.05, 1.2)
              for frac in (0.05, 0.10)]
    states, fns = [], []
    for alpha, frac in combos:
        bc = BulkCachedEmbeddingBagCollection.build(
            cfg, cache_rows=max(64, int(total * frac)),
            dram_rows=int(total * 0.25), bulk_chunk=32, bulk_latency_us=0.0)
        state = bc.init_state(mega)
        box = [0]                       # per-candidate step cursor

        def one(bc=bc, state=state, alpha=alpha, box=box):
            idx = _traffic(cfg, ebc, alpha, box[0], BATCH)
            box[0] += 1
            jax.block_until_ready(bc.lookup(state, idx, train=False))

        states.append(state)
        fns.append(one)
    for _ in range(WARM_STEPS):         # round-robin warm-up
        for fn in fns:
            fn()
    for s in states:
        s.stats.reset()
    argsets = [() for _ in fns]
    medians = time_interleaved(fns, argsets, warmup=0, iters=MEASURE_STEPS)
    dram_rate = 0.0
    for (alpha, frac), state, us in zip(combos, states, medians):
        s = state.stats
        looked = max(s.hits + s.misses, 1)
        tag = f"a{alpha}_c{int(frac * 100)}pct"
        emit(f"tiers/hit_hbm_{tag}", us, s.hits / looked)
        emit(f"tiers/hit_dram_{tag}", us, s.dram_hits / looked)
        emit(f"tiers/hit_bulk_{tag}", us, s.bulk_hits / looked)
        emit(f"tiers/promotion_bytes_{tag}", us,
             s.promotion_bytes / MEASURE_STEPS)
        if (alpha, frac) == (1.05, 0.10):
            dram_rate = s.dram_hit_rate
    # analytic hierarchy price at the measured Zipf(1.05) c=10% DRAM hit
    # rate: miss-stream latency vs serving the same misses all-DRAM
    traffic = tier_hierarchy_traffic(
        fetched_rows=1000, embed_dim=cfg.embed_dim, dram_hit_rate=dram_rate,
        bulk_chunk=32, bulk_latency_us=50.0)
    emit("tiers/bulk_vs_dram_latency", 0.0, traffic["bulk_vs_dram"])


def bulk_overlap():
    """derived = fraction of injected bulk latency hidden by the async
    stream (1 - waited/scheduled); us = median wall time per train step.

    The deadline model (`BulkStore._schedule`/`wait`) books the scheduled
    cost when promotions for batch k+1 are staged and only sleeps the
    REMAINDER when the commit barrier needs the rows — so everything
    dispatched in between (the in-flight dense compute of batch k) pays
    the latency down. strict_sync preserves the same accounting with the
    wait taken inline, so both rows exist: the async one is the headline,
    the strict one guards that determinism mode still absorbs the cost."""
    cfg = test_suite_config(n_dense=64, n_sparse=2, hash_size=100_000,
                            mlp_width=512, mlp_layers=3, embed_dim=32)
    ebc = EmbeddingBagCollection.build(cfg, n_shards=1,
                                      strategy="cached_host")
    total = ebc.plan.total_rows
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)

    rng = np.random.RandomState(7)
    batches = [{"dense": jnp.asarray(rng.randn(OV_BATCH, cfg.n_dense_features),
                                     jnp.float32),
                "idx": _traffic(cfg, ebc, 1.05, s, OV_BATCH),
                "label": jnp.asarray(rng.rand(OV_BATCH) > 0.5, jnp.float32)}
               for s in range(OV_WARM + OV_MEASURE)]

    def run(strict: bool) -> tuple[float, float]:
        bc = BulkCachedEmbeddingBagCollection.build(
            cfg, cache_rows=int(total * 0.10), dram_rows=int(total * 0.30),
            bulk_chunk=64, bulk_latency_us=OV_LATENCY_US)
        tier = AsyncCachedTier(bc)
        dense = {"bottom": params["bottom"], "top": params["top"]}
        state = cached_dlrm_init_state(bc, opt, params)
        astate = tier.init_state(params["emb"]["mega"])
        step_fn = build_cached_train_step(cfg, tier, opt, strict_sync=strict)
        times = []
        for t, b in enumerate(batches):
            nxt = (batches[t + 1] if not strict and t + 1 < len(batches)
                   else None)
            t0 = time.perf_counter()
            dense_out, state, m = step_fn(dense, state, astate, b,
                                          jnp.asarray(t, jnp.int32),
                                          next_batch=nxt)
            dense = dense_out
            jax.block_until_ready(m["loss"])
            if t >= OV_WARM:
                times.append(time.perf_counter() - t0)
            if t == OV_WARM - 1:
                astate.stats.reset()
        s = astate.stats
        hidden = (1.0 - s.bulk_wait_us / s.bulk_sched_us
                  if s.bulk_sched_us else 1.0)
        times.sort()
        return times[len(times) // 2] * 1e6, hidden

    lat = int(OV_LATENCY_US)
    us, hidden = run(strict=True)
    emit(f"tiers/bulk_overlap_l{lat}us_strict", us, hidden)
    us, hidden = run(strict=False)
    emit(f"tiers/bulk_overlap_l{lat}us", us, hidden)


def main():
    """Run the tier hit-mix sweep and the bulk-overlap measurement."""
    tier_hit_sweep()
    bulk_overlap()


if __name__ == "__main__":
    main()
