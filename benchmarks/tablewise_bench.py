"""Table-wise hybrid parallelism benchmarks (docs/parallelism.md).

Wall rows time `build_tablewise_train_step` (sync and staged-overlap) on a
reduced suite config. The deterministic `tablewise/pooled_exchange_*` rows
are the analytic pooled-exchange accounting at the PROD shape — the
table-wise all-to-all moves pooled (B, F, d) activations, never per-lookup
rows, so the bytes are exact closed forms (launch/analysis.py
`tablewise_exchange_traffic`) — gated against BENCH_baseline.json by
diff_bench's pooled-exchange/bytes rule, and validated against the train
step's measured exchange metrics in the `model_vs_measured` row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.registry import get_config, get_smoke_config
from repro.core.design_space import reduced
from repro.core.dlrm import dlrm_param_specs
from repro.core.embedding import EmbeddingBagCollection
from repro.data.synthetic import make_dlrm_batch
from repro.launch.analysis import (recommend_placement,
                                   tablewise_exchange_traffic)
from repro.nn.params import init_params
from repro.optim.optimizers import adagrad
from repro.train.steps import build_tablewise_train_step, dlrm_init_state

N_HOSTS = 4          # owners for the wall rows (single-process, no mesh)
PROD_HOSTS = 16      # the analytic rows' Zion-scale host count
PROD_BATCH = 8192    # per-step global batch at prod shape


def _build(cfg, overlap: bool):
    ebc = EmbeddingBagCollection.build(cfg, n_shards=N_HOSTS,
                                       strategy="table_wise")
    params = init_params(dlrm_param_specs(cfg, ebc), jax.random.PRNGKey(0))
    opt = adagrad(0.01)
    state = dlrm_init_state(ebc, opt, params)
    step = build_tablewise_train_step(cfg, ebc, opt, overlap=overlap)
    raw = make_dlrm_batch(cfg, 128)
    batch = {"dense": raw["dense"],
             "idx": np.asarray(ebc.offset_indices(jnp.asarray(raw["idx"]))),
             "label": raw["label"]}
    return step, params, state, batch


def _bench_step(name: str, cfg, overlap: bool):
    step, params, state, batch = _build(cfg, overlap)
    cell = [params, state, 0]

    def run(b):
        p, s, m = step(cell[0], cell[1], b, cell[2],
                       next_batch=b if overlap else None)
        cell[0], cell[1] = p, s
        cell[2] += 1
        return m["loss"]

    us = time_fn(run, batch)
    emit(name, us, 128 / (us / 1e6))


def main():
    cfg = reduced(get_config("dlrm-m1"), 32)
    _bench_step("tablewise/step_sync", cfg, overlap=False)
    _bench_step("tablewise/step_staged", cfg, overlap=True)

    # -- deterministic pooled-exchange accounting at prod shape ----------
    prod = get_config("dlrm-m2")
    tw = tablewise_exchange_traffic(PROD_BATCH, prod.n_sparse_features,
                                    prod.truncation, prod.embed_dim,
                                    PROD_HOSTS)
    # pooled (B,F,d) vs the row-sharded un-pooled (B,F,L,d) exchange: ~L
    emit("tablewise/pooled_exchange_bytes_vs_rowshard_m2", 0.0,
         tw["pooling_reduction"])
    # acceptance headroom: each (host, owner) pair leg must stay under
    # B*F*d*4 bytes; derived = cap / leg (>= 1, higher is better)
    cap = PROD_BATCH * prod.n_sparse_features * prod.embed_dim * 4.0
    emit("tablewise/pooled_exchange_pair_leg_headroom_m2", 0.0,
         cap / tw["pair_leg_bytes"])
    # the placement recommender's priced comparison vs the row-sharded
    # cached tier at the same shape (9.6 GB/host accelerator budget)
    rec = recommend_placement(prod.hash_sizes, prod.mean_lookups,
                              prod.embed_dim, PROD_BATCH, prod.truncation,
                              PROD_HOSTS, 9.6e9)
    emit("tablewise/pooled_exchange_vs_cached_m2", 0.0,
         rec["rowshard"]["total_bytes"] / rec["tablewise"]["total_bytes"])
    assert rec["pick"] == "table_wise", rec["pick"]

    # -- model vs measured: the analytic fwd bytes must equal the train
    #    step's host-computed exchange metric exactly ---------------------
    smoke = get_smoke_config("dlrm-m1")
    step, params, state, batch = _build(smoke, overlap=False)
    _, _, metrics = step(params, state, batch, 0)
    b, f, _ = batch["idx"].shape
    model = tablewise_exchange_traffic(b, f, smoke.truncation,
                                       smoke.embed_dim, N_HOSTS)
    measured = float(metrics["exchange_pooled_fwd_bytes"])
    assert measured == model["fwd_bytes"], (measured, model["fwd_bytes"])
    emit("tablewise/pooled_exchange_model_vs_measured", 0.0,
         model["fwd_bytes"] / measured)


if __name__ == "__main__":
    main()
