"""Paper Fig. 11: batch-size throughput scaling.

Expected reproduction: examples/s rises with batch until compute saturates,
then flattens — the paper's saturation curve (section V-B).
"""
from benchmarks.dlrm_bench import bench_dlrm
from repro.core.design_space import test_suite_config


def main():
    cfg = test_suite_config()
    for batch in (64, 128, 256, 512, 1024):
        bench_dlrm(f"fig11/batch{batch}", cfg, batch)


if __name__ == "__main__":
    main()
