"""Kernel microbenchmarks (section III-A.2 hot spots): oracle (jnp) path
timing on CPU + a correctness pass of the Pallas body (interpret mode).
derived = lookups/s (embedding_bag), pairs/s (dot_interaction),
rows/s (rowwise_adagrad).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def main():
    rng = np.random.RandomState(0)
    h, d, b, lk = 100_000, 64, 4096, 32
    table = jnp.asarray(rng.randn(h, d), jnp.float32)
    idx = jnp.asarray(rng.randint(-1, h, size=(b, lk)), jnp.int32)
    f = jax.jit(lambda t, i: ref.embedding_bag_ref(t, i, "sum"))
    us = time_fn(f, table, idx)
    emit("kernels/embedding_bag_ref", us, b * lk / (us / 1e6))

    z = jnp.asarray(rng.randn(2048, 33, 64), jnp.float32)
    g = jax.jit(ref.dot_interaction_ref)
    us = time_fn(g, z)
    emit("kernels/dot_interaction_ref", us,
         2048 * 33 * 32 / 2 / (us / 1e6))

    accum = jnp.zeros((h,), jnp.float32)
    gr = jnp.asarray(rng.randn(b * 4, d), jnp.float32)
    ii = jnp.asarray(rng.randint(-1, h, size=(b * 4,)), jnp.int32)
    k = jax.jit(lambda t, a, i, g: ref.rowwise_adagrad_ref(t, a, i, g, 0.01))
    us = time_fn(k, table, accum, ii, gr)
    emit("kernels/rowwise_adagrad_ref", us, b * 4 / (us / 1e6))

    q = jnp.asarray(rng.randn(2, 256, 4, 64) * 0.5, jnp.float32)
    fa = jax.jit(lambda q: ref.flash_attention_ref(
        q.swapaxes(1, 2), q.swapaxes(1, 2), q.swapaxes(1, 2), True))
    us = time_fn(fa, q)
    emit("kernels/flash_attention_ref", us, 2 * 256 * 256 / (us / 1e6))

    # interpret-mode correctness spot check (body actually executes)
    out_k = ops.embedding_bag(table[:512], idx[:8] % 512, "sum", None, True)
    out_r = ref.embedding_bag_ref(table[:512], idx[:8] % 512, "sum")
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)
    emit("kernels/pallas_interpret_check", 0.0, 1.0)


if __name__ == "__main__":
    main()
